//! Runtime integration: artifact loading, init determinism, train-step
//! parameter updates, eval counters, logits/variance roles.
//!
//! Heavy checks share ONE TrainSession (XLA compilation dominates test
//! time), so they live in a single #[test].  Requires `make artifacts`
//! (skips gracefully if absent).

use kla::data::{task_by_name, Batch};
use kla::runtime::{Runtime, TrainSession, Value};
use kla::tensor::{IntTensor, Tensor};
use kla::util::Pcg64;

fn runtime() -> Option<Runtime> {
    match Runtime::discover() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn fixed_batch(b: usize, t: usize, seed: u64) -> Batch {
    let task = task_by_name("memorization").unwrap();
    let mut rng = Pcg64::seeded(seed);
    task.batch(&mut rng, b, t)
}

#[test]
fn runtime_end_to_end() {
    let Some(rt) = runtime() else { return };

    // ---- init determinism + params non-trivial ----
    let init = rt.load("mad_kla_init").unwrap();
    let a = init.run(&[]).unwrap();
    let b2 = init.run(&[]).unwrap();
    for (x, y) in a.iter().zip(&b2) {
        assert_eq!(x.as_f32().unwrap().data(), y.as_f32().unwrap().data());
    }
    let total: f32 = a
        .iter()
        .map(|v| {
            v.as_f32().unwrap().data().iter().map(|x| x.abs()).sum::<f32>()
        })
        .sum();
    assert!(total > 1.0, "init params look empty: {total}");
    // regression guard for the constant-elision bug: a_raw (param 0) must
    // not be a bit-pattern iota
    let p0 = a[0].as_f32().unwrap().data();
    assert!(p0.iter().any(|x| x.abs() > 1e-3),
            "param 0 is denormal garbage (HLO constant elision?)");

    // ---- one session reused for everything below ----
    let mut session = TrainSession::new(&rt, "mad_kla").unwrap();
    let (b, t) = session.batch_shape();
    let meta = session.meta().clone();

    // eval mask-count echo (proves i32/f32 tensors cross unscrambled)
    let mut mask = Tensor::zeros(&[b, t]);
    for i in 0..13 {
        mask.set(&[i % b, (i * 7) % t], 1.0);
    }
    let echo = Batch {
        tokens: IntTensor::zeros(&[b, t]),
        targets: IntTensor::zeros(&[b, t]),
        mask,
    };
    let r = session.eval_batch(&echo).unwrap();
    assert_eq!(r.count, 13.0);

    // eval on a real batch
    let batch = fixed_batch(b, t, 9);
    let r = session.eval_batch(&batch).unwrap();
    assert_eq!(r.count as f32, batch.mask.data().iter().sum::<f32>());
    assert!(r.correct >= 0.0 && r.correct <= r.count);
    assert!(r.mean_loss() > 0.0);

    // ---- train: params change and fixed-batch loss collapses ----
    let batch = fixed_batch(b, t, 7);
    let before: Vec<f32> =
        session.params()[0].as_f32().unwrap().data().to_vec();
    let loss0 = session.train_step(&batch).unwrap();
    let after: Vec<f32> =
        session.params()[0].as_f32().unwrap().data().to_vec();
    assert_ne!(before, after, "params unchanged after a train step");
    let mut loss = loss0;
    for _ in 0..12 {
        loss = session.train_step(&batch).unwrap();
    }
    assert!(loss < loss0 * 0.5,
            "no learning on a fixed batch: {loss0} -> {loss}");

    // ---- logits role ----
    let tokens = IntTensor::zeros(&[b, t]);
    let out = session.run_role(&rt, "logits", &[Value::I32(tokens)]).unwrap();
    let logits = out[0].as_f32().unwrap();
    assert_eq!(logits.shape(), &[b, t, meta.model.vocab]);
    assert!(logits.data().iter().all(|x| x.is_finite()));
    // logits must differ across vocab (uniform output = dead model)
    let spread = (0..meta.model.vocab)
        .map(|v| logits.get(&[0, 5, v]))
        .fold((f32::MAX, f32::MIN), |(lo, hi), x| (lo.min(x), hi.max(x)));
    assert!(spread.1 - spread.0 > 1e-4, "uniform logits: {spread:?}");

    // ---- variance role ----
    let out = session
        .run_role(&rt, "variance",
                  &[Value::I32(IntTensor::zeros(&[b, t]))])
        .unwrap();
    let var = out[0].as_f32().unwrap();
    assert_eq!(var.shape(), &[b, t]);
    assert!(var.data().iter().all(|&x| x > 0.0));
}

#[test]
fn missing_artifact_error_is_actionable() {
    let Some(rt) = runtime() else { return };
    let err = match rt.load("nonexistent_artifact") {
        Ok(_) => panic!("load of missing artifact succeeded"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("make artifacts") || err.contains("reading"),
            "unhelpful error: {err}");
}

#[test]
fn manifest_names_resolve_to_meta() {
    let Some(rt) = runtime() else { return };
    let names = rt.names().unwrap();
    assert!(names.len() >= 70, "only {} artifacts", names.len());
    for name in names.iter().take(10) {
        let meta = rt.meta(name).unwrap();
        assert_eq!(&meta.name, name);
        assert!(meta.batch > 0 && meta.seq > 0);
    }
}

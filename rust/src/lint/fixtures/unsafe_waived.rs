// Waived fixture for the `unsafe` pass: an undocumented `unsafe`
// suppressed by a waiver comment instead of a
// SAFETY comment.  Never compiled — only `include_str!`-ed by
// unsafe_audit.rs tests.

fn read(p: *const i32) -> i32 {
    // lint: allow(unsafe, fixture: audited in the module doc instead)
    unsafe { *p }
}

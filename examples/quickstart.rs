//! Quickstart: the whole stack in ~60 seconds.
//!
//!   cargo run --release --example quickstart
//!
//! 1. native KLA filter: sequential vs chunked-parallel agree;
//! 2. load an AOT artifact (HLO text -> PJRT) and run a forward pass;
//! 3. train a KLA block on Selective Copy for a few steps;
//! 4. peek at the posterior variance (the paper's uncertainty signal).

use anyhow::Result;
use kla::api::{Filter, KlaFilter, ScanPlan};
use kla::data::task_by_name;
use kla::kla::{random_inputs, random_params};
use kla::runtime::{Runtime, TrainSession, Value};
use kla::util::{Pcg64, Timer};

fn main() -> Result<()> {
    // ---- 1. native filter through the unified kla::api surface ----
    let mut rng = Pcg64::seeded(0);
    let (t, n, d) = (2048, 8, 64);
    let p = random_params(&mut rng, n, d);
    let inp = random_inputs(&mut rng, t, n, d);
    let prior = KlaFilter::init(&p);
    let timer = Timer::start();
    let (seq, _) =
        KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
    let seq_ms = timer.elapsed_ms();
    let timer = Timer::start();
    let plan = ScanPlan::chunked(kla::util::pool::default_threads());
    let (par, posterior) = KlaFilter::prefix(&p, &inp, &prior, &plan);
    let par_ms = timer.elapsed_ms();
    let max_diff = seq
        .y
        .iter()
        .zip(&par.y)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("[1] native Moebius filter, T={t}: sequential {seq_ms:.1} ms, \
              chunked {par_ms:.1} ms ({:.1}x), max |diff| {max_diff:.2e}",
             seq_ms / par_ms);

    // ---- 1b. decode-time stepping carries the same belief type ----
    // run the first half as a scan, then step token-by-token: the carry
    // (posterior precision + information mean) reproduces the full scan.
    let half = t / 2;
    let (_, mut carry) = KlaFilter::prefix(&p, &inp.slice(0, half), &prior,
                                           &ScanPlan::sequential());
    let tail = inp.slice(half, t);
    let mut y_last = Vec::new();
    for ti in 0..tail.t {
        y_last = KlaFilter::step(&p, &tail, ti, &mut carry);
    }
    let max_step_diff = y_last
        .iter()
        .zip(&seq.y[(t - 1) * d..])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("[1b] prefix({half}) + step()x{} reproduces the full scan: \
              max |diff| {max_step_diff:.2e}; mean posterior variance \
              {:.4}", tail.t, posterior.mean_variance());

    // ---- 2. artifact forward ----
    let rt = Runtime::discover()?;
    let session = TrainSession::new(&rt, "mad_kla")?;
    let (b, tt) = session.batch_shape();
    let tokens = kla::tensor::IntTensor::zeros(&[b, tt]);
    let timer = Timer::start();
    let out = session.run_role(&rt, "logits", &[Value::I32(tokens)])?;
    println!("[2] XLA artifact mad_kla_logits: output {:?} in {:.1} ms",
             out[0].shape(), timer.elapsed_ms());

    // ---- 3. a short training run ----
    let task = task_by_name("selective_copy").unwrap();
    let mut session = session;
    let mut data_rng = Pcg64::seeded(1);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..20 {
        let batch = task.batch(&mut data_rng, b, tt);
        let loss = session.train_step(&batch)?;
        if step == 0 {
            first = loss;
        }
        last = loss;
    }
    println!("[3] 20 train steps on selective_copy: loss {first:.3} -> \
              {last:.3}");

    // ---- 4. posterior variance ----
    let batch = task.batch(&mut data_rng, b, tt);
    let out = session.run_role(&rt, "variance",
                               &[Value::I32(batch.tokens.clone())])?;
    let var = out[0].as_f32()?;
    let early: f32 = (0..10).map(|i| var.get(&[0, i])).sum::<f32>() / 10.0;
    let late: f32 =
        (tt - 10..tt).map(|i| var.get(&[0, i])).sum::<f32>() / 10.0;
    println!("[4] posterior readout variance: early {early:.4} -> late \
              {late:.4} (evidence accumulates, paper Fig. 5b)");
    Ok(())
}

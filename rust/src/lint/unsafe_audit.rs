//! Pass `unsafe`: every `unsafe` carries a `// SAFETY:` comment.
//!
//! The repo has very little `unsafe` (the thread pool's lifetime
//! erasure, the baselines' disjoint-write pointer) and each occurrence
//! must say *why* it is sound, next to the code: a comment containing
//! `SAFETY:` on the same line or within the six lines above (so a
//! multi-line safety argument directly over the block counts).  This
//! covers `unsafe` blocks, `unsafe fn`, and `unsafe impl` alike —
//! `unsafe impl Send/Sync` is a soundness claim about aliasing and
//! needs the argument most of all.

use super::{Finding, LintInput, SourceFile};

/// How many lines above an `unsafe` token a `SAFETY:` comment may
/// start and still count as attached to it.
const SAFETY_WINDOW: usize = 6;

pub fn run(input: &LintInput) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &input.files {
        check_file(file, &mut out);
    }
    out
}

fn check_file(file: &SourceFile, out: &mut Vec<Finding>) {
    let safety_lines: Vec<usize> = file
        .toks
        .iter()
        .filter(|t| {
            t.comment_text().is_some_and(|c| c.contains("SAFETY:"))
        })
        .map(|t| t.line)
        .collect();
    for t in &file.code {
        if t.ident() != Some("unsafe") {
            continue;
        }
        let covered = safety_lines.iter().any(|&sl| {
            sl <= t.line && t.line - sl <= SAFETY_WINDOW
        });
        if !covered {
            out.push(Finding {
                pass: "unsafe",
                file: file.path.clone(),
                line: t.line,
                message: format!(
                    "`unsafe` without a `// SAFETY:` comment on the \
                     same line or the {SAFETY_WINDOW} lines above it; \
                     state the soundness argument"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{LintInput, SourceFile};

    fn input(src: &str) -> LintInput {
        LintInput {
            files: vec![SourceFile::from_source(
                "rust/src/util/thread_pool.rs",
                src,
            )],
            design_md: String::new(),
        }
    }

    #[test]
    fn fixture_fires_on_each_undocumented_unsafe() {
        let src = include_str!("fixtures/unsafe_bad.rs");
        let fs = run(&input(src));
        // one per `unsafe`: the block AND both unsafe impls
        assert_eq!(fs.len(), 3, "{fs:?}");
        assert!(fs.iter().all(|f| f.pass == "unsafe"));
    }

    #[test]
    fn fixture_with_safety_comments_is_clean() {
        let src = include_str!("fixtures/unsafe_ok.rs");
        let fs = run(&input(src));
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_waiver_suppresses_without_safety_comment() {
        let src = include_str!("fixtures/unsafe_waived.rs");
        let report = crate::lint::run(&input(src));
        let left: Vec<_> = report
            .findings
            .iter()
            .filter(|f| f.pass == "unsafe")
            .collect();
        assert!(left.is_empty(), "waived fixture not clean: {left:?}");
        let s = report
            .summaries
            .iter()
            .find(|s| s.pass == "unsafe")
            .unwrap_or_else(|| panic!("no unsafe summary"));
        assert_eq!(s.waivers_used, 1);
    }

    #[test]
    fn safety_comment_too_far_above_does_not_count() {
        let src = "\
// SAFETY: this argument is stranded eight lines up\n\
//\n\
//\n\
//\n\
//\n\
//\n\
//\n\
//\n\
fn f(p: *const i32) -> i32 {\n\
    unsafe { *p }\n\
}\n";
        let fs = run(&input(src));
        assert_eq!(fs.len(), 1, "{fs:?}");
    }

    #[test]
    fn same_line_safety_comment_counts() {
        let src = "\
fn f(p: *const i32) -> i32 {\n\
    unsafe { *p } // SAFETY: caller passes a valid pointer\n\
}\n";
        assert!(run(&input(src)).is_empty());
    }
}

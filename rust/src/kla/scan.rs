//! Native KLA information filter: sequential, Blelloch-parallel, and
//! chunked multi-threaded scans over a (T, N, D) state grid.  The time
//! axis is the only one chunked here; the lane (slot) axis of a batched
//! round is parallelised one level up — `api::prefix_batch` /
//! `NativeLm::prefill_ragged` chain whole lanes across the shared
//! `util::thread_pool`, each lane running these sequential kernels
//! unchanged (which is what keeps multi-lane rounds bit-exact against
//! single-lane scans).
//!
//! This is the L3-side mirror of the L1 kernels — used by the Fig. 4
//! compute-scaling study (recurrent vs scan on CPU cores), by the property
//! tests, and cross-validated against the Python oracle via pinned
//! test vectors (`integration_cross_validation.rs`).
//!
//! Data layout: time-major contiguous rows of S = N*D channels, i.e.
//! `k[t*N + n]`, `v[t*D + d]`, `lam[t*S + n*D + d]` — matching the (B=1)
//! slices of the Python implementation.
//!
//! These are the low-level strategy implementations behind the unified
//! [`crate::api::Filter`] abstraction; external callers should go through
//! `kla::api` (`KlaFilter` + `ScanPlan`) rather than calling the
//! `filter_*` free functions directly.  The `*_from` variants take an
//! explicit prior belief `(lam_init, eta_init)` so a scan can resume from
//! any carried posterior — the same carry type decode-time `step()` and
//! the serving belief cache use.

use crate::kla::mobius::Mobius64;
use crate::util::prefix::blelloch_inclusive;

pub const LAM_MIN: f32 = 1e-6;
pub const LAM_MAX: f32 = 1e8;

/// The single clamp applied to posterior precision everywhere — the
/// sequential, Blelloch, and chunked paths (including chunk carries, via
/// [`clamp_lam64`]) all funnel through this pair of helpers so the
/// numerical guard rails cannot drift apart between strategies.
#[inline]
pub fn clamp_lam(lam: f32) -> f32 {
    lam.clamp(LAM_MIN, LAM_MAX)
}

/// f64 twin of [`clamp_lam`], for the high-precision carry path.
#[inline]
pub fn clamp_lam64(lam: f64) -> f64 {
    lam.clamp(LAM_MIN as f64, LAM_MAX as f64)
}

/// Per-(N,D)-grid filter parameters.
#[derive(Clone, Debug)]
pub struct FilterParams {
    pub n: usize,
    pub d: usize,
    pub abar: Vec<f32>, // (N*D)
    pub pbar: Vec<f32>, // (N*D)
    pub lam0: Vec<f32>, // (N*D)
    pub eta0: Vec<f32>, // (N*D)
}

impl FilterParams {
    pub fn uniform(n: usize, d: usize, abar: f32, pbar: f32) -> Self {
        FilterParams {
            n,
            d,
            abar: vec![abar; n * d],
            pbar: vec![pbar; n * d],
            lam0: vec![1.0; n * d],
            eta0: vec![0.0; n * d],
        }
    }

    pub fn state(&self) -> usize {
        self.n * self.d
    }
}

/// Filter inputs for one sequence: k (T,N), q (T,N), v (T,D), lam_v (T,D).
#[derive(Clone, Debug)]
pub struct FilterInputs {
    pub t: usize,
    pub k: Vec<f32>,
    pub q: Vec<f32>,
    pub v: Vec<f32>,
    pub lam_v: Vec<f32>,
}

impl FilterInputs {
    /// Time-slice `[lo, hi)` — used by `kla::api` for carry-split
    /// execution (run a prefix of the sequence, carry the belief, resume).
    pub fn slice(&self, lo: usize, hi: usize) -> FilterInputs {
        assert!(lo <= hi && hi <= self.t, "slice [{lo}, {hi}) of t={}",
                self.t);
        if self.t == 0 {
            return FilterInputs {
                t: 0,
                k: Vec::new(),
                q: Vec::new(),
                v: Vec::new(),
                lam_v: Vec::new(),
            };
        }
        let n = self.k.len() / self.t;
        let d = self.v.len() / self.t;
        FilterInputs {
            t: hi - lo,
            k: self.k[lo * n..hi * n].to_vec(),
            q: self.q[lo * n..hi * n].to_vec(),
            v: self.v[lo * d..hi * d].to_vec(),
            lam_v: self.lam_v[lo * d..hi * d].to_vec(),
        }
    }
}

/// Filter outputs: lam, eta (T, N, D) and readout y (T, D).
#[derive(Clone, Debug, PartialEq)]
pub struct FilterOutputs {
    pub lam: Vec<f32>,
    pub eta: Vec<f32>,
    pub y: Vec<f32>,
}

/// One channel's token update — the single source of the KLA recursion
/// used by every strategy (sequential loop, chunked replay, incremental
/// `step()`), so the strategies stay bit-identical where they share the
/// same carry.  `k2` must be `k * k` (hoisted by the caller, which knows
/// it is constant across the D inner iterations).  Returns
/// `(lam, eta, gate)` with `gate = rho * abar`.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn kla_update(abar: f32, pbar: f32, k: f32, k2: f32,
                         lam_v: f32, v: f32, lam_prev: f32,
                         eta_prev: f32) -> (f32, f32, f32) {
    let rho = 1.0 / (abar * abar + pbar * lam_prev);
    let gate = rho * abar;
    let lam = clamp_lam(rho * lam_prev + k2 * lam_v);
    let eta = gate * eta_prev + k * lam_v * v;
    (lam, eta, gate)
}

#[inline]
fn readout(p: &FilterParams, inp: &FilterInputs, lam: &[f32], eta: &[f32],
           y: &mut [f32]) {
    let (n, d, s) = (p.n, p.d, p.state());
    for t in 0..inp.t {
        let (lam_t, eta_t) = (&lam[t * s..(t + 1) * s], &eta[t * s..(t + 1) * s]);
        let y_t = &mut y[t * d..(t + 1) * d];
        for ni in 0..n {
            let qn = inp.q[t * n + ni];
            if qn == 0.0 {
                continue;
            }
            let row = ni * d;
            for di in 0..d {
                y_t[di] += qn * eta_t[row + di] / lam_t[row + di];
            }
        }
    }
}

/// One incremental filter update: advance the belief `(lam, eta)` through
/// step `t` of `inp` in place and return the readout row y_t (D values).
/// Chaining this over t reproduces `filter_sequential_from` bit-for-bit —
/// the decode-time face of the same recursion.
pub(crate) fn step_once(p: &FilterParams, inp: &FilterInputs, t: usize,
                        lam: &mut [f32], eta: &mut [f32]) -> Vec<f32> {
    let (n, d) = (p.n, p.d);
    debug_assert!(t < inp.t);
    debug_assert_eq!(lam.len(), p.state());
    let k_t = &inp.k[t * n..(t + 1) * n];
    let v_t = &inp.v[t * d..(t + 1) * d];
    let lv_t = &inp.lam_v[t * d..(t + 1) * d];
    for ni in 0..n {
        let kk = k_t[ni];
        let k2 = kk * kk;
        let row = ni * d;
        for di in 0..d {
            let idx = row + di;
            let (l, e, _) = kla_update(p.abar[idx], p.pbar[idx], kk, k2,
                                       lv_t[di], v_t[di], lam[idx],
                                       eta[idx]);
            lam[idx] = l;
            eta[idx] = e;
        }
    }
    // readout row — same accumulation order as `readout` above
    let mut y = vec![0.0f32; d];
    for ni in 0..n {
        let qn = inp.q[t * n + ni];
        if qn == 0.0 {
            continue;
        }
        let row = ni * d;
        for di in 0..d {
            y[di] += qn * eta[row + di] / lam[row + di];
        }
    }
    y
}

/// The naive recurrent (time-stepped) Kalman update — the Fig. 4 baseline.
/// O(T) sequential steps, each O(N*D).  Starts from the explicit belief
/// `(lam_init, eta_init)`.
pub fn filter_sequential_from(p: &FilterParams, inp: &FilterInputs,
                              lam_init: &[f32], eta_init: &[f32])
                              -> FilterOutputs {
    let (n, d, s, t_len) = (p.n, p.d, p.state(), inp.t);
    let mut lam = vec![0.0f32; t_len * s];
    let mut eta = vec![0.0f32; t_len * s];
    let mut lam_prev = lam_init.to_vec();
    let mut eta_prev = eta_init.to_vec();
    for t in 0..t_len {
        let k_t = &inp.k[t * n..(t + 1) * n];
        let v_t = &inp.v[t * d..(t + 1) * d];
        let lv_t = &inp.lam_v[t * d..(t + 1) * d];
        for ni in 0..n {
            let kk = k_t[ni];
            let k2 = kk * kk;
            let row = ni * d;
            for di in 0..d {
                let idx = row + di;
                let (l, e, _) = kla_update(p.abar[idx], p.pbar[idx], kk,
                                           k2, lv_t[di], v_t[di],
                                           lam_prev[idx], eta_prev[idx]);
                lam[t * s + idx] = l;
                eta[t * s + idx] = e;
                lam_prev[idx] = l;
                eta_prev[idx] = e;
            }
        }
    }
    let mut y = vec![0.0f32; t_len * d];
    readout(p, inp, &lam, &eta, &mut y);
    FilterOutputs { lam, eta, y }
}

/// `filter_sequential_from` starting at the learned prior (lam0, eta0).
pub fn filter_sequential(p: &FilterParams, inp: &FilterInputs)
                         -> FilterOutputs {
    filter_sequential_from(p, inp, &p.lam0, &p.eta0)
}

/// Work-efficient parallel form: two associative prefix scans
/// (Moebius for lam, affine for eta), single-threaded.  Exposes the same
/// O(T) work / O(log T) depth structure as the L1 kernel; `filter_chunked`
/// adds the multi-core execution.
pub fn filter_scan(p: &FilterParams, inp: &FilterInputs) -> FilterOutputs {
    filter_chunked(p, inp, 1)
}

/// Blelloch tree scan (the paper's "parallel scan" reference shape): per
/// channel, an up-sweep/down-sweep over the f64 Moebius maps yields every
/// precision prefix in O(log T) depth; the gates recovered from the lam
/// trajectory then drive a second tree scan over affine (F, B) pairs for
/// eta.  Single-threaded here — the point is the dependency structure, not
/// the core count (that is `filter_chunked`'s job).
///
/// The composed maps are unclamped (clamping is not associative); lam is
/// clamped only when materialised.  Like the L1 kernels, this strategy
/// therefore assumes the `[LAM_MIN, LAM_MAX]` guard rails do not bind
/// mid-sequence — see the conformance caveat on `crate::api::Filter`.
pub fn filter_blelloch_from(p: &FilterParams, inp: &FilterInputs,
                            lam_init: &[f32], eta_init: &[f32])
                            -> FilterOutputs {
    let (n, d, s, t_len) = (p.n, p.d, p.state(), inp.t);
    if t_len == 0 {
        return FilterOutputs { lam: vec![], eta: vec![], y: vec![] };
    }
    let mut lam = vec![0.0f32; t_len * s];
    let mut eta = vec![0.0f32; t_len * s];
    let mut mob: Vec<Mobius64> = Vec::with_capacity(t_len);
    let mut aff: Vec<(f64, f64)> = Vec::with_capacity(t_len);
    for ni in 0..n {
        for di in 0..d {
            let idx = ni * d + di;
            let (abar, pbar) = (p.abar[idx] as f64, p.pbar[idx] as f64);
            // pass A: precision prefixes via the Moebius tree
            mob.clear();
            for t in 0..t_len {
                let k = inp.k[t * n + ni] as f64;
                let lv = inp.lam_v[t * d + di] as f64;
                mob.push(Mobius64::kla_step(abar, pbar, k * k * lv));
            }
            blelloch_inclusive(&mut mob, |earlier, later| {
                later.compose(earlier)
            });
            let l0 = lam_init[idx] as f64;
            for t in 0..t_len {
                lam[t * s + idx] =
                    clamp_lam64(mob[t].apply(l0)) as f32;
            }
            // pass B: gates from lam[t-1], then the affine tree for eta
            aff.clear();
            for t in 0..t_len {
                let lam_prev_f32 = if t == 0 {
                    lam_init[idx]
                } else {
                    lam[(t - 1) * s + idx]
                };
                let lam_prev = lam_prev_f32 as f64;
                let rho = 1.0 / (abar * abar + pbar * lam_prev);
                let k = inp.k[t * n + ni] as f64;
                let lv = inp.lam_v[t * d + di] as f64;
                let v = inp.v[t * d + di] as f64;
                aff.push((rho * abar, k * lv * v));
            }
            blelloch_inclusive(&mut aff, |earlier, later| {
                (later.0 * earlier.0, later.0 * earlier.1 + later.1)
            });
            let e0 = eta_init[idx] as f64;
            for t in 0..t_len {
                let (fp, bp) = aff[t];
                eta[t * s + idx] = (fp * e0 + bp) as f32;
            }
        }
    }
    let mut y = vec![0.0f32; t_len * d];
    readout(p, inp, &lam, &eta, &mut y);
    FilterOutputs { lam, eta, y }
}

/// Chunked two-level scan over `threads` cores (the CUDA-kernel analogue
/// from DESIGN.md §4), starting at the learned prior.
pub fn filter_chunked(p: &FilterParams, inp: &FilterInputs, threads: usize)
                      -> FilterOutputs {
    filter_chunked_from(p, inp, threads, &p.lam0, &p.eta0)
}

/// Chunked two-level scan from an explicit belief.  Three passes, all
/// O(T·S):
///   1. (parallel) per-chunk Moebius composition in f64 -> chunk precision
///      maps (f64 keeps cross-chunk carries accurate far below the 1e-5
///      strategy-conformance tolerance);
///   2. (serial, cheap) chunk carries for lam and, later, eta;
///   3. (parallel, fused) per-chunk replay producing lam, a zero-carry
///      eta_partial AND the running gate-prefix G; a final light fixup adds
///      G[t] * eta_carry so eta needs no second heavy scan.
/// Exact (Moebius maps compose associatively); matches
/// `filter_sequential_from` to f32 roundoff.
pub fn filter_chunked_from(p: &FilterParams, inp: &FilterInputs,
                           threads: usize, lam_init: &[f32],
                           eta_init: &[f32]) -> FilterOutputs {
    let (n, d, s, t_len) = (p.n, p.d, p.state(), inp.t);
    if t_len == 0 {
        return FilterOutputs { lam: vec![], eta: vec![], y: vec![] };
    }
    let threads = threads.clamp(1, t_len);
    let chunk_len = t_len.div_ceil(threads);
    let n_chunks = t_len.div_ceil(chunk_len); // may be < threads

    if n_chunks == 1 {
        return filter_sequential_from(p, inp, lam_init, eta_init);
    }
    let dbg = std::env::var("KLA_SCAN_DEBUG").is_ok();
    let t0 = std::time::Instant::now(); // lint: allow(determinism, env-gated debug meter; timing never affects results)

    // ---- Pass 1 (parallel): per-chunk Moebius composition (f64) ----
    let mut summaries: Vec<Vec<Mobius64>> = vec![Vec::new(); n_chunks];
    parallel_chunk_exec(&mut summaries[..], |c, out| {
        let start = c * chunk_len;
        let end = ((c + 1) * chunk_len).min(t_len);
        let mut mob = vec![Mobius64::IDENTITY; s];
        for t in start..end {
            let k_t = &inp.k[t * n..(t + 1) * n];
            let lv_t = &inp.lam_v[t * d..(t + 1) * d];
            for ni in 0..n {
                let k2 = (k_t[ni] as f64) * (k_t[ni] as f64);
                let row = ni * d;
                for di in 0..d {
                    let idx = row + di;
                    let m = Mobius64::kla_step(p.abar[idx] as f64,
                                               p.pbar[idx] as f64,
                                               k2 * lv_t[di] as f64);
                    mob[idx] = m.compose(&mob[idx]);
                }
            }
        }
        *out = mob;
    });

    if dbg { eprintln!("pass1 compose: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    let t0 = std::time::Instant::now(); // lint: allow(determinism, env-gated debug meter; timing never affects results)
    // ---- Pass 2a (serial, cheap): lam carries (f64 chain) ----
    let carry0: Vec<f64> = lam_init.iter().map(|&x| x as f64).collect();
    let mut carry_lam = vec![carry0];
    for c in 0..n_chunks - 1 {
        let prev = carry_lam.last().unwrap();
        let mut next = vec![0.0f64; s];
        for idx in 0..s {
            next[idx] = clamp_lam64(summaries[c][idx].apply(prev[idx]));
        }
        carry_lam.push(next);
    }

    if dbg { eprintln!("pass2a carries: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    let t0 = std::time::Instant::now(); // lint: allow(determinism, env-gated debug meter; timing never affects results)
    // ---- Pass 3 (parallel, fused): replay lam + eta_partial + gates ----
    let mut lam = vec![0.0f32; t_len * s];
    let mut eta = vec![0.0f32; t_len * s];     // zero-carry partial for now
    let mut gates = vec![0.0f32; t_len * s];   // prefix gate products G[t]
    let mut chunk_fb: Vec<(Vec<f32>, Vec<f32>)> =
        vec![(Vec::new(), Vec::new()); n_chunks];
    {
        let mut parts: Vec<(usize, &mut [f32], &mut [f32], &mut [f32],
                            &mut (Vec<f32>, Vec<f32>))> = Vec::new();
        let (mut lr, mut er, mut gr) =
            (&mut lam[..], &mut eta[..], &mut gates[..]);
        let mut fb_rest = &mut chunk_fb[..];
        for c in 0..n_chunks {
            let start = c * chunk_len;
            let end = ((c + 1) * chunk_len).min(t_len);
            let take = (end - start) * s;
            let (lh, lt) = lr.split_at_mut(take);
            let (eh, et) = er.split_at_mut(take);
            let (gh, gt) = gr.split_at_mut(take);
            let (fbh, fbt) = fb_rest.split_at_mut(1);
            parts.push((c, lh, eh, gh, &mut fbh[0]));
            lr = lt;
            er = et;
            gr = gt;
            fb_rest = fbt;
        }
        std::thread::scope(|scope| {
            for (c, lam_out, eta_out, g_out, fb) in parts {
                let lam_carry: Vec<f32> = carry_lam[c]
                    .iter()
                    .map(|&x| clamp_lam(x as f32))
                    .collect();
                scope.spawn(move || {
                    let start = c * chunk_len;
                    let end = ((c + 1) * chunk_len).min(t_len);
                    let mut cur_l = lam_carry;
                    let mut cur_e = vec![0.0f32; s]; // zero-carry partial
                    let mut cur_g = vec![1.0f32; s];
                    for (ti, t) in (start..end).enumerate() {
                        let k_t = &inp.k[t * n..(t + 1) * n];
                        let v_t = &inp.v[t * d..(t + 1) * d];
                        let lv_t = &inp.lam_v[t * d..(t + 1) * d];
                        let row_out = ti * s;
                        for ni in 0..n {
                            let kk = k_t[ni];
                            let k2 = kk * kk;
                            let row = ni * d;
                            for di in 0..d {
                                let idx = row + di;
                                let (l, e, gate) =
                                    kla_update(p.abar[idx], p.pbar[idx],
                                               kk, k2, lv_t[di], v_t[di],
                                               cur_l[idx], cur_e[idx]);
                                // prefix gate products decay geometrically;
                                // flush to zero before they go DENORMAL
                                // (denormal multiplies are ~100x slower,
                                // and the fixup contribution is ~0 anyway)
                                let mut g = gate * cur_g[idx];
                                if g < 1e-30 {
                                    g = 0.0;
                                }
                                lam_out[row_out + idx] = l;
                                eta_out[row_out + idx] = e;
                                g_out[row_out + idx] = g;
                                cur_l[idx] = l;
                                cur_e[idx] = e;
                                cur_g[idx] = g;
                            }
                        }
                    }
                    *fb = (cur_g, cur_e);
                });
            }
        });
    }

    if dbg { eprintln!("pass3 replay: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    let t0 = std::time::Instant::now(); // lint: allow(determinism, env-gated debug meter; timing never affects results)
    // ---- Pass 2b (serial, cheap): eta carries from (F, B) ----
    let mut carry_eta = vec![eta_init.to_vec()];
    for c in 0..n_chunks - 1 {
        let prev = carry_eta.last().unwrap();
        let (f_c, b_c) = &chunk_fb[c];
        let mut next = vec![0.0f32; s];
        for idx in 0..s {
            next[idx] = f_c[idx] * prev[idx] + b_c[idx];
        }
        carry_eta.push(next);
    }

    // ---- Pass 4 (parallel, light): eta fixup with gate prefixes ----
    {
        let mut parts: Vec<(usize, &mut [f32], &[f32])> = Vec::new();
        let mut er = &mut eta[..];
        let mut gr = &gates[..];
        for c in 0..n_chunks {
            let start = c * chunk_len;
            let end = ((c + 1) * chunk_len).min(t_len);
            let take = (end - start) * s;
            let (eh, et) = er.split_at_mut(take);
            let (gh, gt) = gr.split_at(take);
            parts.push((c, eh, gh));
            er = et;
            gr = gt;
        }
        std::thread::scope(|scope| {
            for (c, eta_out, g_in) in parts {
                let carry = carry_eta[c].clone();
                scope.spawn(move || {
                    if carry.iter().all(|&x| x == 0.0) {
                        return; // first chunk (or zero prior): no fixup
                    }
                    let rows = eta_out.len() / s;
                    for ti in 0..rows {
                        let off = ti * s;
                        for idx in 0..s {
                            eta_out[off + idx] +=
                                g_in[off + idx] * carry[idx];
                        }
                    }
                });
            }
        });
    }

    if dbg { eprintln!("pass2b+4 eta: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    let t0 = std::time::Instant::now(); // lint: allow(determinism, env-gated debug meter; timing never affects results)
    let mut y = vec![0.0f32; t_len * d];
    readout(p, inp, &lam, &eta, &mut y);
    if dbg { eprintln!("readout: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    FilterOutputs { lam, eta, y }
}

/// Run `f(c, &mut out[c])` for each element on its own scoped thread.
fn parallel_chunk_exec<T: Send, F>(out: &mut [T], f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    std::thread::scope(|scope| {
        for (c, slot) in out.iter_mut().enumerate() {
            let f = &f;
            scope.spawn(move || f(c, slot));
        }
    });
}

/// Convenience: random filter inputs for tests/benches.
pub fn random_inputs(rng: &mut crate::util::Pcg64, t: usize, n: usize,
                     d: usize) -> FilterInputs {
    FilterInputs {
        t,
        k: (0..t * n).map(|_| rng.normal_f32()).collect(),
        q: (0..t * n).map(|_| rng.normal_f32()).collect(),
        v: (0..t * d).map(|_| rng.normal_f32()).collect(),
        lam_v: (0..t * d).map(|_| rng.range_f32(0.1, 2.0)).collect(),
    }
}

pub fn random_params(rng: &mut crate::util::Pcg64, n: usize, d: usize)
                     -> FilterParams {
    FilterParams {
        n,
        d,
        abar: (0..n * d).map(|_| rng.range_f32(0.7, 0.999)).collect(),
        pbar: (0..n * d).map(|_| rng.range_f32(1e-3, 0.2)).collect(),
        lam0: (0..n * d).map(|_| rng.range_f32(0.5, 2.0)).collect(),
        eta0: (0..n * d).map(|_| rng.normal_f32() * 0.1).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
        if a.len() != b.len() {
            return Err(format!("len {} vs {}", a.len(), b.len()));
        }
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
                return Err(format!("idx {i}: {x} vs {y}"));
            }
        }
        Ok(())
    }

    #[test]
    fn chunked_matches_sequential_various_threads() {
        let mut rng = Pcg64::seeded(1);
        for &(t, n, d) in &[(1, 1, 1), (7, 2, 3), (64, 4, 8), (129, 3, 5)] {
            let p = random_params(&mut rng, n, d);
            let inp = random_inputs(&mut rng, t, n, d);
            let seq = filter_sequential(&p, &inp);
            for threads in [1, 2, 4, 7] {
                let par = filter_chunked(&p, &inp, threads);
                close(&par.lam, &seq.lam, 1e-4)
                    .unwrap_or_else(|e| panic!("lam t={t} th={threads}: {e}"));
                close(&par.eta, &seq.eta, 1e-4)
                    .unwrap_or_else(|e| panic!("eta t={t} th={threads}: {e}"));
                close(&par.y, &seq.y, 1e-3)
                    .unwrap_or_else(|e| panic!("y t={t} th={threads}: {e}"));
            }
        }
    }

    #[test]
    fn blelloch_matches_sequential() {
        let mut rng = Pcg64::seeded(4);
        for &(t, n, d) in &[(1, 1, 1), (7, 2, 3), (64, 4, 8), (129, 3, 5)] {
            let p = random_params(&mut rng, n, d);
            let inp = random_inputs(&mut rng, t, n, d);
            let seq = filter_sequential(&p, &inp);
            let par = filter_blelloch_from(&p, &inp, &p.lam0, &p.eta0);
            close(&par.lam, &seq.lam, 1e-4)
                .unwrap_or_else(|e| panic!("lam t={t}: {e}"));
            close(&par.eta, &seq.eta, 1e-4)
                .unwrap_or_else(|e| panic!("eta t={t}: {e}"));
            close(&par.y, &seq.y, 1e-3)
                .unwrap_or_else(|e| panic!("y t={t}: {e}"));
        }
    }

    #[test]
    fn explicit_belief_resumes_mid_sequence() {
        // prefix [0, c) then [c, T) from the carried belief must equal the
        // full scan bit-for-bit on the sequential path.
        let mut rng = Pcg64::seeded(5);
        let (t, n, d) = (37, 2, 3);
        let s = n * d;
        let p = random_params(&mut rng, n, d);
        let inp = random_inputs(&mut rng, t, n, d);
        let full = filter_sequential(&p, &inp);
        for &c in &[1usize, 7, 18, 36] {
            let head = inp.slice(0, c);
            let tail = inp.slice(c, t);
            let out_head = filter_sequential(&p, &head);
            let lam_carry = &out_head.lam[(c - 1) * s..];
            let eta_carry = &out_head.eta[(c - 1) * s..];
            let out_tail =
                filter_sequential_from(&p, &tail, lam_carry, eta_carry);
            assert_eq!(&full.lam[c * s..], &out_tail.lam[..],
                       "lam split at {c}");
            assert_eq!(&full.eta[c * s..], &out_tail.eta[..],
                       "eta split at {c}");
            assert_eq!(&full.y[c * d..], &out_tail.y[..], "y split at {c}");
        }
    }

    #[test]
    fn step_once_chain_matches_sequential_exactly() {
        let mut rng = Pcg64::seeded(6);
        let (t, n, d) = (23, 3, 4);
        let s = n * d;
        let p = random_params(&mut rng, n, d);
        let inp = random_inputs(&mut rng, t, n, d);
        let full = filter_sequential(&p, &inp);
        let mut lam = p.lam0.clone();
        let mut eta = p.eta0.clone();
        for ti in 0..t {
            let y = step_once(&p, &inp, ti, &mut lam, &mut eta);
            assert_eq!(&full.lam[ti * s..(ti + 1) * s], &lam[..]);
            assert_eq!(&full.eta[ti * s..(ti + 1) * s], &eta[..]);
            assert_eq!(&full.y[ti * d..(ti + 1) * d], &y[..]);
        }
    }

    #[test]
    fn zero_noise_linear_case() {
        let mut rng = Pcg64::seeded(2);
        let mut p = random_params(&mut rng, 2, 4);
        p.pbar.iter_mut().for_each(|x| *x = 0.0);
        let inp = random_inputs(&mut rng, 48, 2, 4);
        let seq = filter_sequential(&p, &inp);
        let par = filter_chunked(&p, &inp, 4);
        close(&par.lam, &seq.lam, 1e-4).unwrap();
        close(&par.eta, &seq.eta, 1e-4).unwrap();
    }

    #[test]
    fn precision_monotone_without_forgetting() {
        // abar = 1, pbar = 0: precision accumulates monotonically
        let n = 1;
        let d = 1;
        let p = FilterParams {
            n, d,
            abar: vec![1.0],
            pbar: vec![0.0],
            lam0: vec![1.0],
            eta0: vec![0.0],
        };
        let mut rng = Pcg64::seeded(3);
        let inp = random_inputs(&mut rng, 32, n, d);
        let out = filter_sequential(&p, &inp);
        for t in 1..32 {
            assert!(out.lam[t] >= out.lam[t - 1] - 1e-6);
        }
    }

    #[test]
    fn empty_sequence() {
        let p = FilterParams::uniform(2, 2, 0.9, 0.01);
        let inp = FilterInputs { t: 0, k: vec![], q: vec![], v: vec![],
                                 lam_v: vec![] };
        let out = filter_chunked(&p, &inp, 4);
        assert!(out.lam.is_empty() && out.y.is_empty());
        let out = filter_blelloch_from(&p, &inp, &p.lam0, &p.eta0);
        assert!(out.lam.is_empty() && out.y.is_empty());
    }

    #[test]
    fn clamp_helpers_agree() {
        for &x in &[-1.0f32, 0.0, 1e-9, 0.5, 1e9] {
            assert_eq!(clamp_lam(x), clamp_lam64(x as f64) as f32);
        }
    }
}

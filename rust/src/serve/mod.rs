//! Serving runtime (DESIGN.md §S15): a request router + continuous batcher
//! + belief-state cache manager over the O(1) recurrent decode artifact.
//!
//! Architecture (vLLM-router-shaped, adapted to constant-size state):
//!
//!   TCP conns ──> router threads ──mpsc──> engine thread ──> PJRT decode
//!                                             │
//!                                   BeliefStateCache (slot pool,
//!                                   reset / snapshot / restore)
//!
//! Because a KLA sequence's state never grows, scheduling has no memory
//! watermark: admission is purely slot-bound and prefill/decode unify into
//! one recurrent step per token (batcher.rs).

pub mod batcher;
pub mod engine;
pub mod server;
pub mod state_cache;

pub use batcher::{Feed, SchedRequest, Scheduler};
pub use engine::{EngineRequest, EngineResponse, EngineStats};
pub use server::{serve, Client, ServerHandle};
pub use state_cache::BeliefStateCache;

//! Serving runtime (DESIGN.md §S15/§S17): a request router + continuous
//! batcher + belief-state cache manager over an O(1) recurrent decode
//! backend.
//!
//! Architecture (vLLM-router-shaped, adapted to constant-size state):
//!
//!   TCP conns ──> router threads ──mpsc──> engine thread ──> DecodeBackend
//!                                             │              (native | xla)
//!                                   BeliefStateCache (slot pool,
//!                                   reset / snapshot / restore)
//!
//! The engine is generic over `runtime::backend::DecodeBackend`: the
//! pure-Rust `NativeBackend` runs (and is integration-tested) with no
//! artifacts at all, while the XLA artifact session plugs into the same
//! seam in production.  Because a KLA sequence's state never grows,
//! scheduling has no memory watermark: admission is purely slot-bound.
//! Prompt prefill is scan-based, chunked, and FUSED across slots: each
//! engine iteration gathers up to `ServeConfig::prefill_chunk` tokens
//! from EVERY mid-prefill slot and hands the whole ragged (slots ×
//! time) round to one `DecodeBackend::prefill_batch` call — on the
//! native backend a single multi-dimensional scan that chains lanes
//! across the shared `util::thread_pool` (each lane sequential, so
//! fused ≡ per-slot ≡ token-by-token, bit-exact), bounded so in-flight
//! decodes never stall longer than one chunk round per iteration.  The
//! round returns one `Result` per lane: a failing lane retires only its
//! own request (terminal `prefill-failed` event, slot reset and
//! released) while every other lane proceeds — per-slot fault
//! isolation, never an engine-fatal error.  Mid-prefill cursors stay on
//! the `prefill_chunk` grid (the scheduler idles those slots in the
//! shared batched step rather than drip-feeding them tokens), which is
//! what keeps block-aligned prefix-cache snapshot points reachable
//! after the first chunk.  At `prefill_chunk <= 1`, or on backends
//! without a parallel prefill (XLA), prompts fall back to one recurrent
//! step per token interleaved with decode (batcher.rs).

//! Per-request sampling & termination live in `sampling`: a composable
//! [`SamplerConfig`] (greedy | temperature | top-k | top-p, optional
//! uncertainty-scaled temperature over the slot's belief variance, stop
//! tokens) with counter-based RNG draws keyed per request, so sampled
//! outputs are deterministic regardless of batch composition, slot
//! assignment, or prefill chunking (greedy is the exact argmax special
//! case).
//!
//! The wire protocol is v2 (server.rs): multiplexed streaming sessions.
//! Every request carries a client-chosen `id`, replies are typed event
//! lines (`start` | `token` | `done` | `err`) serialised by a
//! per-connection writer thread, each `token` event carries the slot's
//! post-step posterior uncertainty, and requests are cancellable
//! mid-generation (`{"cmd":"cancel"}`, or implicitly by disconnecting —
//! the engine retires the slot and a queued request takes it over
//! within one iteration).  Streaming and cancellation are engine-side
//! ([`EngineEvent`] / [`EventSink`]), so every `DecodeBackend` inherits
//! them.
//!
//! Because the belief state is constant-size, prompt caching is nearly
//! free: `prefix_cache` keeps a content-addressed, LRU-evicted map from
//! token prefixes to per-slot snapshots (a few KB each — per-layer
//! `KlaBelief` + conv windows), keyed by a model fingerprint so a
//! snapshot can never restore into a mismatched model.  At admit, the
//! engine restores the longest cached prefix of the new prompt and
//! jumps the prefill cursor past it; a fleet of requests sharing a
//! system prompt prefills it exactly once (`--prefix-cache-mb`,
//! DESIGN.md §S15).

pub mod batcher;
pub mod engine;
pub mod prefix_cache;
pub mod sampling;
pub mod server;
pub mod state_cache;

pub use batcher::{Cancelled, Feed, PrefillView, SchedRequest, Scheduler};
pub use engine::{run_engine, run_engine_opts, EngineEvent, EngineOptions,
                 EngineRequest, EngineResponse, EngineStats, EventSink,
                 LiveStats, SinkClosed};
pub use prefix_cache::{ModelFingerprint, PrefixCache, PrefixCacheStats};
pub use sampling::SamplerConfig;
pub use server::{serve, serve_native, serve_with, Client, ClientStream,
                 EngineSpec, RequestOpts, ServerHandle, StreamEvent};
pub use state_cache::{BeliefStateCache, RestoreError, SlotSnapshot};

//! Property tests (via `testing::property`) for the content-addressed
//! belief-state prefix cache (`serve::prefix_cache`):
//!
//! 1. under random insert/lookup interleavings, `lookup` agrees with a
//!    brute-force longest-prefix reference over the accepted entries
//!    (offset AND which snapshot comes back) — checking the FNV keying,
//!    exact-token compare, and candidate-offset walk end to end;
//! 2. the byte budget is an invariant, never a target: after every
//!    operation `bytes() <= budget()`, the counters reconcile
//!    (`insertions - evictions == entries`), and an accepted insert is
//!    immediately findable (LRU never evicts the newest entry);
//! 3. a fingerprint differing in ANY single field never returns a hit,
//!    whatever the token overlap.

use kla::api::KlaBelief;
use kla::serve::state_cache::SlotSnapshot;
use kla::serve::{ModelFingerprint, PrefixCache};
use kla::testing::{property, Gen};

fn fp() -> ModelFingerprint {
    ModelFingerprint {
        vocab: 32,
        backend: "native",
        layers: 2,
        conv_window: 3,
        d_model: 4,
        n_state: 2,
        seed: 7,
    }
}

/// A 2-layer snapshot whose fill value identifies the entry: 24 conv
/// floats + 2 * (8 + 8) posterior floats = 224 payload bytes.
fn snap(tag: f32) -> SlotSnapshot {
    SlotSnapshot {
        conv: vec![tag; 2 * 3 * 4],
        beliefs: (0..2)
            .map(|_| KlaBelief::from_parts(vec![tag; 8], vec![tag; 8]))
            .collect(),
    }
}

/// The documented candidate-offset contract: the full usable prefix
/// first, then every block multiple strictly below it, descending.
fn ref_candidates(usable: usize, block: usize) -> Vec<usize> {
    let mut offs = Vec::new();
    if usable == 0 {
        return offs;
    }
    offs.push(usable);
    let mut m = (usable / block) * block;
    if m == usable {
        m = m.saturating_sub(block);
    }
    while m > 0 {
        offs.push(m);
        m = m.saturating_sub(block);
    }
    offs
}

/// Brute-force longest-prefix reference: the longest candidate offset
/// for which `model` holds an entry with exactly those tokens.
fn ref_lookup(model: &[(Vec<i32>, f32)], query: &[i32], usable: usize,
              block: usize) -> Option<(usize, f32)> {
    let usable = usable.min(query.len());
    for off in ref_candidates(usable, block) {
        if let Some((_, tag)) =
            model.iter().find(|(t, _)| t[..] == query[..off])
        {
            return Some((off, *tag));
        }
    }
    None
}

/// A query stream related to `base`: share a random-length prefix, then
/// diverge — the shape that exercises partial hits, not just full ones.
fn related_stream(g: &mut Gen, base: &[i32]) -> Vec<i32> {
    let keep = g.usize_in(0, base.len());
    let extra = g.usize_in(0, 8);
    let mut s: Vec<i32> = base[..keep].to_vec();
    for _ in 0..extra {
        s.push(g.usize_in(0, 5) as i32);
    }
    s
}

#[test]
fn prefix_cache_lookup_matches_longest_prefix_reference() {
    property("prefix_cache_reference", 40, |g: &mut Gen| {
        let block = g.usize_in(1, 5);
        // budget far above anything 30 ops can insert: no eviction, so
        // the reference model and the cache hold the same entry set
        let mut pc = PrefixCache::new(block, 1 << 20);
        let base: Vec<i32> = (0..g.usize_in(8, 24))
            .map(|_| g.usize_in(0, 5) as i32)
            .collect();
        let mut model: Vec<(Vec<i32>, f32)> = Vec::new();
        let mut next_tag = 1.0f32;
        let mut lookups = 0usize;

        for op in 0..30 {
            let stream = related_stream(g, &base);
            if g.usize_in(0, 2) < 2 {
                // insert a random-length prefix of the stream
                let cut = g.usize_in(0, stream.len());
                let toks = &stream[..cut];
                let dup = model.iter().any(|(t, _)| t[..] == *toks);
                let stored = pc.insert(&fp(), toks, snap(next_tag));
                kla::prop_assert!(
                    stored == (!toks.is_empty() && !dup),
                    "op {op}: insert of {} tokens (dup {dup}) returned \
                     {stored}", toks.len()
                );
                if stored {
                    model.push((toks.to_vec(), next_tag));
                    next_tag += 1.0;
                }
            } else {
                // lookup with a random usable bound (occasionally past
                // the end: the cache clamps, and so does the reference)
                let usable = g.usize_in(0, stream.len() + 2);
                lookups += 1;
                let got = pc
                    .lookup(&fp(), &stream, usable)
                    .map(|(off, s)| (off, s.conv[0]));
                let want = ref_lookup(&model, &stream, usable, block);
                kla::prop_assert!(
                    got == want,
                    "op {op}: lookup(usable {usable}) on {} entries got \
                     {got:?}, reference says {want:?}", model.len()
                );
            }
        }

        let st = pc.stats();
        kla::prop_assert!(
            st.hits + st.partial_hits + st.misses == lookups,
            "{} + {} + {} lookups accounted != {lookups} performed",
            st.hits, st.partial_hits, st.misses
        );
        kla::prop_assert!(st.insertions == model.len(),
                          "{} insertions != {} model entries",
                          st.insertions, model.len());
        kla::prop_assert!(st.evictions == 0 && pc.len() == model.len(),
                          "eviction under an unreachable budget");
        Ok(())
    });
}

#[test]
fn prefix_cache_lru_never_exceeds_byte_budget() {
    property("prefix_cache_budget", 40, |g: &mut Gen| {
        let block = g.usize_in(1, 4);
        // tight budget: a snap() entry costs 320 + 4 * tokens bytes, so
        // this fits only a handful of entries and forces real evictions
        let budget = g.usize_in(1, 5) * 350;
        let mut pc = PrefixCache::new(block, budget);
        let base: Vec<i32> = (0..16).map(|_| g.usize_in(0, 5) as i32)
            .collect();
        let mut accepted = 0usize;

        for op in 0..25 {
            let stream = related_stream(g, &base);
            if g.usize_in(0, 2) < 2 {
                let cut = g.usize_in(1, stream.len().max(1));
                let toks = stream[..cut.min(stream.len())].to_vec();
                if pc.insert(&fp(), &toks, snap(op as f32)) {
                    accepted += 1;
                    // the newest entry is never the eviction victim:
                    // it must full-hit right away
                    let hit = pc.lookup(&fp(), &toks, toks.len());
                    kla::prop_assert!(
                        matches!(hit, Some((off, _)) if off == toks.len()),
                        "op {op}: freshly inserted {}-token entry not \
                         findable", toks.len()
                    );
                }
            } else {
                let usable = g.usize_in(0, stream.len());
                let _ = pc.lookup(&fp(), &stream, usable);
            }
            let st = pc.stats();
            kla::prop_assert!(pc.bytes() <= pc.budget(),
                              "op {op}: {} bytes over the {} budget",
                              pc.bytes(), pc.budget());
            kla::prop_assert!(st.bytes == pc.bytes()
                              && st.entries == pc.len(),
                              "op {op}: stats residency out of sync");
            kla::prop_assert!(
                st.insertions - st.evictions == st.entries,
                "op {op}: {} inserted - {} evicted != {} resident",
                st.insertions, st.evictions, st.entries
            );
        }
        kla::prop_assert!(pc.stats().insertions == accepted,
                          "insertion counter disagrees with accepted \
                           inserts");
        Ok(())
    });
}

#[test]
fn prefix_cache_fingerprint_mismatch_never_hits() {
    property("prefix_cache_fingerprint", 40, |g: &mut Gen| {
        let mut pc = PrefixCache::new(g.usize_in(1, 4), 1 << 20);
        let toks: Vec<i32> = (0..g.usize_in(1, 12))
            .map(|_| g.usize_in(0, 5) as i32)
            .collect();
        kla::prop_assert!(pc.insert(&fp(), &toks, snap(1.0)),
                          "seed insert refused");
        // perturb exactly one fingerprint field
        let wrong = match g.usize_in(0, 6) {
            0 => ModelFingerprint { vocab: 33, ..fp() },
            1 => ModelFingerprint { backend: "xla", ..fp() },
            2 => ModelFingerprint { layers: 3, ..fp() },
            3 => ModelFingerprint { conv_window: 4, ..fp() },
            4 => ModelFingerprint { d_model: 8, ..fp() },
            5 => ModelFingerprint { n_state: 4, ..fp() },
            _ => ModelFingerprint { seed: 8, ..fp() },
        };
        let misses_before = pc.stats().misses;
        kla::prop_assert!(
            pc.lookup(&wrong, &toks, toks.len()).is_none(),
            "{wrong:?} matched an entry from {:?}", fp()
        );
        kla::prop_assert!(pc.stats().misses == misses_before + 1,
                          "fingerprint miss not counted");
        // the true fingerprint still full-hits the same tokens
        let hit = pc.lookup(&fp(), &toks, toks.len());
        kla::prop_assert!(
            matches!(hit, Some((off, _)) if off == toks.len()),
            "true fingerprint lost its entry"
        );
        Ok(())
    });
}

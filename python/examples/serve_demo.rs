fn main() { println!("todo"); }

//! Stand-in `serve/server.rs` for the counter-sync fixtures: the
//! protocol doc and stats reply know `requests` and `steps` only.
//!
//! Codes:
//!
//! Event kinds:

fn stats_reply(live: &LiveStats) -> Vec<(&'static str, usize)> {
    vec![
        ("requests", live.requests.load()),
        ("steps", live.steps.load()),
    ]
}

//! Known-bad fixture for the `lock-order` pass: a two-lock deadlock
//! cycle plus both condvar-discipline violations.  Never compiled —
//! `include_str!`-ed by the pass's unit tests only.

use std::sync::{Condvar, Mutex};

pub struct S {
    pub a: Mutex<u32>,
    pub b: Mutex<u32>,
    pub cv: Condvar,
}

// One path locks `a` then `b`...
pub fn ab(s: &S) {
    let ga = s.a.lock().unwrap();
    let gb = s.b.lock().unwrap();
    drop(gb);
    drop(ga);
}

// ...the other locks `b` then `a`: a deadlock cycle.
pub fn ba(s: &S) {
    let gb = s.b.lock().unwrap();
    let ga = s.a.lock().unwrap();
    drop(ga);
    drop(gb);
}

// Waiting without a predicate-recheck loop loses wakeups.
pub fn waits_wrong(s: &S) {
    let ga = s.a.lock().unwrap();
    let _g = s.cv.wait(ga).unwrap();
}

// Waiting while a second lock is held blocks its acquirers for the
// whole sleep.
pub fn waits_holding(s: &S) {
    let gb = s.b.lock().unwrap();
    let ga = s.a.lock().unwrap();
    loop {
        let _g = s.cv.wait(ga).unwrap();
    }
}

// Known-bad fixture for the `determinism` pass: a wall-clock read, a
// free-running thread spawn, and a narrowing token cast, all in what
// the tests present as a serve module.  Never compiled — only
// `include_str!`-ed by rust/src/lint/determinism.rs tests.

fn drifty(vocab: usize) -> i32 {
    let t0 = std::time::Instant::now();
    std::thread::spawn(move || t0.elapsed());
    vocab as i32
}

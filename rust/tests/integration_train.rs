//! Trainer integration: full `train::run` loop with eval + checkpoints +
//! metrics, checkpoint save/load roundtrip into a new session, and
//! target-accuracy early stopping.

use kla::config::TrainConfig;
use kla::data::task_by_name;
use kla::runtime::{Runtime, TrainSession};
use kla::train::{checkpoint, run};

fn runtime() -> Option<Runtime> {
    match Runtime::discover() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn trainer_loop_and_checkpoint_roundtrip() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("kla_it_ckpt");
    let dir_s = dir.to_str().unwrap().to_string();
    let cfg = TrainConfig {
        artifact: "mad_kla".into(),
        steps: 40,
        seed: 1,
        eval_every: 20,
        eval_batches: 2,
        log_every: 20,
        checkpoint_dir: Some(dir_s.clone()),
        target_accuracy: None,
    };
    let task = task_by_name("memorization").unwrap();
    let outcome = run(&rt, &cfg, task.as_ref()).unwrap();
    assert_eq!(outcome.steps, 40);
    assert!(outcome.final_loss.is_finite());
    assert!(!outcome.evals.is_empty(), "no eval points recorded");
    assert!(!outcome.losses.is_empty());
    // loss must have moved substantially from ln(64)
    assert!(outcome.final_loss < 3.5,
            "memorization barely trained: {}", outcome.final_loss);

    // checkpoint exists and round-trips into a fresh session
    let path = checkpoint::path_for(&dir_s, "mad_kla");
    assert!(path.exists());
    let params = checkpoint::load(&path).unwrap();
    let mut session = TrainSession::new(&rt, "mad_kla").unwrap();
    let fresh_eval = {
        let mut rng = kla::util::Pcg64::seeded(99);
        let (b, t) = session.batch_shape();
        session.eval_batch(&task.batch(&mut rng, b, t)).unwrap()
    };
    session.set_params(params).unwrap();
    let loaded_eval = {
        let mut rng = kla::util::Pcg64::seeded(99);
        let (b, t) = session.batch_shape();
        session.eval_batch(&task.batch(&mut rng, b, t)).unwrap()
    };
    assert!(
        loaded_eval.mean_loss() < fresh_eval.mean_loss(),
        "checkpoint params no better than fresh init: {} vs {}",
        loaded_eval.mean_loss(), fresh_eval.mean_loss()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn target_accuracy_stops_early() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig {
        artifact: "mad_kla".into(),
        steps: 400,
        seed: 2,
        eval_every: 10,
        eval_batches: 1,
        log_every: 100,
        checkpoint_dir: None,
        // memorization reaches ~50%+ quickly; generous target to trigger
        target_accuracy: Some(0.10),
    };
    let task = task_by_name("memorization").unwrap();
    let outcome = run(&rt, &cfg, task.as_ref()).unwrap();
    assert!(outcome.steps < 400,
            "early stop never triggered ({} steps)", outcome.steps);
}

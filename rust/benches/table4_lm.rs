//! Table 4 / Fig. 1b (scaled): pretrain on the synthetic corpus, evaluate
//! the 8-family zero-shot suite.  Paper: KLA competitive standalone;
//! GPT+KLA (final layer swapped) beats pure GPT on average.
//!
//! Default manifest models: kla, gpt, hybrid_kla (mamba/gdn/hybrids via
//! `make artifacts-full`).  KLA_BENCH_STEPS scales pretraining length.

use kla::bench::exp::{bench_steps, have};
use kla::bench::Suite;
use kla::config::TrainConfig;
use kla::data::corpus::CorpusLm;
use kla::eval::ZeroShotSuite;
use kla::runtime::{Runtime, ScoreSession, TrainSession};

fn main() {
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP table4: {e}");
            return;
        }
    };
    let steps = bench_steps(200);
    let seed = 0u64;
    let meta = rt.meta("lm_kla_train").unwrap();
    let (lm_data, tok, corpus) =
        CorpusLm::build(seed, 2_000_000, meta.model.vocab).unwrap();
    let suite_items = ZeroShotSuite::build(&corpus, seed, 8);
    let mut suite = Suite::new("table4_lm");

    let models = ["kla", "gpt", "hybrid_kla", "mamba", "gdn",
                  "hybrid_mamba", "hybrid_gdn", "kla_plus"];
    for model in models {
        let base = format!("lm_{model}");
        if !have(&rt, &base) {
            println!("({base} not built — `make artifacts-full`)");
            continue;
        }
        let ckdir = std::env::temp_dir().join("kla_table4");
        let ckdir_s = ckdir.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(
            kla::train::checkpoint::path_for(&ckdir_s, &base));
        let cfg = TrainConfig {
            artifact: base.clone(),
            steps,
            seed,
            eval_every: 0,
            eval_batches: 3,
            log_every: steps.max(1),
            checkpoint_dir: Some(ckdir_s.clone()),
            target_accuracy: None,
        };
        let outcome = kla::train::run(&rt, &cfg, &lm_data).unwrap();
        let params = kla::train::checkpoint::load(
            &kla::train::checkpoint::path_for(&ckdir_s, &base)).unwrap();
        let scorer = ScoreSession::new(&rt, &base, params).unwrap();
        let report = suite_items.evaluate(&scorer, &tok).unwrap();
        let mut metrics: Vec<(String, f64)> = vec![
            ("ppl_loss".into(), outcome.eval.mean_loss()),
            ("next_tok_acc".into(), outcome.accuracy()),
            ("zeroshot_avg".into(), report.average()),
        ];
        for (t, a, _) in &report.per_task {
            metrics.push((t.clone(), *a));
        }
        suite.metric_row(&format!("lm/{model}"), metrics);
    }
    suite.finish();
}

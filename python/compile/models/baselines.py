"""Baseline sequence mixers (paper Section 5.1), matched on state size.

All sub-quadratic baselines share the KLA scaffold (RMSNorm -> causal conv ->
SiLU -> mixer -> SiLU-gate -> out-proj -> residual) so that accuracy
differences isolate the *update mechanism*, exactly as the paper's
single-block protocol prescribes.

- Mamba (S6): input-dependent (selective) diagonal SSM; token-dependent
  Delta_t overloads discretisation with selection (contrast: KLA's global
  dynamics + uncertainty gating).
- GLA: gated linear attention, H_t = g_t ⊙ H_{t-1} + k_t v_t^T.
- GDN (Gated DeltaNet): delta-rule write with scalar forget gate
  S_t = a_t (I - b_t k_t k_t^T) S_{t-1} + b_t k_t v_t^T  (sequential scan:
  the rank-one erase term is non-diagonal, so no associative form is used).
- GPT: causal multi-head softmax attention + MLP (the O(T^2) reference).

mLSTM is omitted (DESIGN.md §5 — documented substitution).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.nn import sigmoid, softplus

from ..kernels.scan import affine_prefix_scan
from .common import causal_conv1d, dense_init, l2norm, rmsnorm, silu


# ----------------------------------------------------------------- Mamba ---

def init_mamba_block(rng, d, n_state, conv_kernel=4):
    N = n_state
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "conv_w": jnp.asarray(rng.normal(0, 0.2, (conv_kernel, d)), jnp.float32),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "a_log": jnp.asarray(np.log(rng.uniform(0.5, 8.0, (N, d))), jnp.float32),
        "w_dt": dense_init(rng, d, d, scale=0.5),
        "b_dt": jnp.full((d,), -2.0, jnp.float32),
        "w_b": dense_init(rng, d, N),
        "w_c": dense_init(rng, d, N),
        "skip_d": jnp.ones((d,), jnp.float32),
        "wg": dense_init(rng, d, d),
        "wo": dense_init(rng, d, d, scale=0.5),
    }


def mamba_block(p, x):
    """Selective SSM (S6) block.  h_t = exp(-A dt_t) h_{t-1} + dt_t B_t x_t,
    y_t = C_t^T h_t + D x_t, all per channel with N slots."""
    xn = rmsnorm(x, p["norm"])
    c = silu(causal_conv1d(xn, p["conv_w"], p["conv_b"]))
    dt = softplus(c @ p["w_dt"] + p["b_dt"])                 # (B,T,D)
    bt = c @ p["w_b"]                                        # (B,T,N)
    ct = c @ p["w_c"]                                        # (B,T,N)
    A = jnp.exp(p["a_log"])                                  # (N,D) > 0
    abar = jnp.exp(-A[None, None] * dt[:, :, None, :])       # (B,T,N,D)
    drive = dt[:, :, None, :] * bt[..., None] * c[:, :, None, :]
    h = affine_prefix_scan(abar, drive, jnp.zeros(A.shape, jnp.float32))
    y = jnp.einsum("btn,btnd->btd", ct, h) + p["skip_d"] * c
    gate = silu(xn @ p["wg"])
    return x + (y * gate) @ p["wo"]


# ------------------------------------------------------------------- GLA ---

def init_gla_block(rng, d, n_state, conv_kernel=4):
    N = n_state
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "conv_w": jnp.asarray(rng.normal(0, 0.2, (conv_kernel, d)), jnp.float32),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "wk": dense_init(rng, d, N),
        "wq": dense_init(rng, d, N),
        "wv": dense_init(rng, d, d),
        "w_f": dense_init(rng, d, N, scale=0.5),
        "b_f": jnp.full((N,), 2.0, jnp.float32),  # open forget gate at init
        "wg": dense_init(rng, d, d),
        "wo": dense_init(rng, d, d, scale=0.5),
    }


def gla_block(p, x):
    """Gated linear attention: H_t = g_t ⊙ H_{t-1} + k_t v_t^T, y = q^T H."""
    xn = rmsnorm(x, p["norm"])
    c = silu(causal_conv1d(xn, p["conv_w"], p["conv_b"]))
    k = l2norm(c @ p["wk"])                                  # (B,T,N)
    q = l2norm(c @ p["wq"])
    v = c @ p["wv"]                                          # (B,T,D)
    g = sigmoid(c @ p["w_f"] + p["b_f"])                     # (B,T,N)
    N, D = k.shape[-1], v.shape[-1]
    f = jnp.broadcast_to(g[..., None], k.shape + (D,))       # (B,T,N,D)
    drive = k[..., None] * v[:, :, None, :]
    h = affine_prefix_scan(f, drive, jnp.zeros((N, D), jnp.float32))
    y = jnp.einsum("btn,btnd->btd", q, h)
    gate = silu(xn @ p["wg"])
    return x + (y * gate) @ p["wo"]


# ------------------------------------------------------------------- GDN ---

def init_gdn_block(rng, d, n_state, conv_kernel=4):
    N = n_state
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "conv_w": jnp.asarray(rng.normal(0, 0.2, (conv_kernel, d)), jnp.float32),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "wk": dense_init(rng, d, N),
        "wq": dense_init(rng, d, N),
        "wv": dense_init(rng, d, d),
        "w_alpha": dense_init(rng, d, 1, scale=0.5),
        "b_alpha": jnp.full((1,), 3.0, jnp.float32),
        "w_beta": dense_init(rng, d, 1, scale=0.5),
        "b_beta": jnp.zeros((1,), jnp.float32),
        "wg": dense_init(rng, d, d),
        "wo": dense_init(rng, d, d, scale=0.5),
    }


def gdn_block(p, x):
    """Gated DeltaNet: S_t = a_t (I - b_t k_t k_t^T) S_{t-1} + b_t k_t v_t^T.

    The erase term couples state rows, so this runs as a sequential
    `lax.scan` over time (matching the reference implementation; the paper's
    chunked parallel form is a kernel-level optimisation, not a different
    mathematical object)."""
    xn = rmsnorm(x, p["norm"])
    c = silu(causal_conv1d(xn, p["conv_w"], p["conv_b"]))
    k = l2norm(c @ p["wk"])                                  # (B,T,N)
    q = l2norm(c @ p["wq"])
    v = c @ p["wv"]                                          # (B,T,D)
    alpha = sigmoid(c @ p["w_alpha"] + p["b_alpha"])[..., 0]  # (B,T)
    beta = sigmoid(c @ p["w_beta"] + p["b_beta"])[..., 0]     # (B,T)
    N, D = k.shape[-1], v.shape[-1]

    def step(S, inp):
        k_t, v_t, a_t, b_t = inp                  # (B,N),(B,D),(B,),(B,)
        kS = jnp.einsum("bn,bnd->bd", k_t, S)     # k^T S
        S = a_t[:, None, None] * (S - b_t[:, None, None]
                                  * k_t[:, :, None] * kS[:, None, :])
        S = S + b_t[:, None, None] * k_t[:, :, None] * v_t[:, None, :]
        return S, S

    B = x.shape[0]
    S0 = jnp.zeros((B, N, D), jnp.float32)
    xs = (jnp.swapaxes(k, 0, 1), jnp.swapaxes(v, 0, 1),
          jnp.swapaxes(alpha, 0, 1), jnp.swapaxes(beta, 0, 1))
    _, S_all = jax.lax.scan(step, S0, xs)          # (T,B,N,D)
    y = jnp.einsum("btn,btnd->btd", q, jnp.swapaxes(S_all, 0, 1))
    gate = silu(xn @ p["wg"])
    return x + (y * gate) @ p["wo"]


# ------------------------------------------------------------------- GPT ---

def init_gpt_block(rng, d, n_heads=4, mlp_mult=4):
    return {
        "norm1": jnp.ones((d,), jnp.float32),
        "wq": dense_init(rng, d, d),
        "wk": dense_init(rng, d, d),
        "wv": dense_init(rng, d, d),
        "wo": dense_init(rng, d, d, scale=0.5),
        "norm2": jnp.ones((d,), jnp.float32),
        "w1": dense_init(rng, d, mlp_mult * d),
        "w2": dense_init(rng, mlp_mult * d, d, scale=0.5),
        "n_heads": None,  # placeholder removed below (keep params arrays only)
    }


def _split_heads(x, h):
    B, T, D = x.shape
    return jnp.transpose(x.reshape(B, T, h, D // h), (0, 2, 1, 3))


def gpt_block(p, x, n_heads=4):
    """Pre-norm causal MHA + MLP (the paper's O(T^2) softmax reference)."""
    xn = rmsnorm(x, p["norm1"])
    q = _split_heads(xn @ p["wq"], n_heads)
    k = _split_heads(xn @ p["wk"], n_heads)
    v = _split_heads(xn @ p["wv"], n_heads)
    dh = q.shape[-1]
    att = jnp.einsum("bhtd,bhsd->bhts", q, k) / np.sqrt(dh)
    T = x.shape[1]
    mask = jnp.tril(jnp.ones((T, T), jnp.float32))
    att = jnp.where(mask[None, None] > 0, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhts,bhsd->bhtd", att, v)
    B = x.shape[0]
    ctx = jnp.transpose(ctx, (0, 2, 1, 3)).reshape(B, T, -1)
    x = x + ctx @ p["wo"]
    xn2 = rmsnorm(x, p["norm2"])
    return x + silu(xn2 @ p["w1"]) @ p["w2"]


def init_gpt_block_fixed(rng, d, n_heads=4, mlp_mult=4):
    """init_gpt_block without the placeholder key (params must be arrays)."""
    p = init_gpt_block(rng, d, n_heads, mlp_mult)
    p.pop("n_heads")
    return p

// Known-bad fixture for the `unsafe` pass: three `unsafe` sites
// (a block, and Send/Sync impls) with no SAFETY comment anywhere.
// Never compiled — only `include_str!`-ed by unsafe_audit.rs tests.

struct RawPtr(*mut f32);

unsafe impl Send for RawPtr {}
unsafe impl Sync for RawPtr {}

fn write(p: &RawPtr, i: usize, x: f32) {
    unsafe { *p.0.add(i) = x };
}

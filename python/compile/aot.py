"""AOT bridge: lower every manifest spec to HLO **text** + meta.json.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.  Artifacts are skipped when the output already
exists and `--force` is not given, so re-running the manifest is cheap.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .models.common import flatten_params
from .models.lm import init_lm
from .models.decode import decode_init_state
from .specs import ArtifactSpec, manifest
from .train_step import (build_decode, build_eval_step, build_logits,
                         build_score_step, build_train_step, build_variance)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True is ESSENTIAL: the default HLO printer
    # elides big literals as `constant({...})`, which the text parser then
    # refills with garbage — silently corrupting any weights baked into the
    # graph (the init artifacts).  See integration_runtime.rs history.
    return comp.as_hlo_text(print_large_constants=True)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _arr_meta(name, x):
    return {"name": name, "shape": list(x.shape), "dtype": str(x.dtype)}


def _tie_params(x, params):
    """Keep every parameter alive in the lowered module.

    Roles that do not touch some parameters (e.g. `variance` never reads the
    LM head) would otherwise get those parameters PRUNED from the HLO entry
    signature, breaking the fixed ABI the Rust side feeds.  Adding a
    zero-scaled sum ties them in without changing values.
    """
    z = sum(jnp.sum(p) for p in params) * 0.0
    return x + z.astype(x.dtype)


def build_role(spec: ArtifactSpec, role: str):
    """Returns (fn, example_args, input_meta, output_meta, extra_meta)."""
    cfg, B, T = spec.model, spec.batch, spec.seq
    template = init_lm(cfg, seed=0)
    flat = flatten_params(template)
    names = [n for n, _ in flat]
    pspecs = [_sds(a.shape) for _, a in flat]
    # every role's leading inputs are the flattened params (group tag lets
    # the Rust side count/slice them uniformly across roles)
    pmeta = [{**_arr_meta(n, a), "group": "params"} for n, a in flat]
    tok = _sds((B, T), jnp.int32)
    tgt = _sds((B, T), jnp.int32)
    msk = _sds((B, T), jnp.float32)
    tok_meta = {"name": "tokens", "shape": [B, T], "dtype": "int32"}
    tgt_meta = {"name": "targets", "shape": [B, T], "dtype": "int32"}
    msk_meta = {"name": "mask", "shape": [B, T], "dtype": "float32"}

    if role == "init":
        def fn():
            return tuple(a for _, a in flatten_params(init_lm(cfg, seed=0)))
        return fn, [], [], pmeta, {}

    if role == "train":
        step_fn = build_train_step(cfg, spec.opt, template)

        def fn(*args):
            n = len(pspecs)
            p, m, v = args[:n], args[n:2 * n], args[2 * n:3 * n]
            step, tokens, targets, mask = args[3 * n:]
            loss, p2, m2, v2 = step_fn(list(p), list(m), list(v), step,
                                       tokens, targets, mask)
            return (loss,) + tuple(p2) + tuple(m2) + tuple(v2)

        ex = pspecs * 3 + [_sds(()), tok, tgt, msk]
        imeta = ([{**d, "group": "params"} for d in pmeta]
                 + [{**d, "group": "opt_m"} for d in pmeta]
                 + [{**d, "group": "opt_v"} for d in pmeta]
                 + [{"name": "step", "shape": [], "dtype": "float32"},
                    {"name": "tokens", "shape": [B, T], "dtype": "int32"},
                    {"name": "targets", "shape": [B, T], "dtype": "int32"},
                    {"name": "mask", "shape": [B, T], "dtype": "float32"}])
        ometa = ([{"name": "loss", "shape": [], "dtype": "float32"}]
                 + [{**d, "group": "params"} for d in pmeta]
                 + [{**d, "group": "opt_m"} for d in pmeta]
                 + [{**d, "group": "opt_v"} for d in pmeta])
        return fn, ex, imeta, ometa, {}

    if role == "eval":
        step_fn = build_eval_step(cfg, template)

        def fn(*args):
            p = list(args[:len(pspecs)])
            tokens, targets, mask = args[len(pspecs):]
            out = step_fn(p, tokens, targets, mask)
            return (_tie_params(out[0], p),) + tuple(out[1:])

        ex = pspecs + [tok, tgt, msk]
        imeta = pmeta + [tok_meta, tgt_meta, msk_meta]
        ometa = [{"name": k, "shape": [], "dtype": "float32"}
                 for k in ("loss_sum", "correct", "count")]
        return fn, ex, imeta, ometa, {}

    if role == "score":
        step_fn = build_score_step(cfg, template)

        def fn(*args):
            p = list(args[:len(pspecs)])
            tokens, targets, mask = args[len(pspecs):]
            return (_tie_params(step_fn(p, tokens, targets, mask), p),)

        ex = pspecs + [tok, tgt, msk]
        imeta = pmeta + [tok_meta, tgt_meta, msk_meta]
        ometa = [{"name": "seq_logprob", "shape": [B], "dtype": "float32"}]
        return fn, ex, imeta, ometa, {}

    if role == "logits":
        step_fn = build_logits(cfg, template)

        def fn(*args):
            p = list(args[:len(pspecs)])
            return (_tie_params(step_fn(p, args[len(pspecs)]), p),)

        ex = pspecs + [tok]
        imeta = pmeta + [tok_meta]
        ometa = [{"name": "logits", "shape": [B, T, cfg.vocab],
                  "dtype": "float32"}]
        return fn, ex, imeta, ometa, {}

    if role == "variance":
        step_fn = build_variance(cfg, template)

        def fn(*args):
            p = list(args[:len(pspecs)])
            return (_tie_params(step_fn(p, args[len(pspecs)]), p),)

        ex = pspecs + [tok]
        imeta = pmeta + [tok_meta]
        ometa = [{"name": "y_var", "shape": [B, T], "dtype": "float32"}]
        return fn, ex, imeta, ometa, {}

    if role == "decode":
        step_fn = build_decode(cfg, template)
        conv0, lam0, eta0 = decode_init_state(cfg, template, B)

        def fn(*args):
            n = len(pspecs)
            p = list(args[:n])
            token, conv, lam, eta = args[n:]
            out = step_fn(p, token, conv, lam, eta)
            return (_tie_params(out[0], p),) + tuple(out[1:])

        ex = pspecs + [_sds((B,), jnp.int32), _sds(conv0.shape),
                       _sds(lam0.shape), _sds(eta0.shape)]
        imeta = pmeta + [{"name": "token", "shape": [B], "dtype": "int32"}]
        smeta = [{"name": "conv", "shape": list(conv0.shape), "dtype": "float32"},
                 {"name": "lam", "shape": list(lam0.shape), "dtype": "float32"},
                 {"name": "eta", "shape": list(eta0.shape), "dtype": "float32"}]
        imeta = imeta + smeta
        ometa = ([{"name": "logits", "shape": [B, cfg.vocab],
                   "dtype": "float32"}] + smeta)
        return fn, ex, imeta, ometa, {"state": smeta}

    raise ValueError(f"unknown role {role!r}")


def emit(spec: ArtifactSpec, role: str, out_dir: str, force: bool) -> str:
    name = spec.artifact_name(role)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    meta_path = os.path.join(out_dir, f"{name}.meta.json")
    if not force and os.path.exists(hlo_path) and os.path.exists(meta_path):
        return "cached"
    t0 = time.time()
    fn, ex_args, imeta, ometa, extra = build_role(spec, role)
    lowered = jax.jit(fn).lower(*ex_args)
    text = to_hlo_text(lowered)
    with open(hlo_path, "w") as f:
        f.write(text)
    meta = {
        "name": name,
        "family": spec.family,
        "tag": spec.tag,
        "role": role,
        "model": spec.model.to_dict(),
        "opt": spec.opt.to_dict(),
        "batch": spec.batch,
        "seq": spec.seq,
        "inputs": imeta,
        "outputs": ometa,
        **extra,
    }
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    return f"{time.time() - t0:.1f}s, {len(text) // 1024} KiB"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--manifest", default="default")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name prefixes")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    specs = manifest(args.manifest)
    names = []
    for spec in specs:
        for role in spec.roles:
            name = spec.artifact_name(role)
            if args.only and not any(name.startswith(p)
                                     for p in args.only.split(",")):
                continue
            status = emit(spec, role, args.out, args.force)
            names.append(name)
            print(f"[aot] {name:40s} {status}", flush=True)
    # manifest index for the Rust registry
    idx_path = os.path.join(args.out, "manifest.json")
    existing = []
    if os.path.exists(idx_path):
        with open(idx_path) as f:
            existing = json.load(f)["artifacts"]
    merged = sorted(set(existing) | set(names))
    with open(idx_path, "w") as f:
        json.dump({"artifacts": merged}, f, indent=1)
    print(f"[aot] manifest: {len(merged)} artifacts")


if __name__ == "__main__":
    main()

//! OU prior discretisation, native mirror of `python/compile/kernels/ou.py`.
//!
//! Used by the native filter (bench/property substrate) and by the serving
//! state manager to build initial precisions without touching Python.

pub const A_MIN: f32 = 1e-4;
pub const DT_LO: f32 = 1e-3;
pub const DT_HI: f32 = 1e-1;

#[inline]
pub fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Map raw (unconstrained) parameters to (a, p, dt).
pub fn constrain(a_raw: f32, p_raw: f32, dt_raw: f32) -> (f32, f32, f32) {
    (
        softplus(a_raw) + A_MIN,
        softplus(p_raw),
        DT_LO + sigmoid(dt_raw) * (DT_HI - DT_LO),
    )
}

/// Exact OU discretisation (paper Eq. 8).
pub fn discretise(a: f32, p: f32, dt: f32) -> (f32, f32) {
    let abar = (-a * dt).exp();
    let pbar = p * p / (2.0 * a) * (1.0 - (-2.0 * a * dt).exp());
    (abar, pbar)
}

/// Raw -> (abar, pbar), with the paper's two ablation switches.
pub fn discretise_raw(a_raw: f32, p_raw: f32, dt_raw: f32,
                      process_noise: bool, ou_exact: bool) -> (f32, f32) {
    let (a, p, dt) = constrain(a_raw, p_raw, dt_raw);
    let (abar, pbar) = if ou_exact {
        discretise(a, p, dt)
    } else {
        ((1.0 - a * dt).clamp(1e-4, 1.0), p * p * dt)
    };
    (abar, if process_noise { pbar } else { 0.0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abar_in_unit_interval() {
        for a_raw in [-5.0, 0.0, 5.0] {
            for dt_raw in [-5.0, 0.0, 5.0] {
                let (abar, pbar) =
                    discretise_raw(a_raw, 0.0, dt_raw, true, true);
                assert!(abar > 0.0 && abar < 1.0, "{abar}");
                assert!(pbar >= 0.0);
            }
        }
    }

    #[test]
    fn stationary_variance_limit() {
        // dt -> inf: pbar -> p^2 / (2a)
        let (a, p) = (1.0f32, 0.5f32);
        let (abar, pbar) = discretise(a, p, 1e4);
        assert!(abar < 1e-6);
        assert!((pbar - p * p / (2.0 * a)).abs() < 1e-6);
    }

    #[test]
    fn noise_switch_zeroes_pbar() {
        let (_, pbar) = discretise_raw(0.0, 0.0, 0.0, false, true);
        assert_eq!(pbar, 0.0);
    }

    #[test]
    fn matches_python_values() {
        // Cross-language pin: values computed by kernels/ou.py at raw=0.
        // a = softplus(0)+1e-4 = 0.6932471, p = 0.6931472,
        // dt = 0.001 + 0.5*0.099 = 0.0505
        let (abar, pbar) = discretise_raw(0.0, 0.0, 0.0, true, true);
        assert!((abar - 0.96562).abs() < 1e-4, "{abar}");
        assert!((pbar - 0.023433).abs() < 1e-4, "{pbar}");
    }

    #[test]
    fn euler_vs_exact_differ() {
        let exact = discretise_raw(0.5, 0.5, 0.5, true, true);
        let euler = discretise_raw(0.5, 0.5, 0.5, true, false);
        assert!((exact.0 - euler.0).abs() > 1e-6);
    }
}

"""LM assembly: embed -> L mixer blocks -> RMSNorm -> head (paper Fig. 7).

`ModelConfig.kind` selects the mixer; `hybrid_*` kinds replace ONLY the
final block of a GPT backbone with the named SSM block (paper Section 5.5:
'a single KLA layer improves a GPT').
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init, rmsnorm
from .kla import init_kla_block, kla_block, kla_block_sample
from .baselines import (gdn_block, gla_block, gpt_block, init_gdn_block,
                        init_gla_block, init_gpt_block_fixed,
                        init_mamba_block, mamba_block)

KINDS = ("kla", "kla_plus", "mamba", "gla", "gdn", "gpt",
         "hybrid_kla", "hybrid_mamba", "hybrid_gdn")


@dataclass(frozen=True)
class ModelConfig:
    kind: str
    vocab: int
    d_model: int
    n_layers: int
    n_state: int = 8          # state-expansion factor N
    n_heads: int = 4          # attention heads (gpt / hybrid backbones)
    conv_kernel: int = 4
    process_noise: bool = True   # False => Fig. 6b / Table 6 ablation
    ou_exact: bool = True        # False => Fig. 3b ablation
    impl: str = "scan"           # KLA kernel impl: scan | pallas | ref
    mc_samples: int = 0          # >0 => KLA+ MC marginal-likelihood loss

    def __post_init__(self):
        assert self.kind in KINDS, self.kind
        assert self.d_model % self.n_heads == 0

    def to_dict(self):
        return asdict(self)


def _block_kind(cfg: ModelConfig, layer: int) -> str:
    if cfg.kind.startswith("hybrid_"):
        inner = cfg.kind.split("_", 1)[1]
        return inner if layer == cfg.n_layers - 1 else "gpt"
    if cfg.kind == "kla_plus":
        return "kla"
    return cfg.kind


_INITS = {
    "kla": lambda rng, cfg: init_kla_block(rng, cfg.d_model, cfg.n_state,
                                           cfg.conv_kernel),
    "mamba": lambda rng, cfg: init_mamba_block(rng, cfg.d_model, cfg.n_state,
                                               cfg.conv_kernel),
    "gla": lambda rng, cfg: init_gla_block(rng, cfg.d_model, cfg.n_state,
                                           cfg.conv_kernel),
    "gdn": lambda rng, cfg: init_gdn_block(rng, cfg.d_model, cfg.n_state,
                                           cfg.conv_kernel),
    "gpt": lambda rng, cfg: init_gpt_block_fixed(rng, cfg.d_model,
                                                 cfg.n_heads),
}


def init_lm(cfg: ModelConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    params = {
        "embed": jnp.asarray(rng.normal(0, 0.02, (cfg.vocab, cfg.d_model)),
                             jnp.float32),
        "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
        "head": dense_init(rng, cfg.d_model, cfg.vocab, scale=0.5),
        "blocks": {},
    }
    for layer in range(cfg.n_layers):
        bk = _block_kind(cfg, layer)
        # zero-pad layer index so sorted-key flattening = layer order
        params["blocks"][f"{layer:02d}_{bk}"] = _INITS[bk](rng, cfg)
    return params


def _apply_block(bk: str, bp: dict, h, cfg: ModelConfig):
    if bk == "kla":
        return kla_block(bp, h, impl=cfg.impl,
                         process_noise=cfg.process_noise,
                         ou_exact=cfg.ou_exact)
    if bk == "mamba":
        return mamba_block(bp, h)
    if bk == "gla":
        return gla_block(bp, h)
    if bk == "gdn":
        return gdn_block(bp, h)
    if bk == "gpt":
        return gpt_block(bp, h, n_heads=cfg.n_heads)
    raise ValueError(bk)


def lm_forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray):
    """tokens: (B, T) int32 -> logits (B, T, V)."""
    h = params["embed"][tokens]
    for name in sorted(params["blocks"].keys()):
        bk = name.split("_", 1)[1]
        h = _apply_block(bk, params["blocks"][name], h, cfg)
    h = rmsnorm(h, params["norm_f"])
    return h @ params["head"]


def lm_forward_sampled(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
                       key: jax.Array):
    """KLA+ forward: every KLA block emits one posterior sample instead of
    the mean (paper 'probabilistic decoding').  Non-KLA blocks unchanged."""
    h = params["embed"][tokens]
    for name in sorted(params["blocks"].keys()):
        bk = name.split("_", 1)[1]
        bp = params["blocks"][name]
        if bk == "kla":
            key, sub = jax.random.split(key)
            eps = jax.random.normal(sub, h.shape, h.dtype)
            h = kla_block_sample(bp, h, eps, impl=cfg.impl,
                                 process_noise=cfg.process_noise,
                                 ou_exact=cfg.ou_exact)
        else:
            h = _apply_block(bk, bp, h, cfg)
    h = rmsnorm(h, params["norm_f"])
    return h @ params["head"]


def lm_variance(cfg: ModelConfig, params: dict, tokens: jnp.ndarray):
    """Posterior readout variance of the LAST KLA block, averaged over
    channels: (B, T).  The Fig. 5b diagnostic."""
    h = params["embed"][tokens]
    y_var = None
    for name in sorted(params["blocks"].keys()):
        bk = name.split("_", 1)[1]
        bp = params["blocks"][name]
        if bk == "kla":
            h, y_var = kla_block(bp, h, impl=cfg.impl,
                                 process_noise=cfg.process_noise,
                                 ou_exact=cfg.ou_exact, want_variance=True)
        else:
            h = _apply_block(bk, bp, h, cfg)
    assert y_var is not None, "lm_variance requires at least one KLA block"
    return jnp.mean(y_var, axis=-1)

//! Property test (via `testing::property`) for the `BeliefStateCache`
//! slot pool, driven through the native backend so every invariant is
//! checked against REAL decode semantics (logits), not just raw state
//! bytes:
//!
//! 1. random acquire/release/snapshot/restore/step interleavings never
//!    alias slots (`free + held == batch`, acquired slots distinct);
//! 2. restoring a snapshot into ANY slot reproduces the snapshotted
//!    logits (per-slot state independence included);
//! 3. released slots always reset to the learned prior — immediately,
//!    and again after re-acquire.

use kla::kla::NativeLmConfig;
use kla::runtime::{DecodeBackend, NativeBackend};
use kla::serve::state_cache::SlotSnapshot;
use kla::serve::BeliefStateCache;
use kla::tensor::IntTensor;
use kla::testing::{property, Gen};

/// Next-token logits every slot would see for a fixed probe token — a
/// pure function of the cache's current state (no mutation).
fn probe_rows(backend: &NativeBackend, cache: &BeliefStateCache)
              -> Vec<Vec<f32>> {
    let b = backend.batch();
    let v = backend.vocab();
    let toks = IntTensor::new(&[b], vec![1; b]).unwrap();
    let (logits, _) = backend.step(&toks, cache.state()).unwrap();
    (0..b).map(|s| logits.data()[s * v..(s + 1) * v].to_vec()).collect()
}

fn rows_close(a: &[f32], b: &[f32], what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: len {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > 1e-6 * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("{what}[{i}]: {x} vs {y}"));
        }
    }
    Ok(())
}

#[test]
fn native_state_cache_interleavings_preserve_slot_isolation() {
    property("state_cache_interleavings", 25, |g: &mut Gen| {
        let batch = g.usize_in(2, 4);
        let cfg = NativeLmConfig {
            vocab: 16,
            d_model: 8,
            n_layers: g.usize_in(1, 2),
            n_state: 2,
            conv_kernel: 3,
            ..Default::default()
        };
        let backend = NativeBackend::seeded(&cfg, 11, batch);
        let mut cache = BeliefStateCache::for_backend(&backend)
            .map_err(|e| e.to_string())?;
        let prior = probe_rows(&backend, &cache);
        let mut held: Vec<usize> = Vec::new();
        let mut snaps: Vec<(SlotSnapshot, Vec<f32>)> = Vec::new();

        let ops = g.usize_in(4, 14);
        for op in 0..ops {
            match g.usize_in(0, 4) {
                // acquire: fresh, distinct, in range
                0 => {
                    if let Some(s) = cache.acquire() {
                        kla::prop_assert!(s < batch,
                                          "op {op}: slot {s} out of range");
                        kla::prop_assert!(!held.contains(&s),
                                          "op {op}: slot {s} aliased");
                        held.push(s);
                    } else {
                        kla::prop_assert!(held.len() == batch,
                                          "op {op}: pool empty with only \
                                           {} of {batch} held",
                                          held.len());
                    }
                }
                // release: slot back to the pool AND back at the prior
                1 => {
                    if !held.is_empty() {
                        let s =
                            held.swap_remove(g.usize_in(0, held.len() - 1)
                                             % held.len());
                        cache.release(s);
                        let rows = probe_rows(&backend, &cache);
                        rows_close(&rows[s], &prior[s],
                                   &format!("op {op}: released slot {s} \
                                             not at prior"))?;
                    }
                }
                // decode step: dirties every slot's posterior
                2 => {
                    let toks: Vec<i32> = (0..batch)
                        .map(|i| ((op + i) % 16) as i32)
                        .collect();
                    let t = IntTensor::new(&[batch], toks).unwrap();
                    let (_, next) = backend
                        .step(&t, cache.state())
                        .map_err(|e| e.to_string())?;
                    cache.set_state(next);
                }
                // snapshot a held slot, remembering its probe logits
                3 => {
                    if !held.is_empty() {
                        let s = held[g.usize_in(0, held.len() - 1)
                                     % held.len()];
                        let rows = probe_rows(&backend, &cache);
                        snaps.push((cache.snapshot(s), rows[s].clone()));
                    }
                }
                // restore any snapshot into any held slot: logits of
                // THAT slot must reproduce the snapshotted ones
                _ => {
                    if !held.is_empty() && !snaps.is_empty() {
                        let s = held[g.usize_in(0, held.len() - 1)
                                     % held.len()];
                        let (snap, expect) =
                            &snaps[g.usize_in(0, snaps.len() - 1)
                                   % snaps.len()];
                        cache.restore(s, snap).map_err(|e| e.to_string())?;
                        let rows = probe_rows(&backend, &cache);
                        rows_close(&rows[s], expect,
                                   &format!("op {op}: restore into slot \
                                             {s} lost the belief"))?;
                    }
                }
            }
            // pool accounting never drifts
            kla::prop_assert!(
                cache.free_slots() + held.len() == batch,
                "op {op}: {} free + {} held != {batch}",
                cache.free_slots(), held.len()
            );
        }

        // drain, then reclaim the whole pool: release resets each held
        // slot, and every acquire hands back a slot at the prior — even
        // for slots that were dirtied batch-wide while sitting free
        // (decode steps advance every row; reset happens at the
        // acquire/release boundaries, exactly like the engine).
        for s in held.drain(..) {
            cache.release(s);
        }
        kla::prop_assert!(cache.free_slots() == batch,
                          "pool not full after draining");
        for _ in 0..batch {
            let s = cache
                .acquire()
                .ok_or_else(|| "pool drained early".to_string())?;
            let rows = probe_rows(&backend, &cache);
            rows_close(&rows[s], &prior[s],
                       &format!("acquired slot {s} not at prior"))?;
        }
        Ok(())
    });
}

#[test]
fn native_prefill_write_slot_preserves_other_lanes() {
    // chunked prefill advances exactly one lane: after prefill() +
    // write_slot(), every OTHER slot's next-token logits are unchanged,
    // and the prefilled slot matches feeding the same tokens through
    // batched step()s (within the scan conformance tolerance).
    property("prefill_write_slot", 20, |g: &mut Gen| {
        let batch = g.usize_in(2, 4);
        let cfg = NativeLmConfig {
            vocab: 16,
            d_model: 8,
            n_layers: g.usize_in(1, 2),
            n_state: 2,
            conv_kernel: 3,
            ..Default::default()
        };
        let backend = NativeBackend::seeded(&cfg, 17, batch);
        let mut cache = BeliefStateCache::for_backend(&backend)
            .map_err(|e| e.to_string())?;
        // dirty every lane so the prefill resumes a real carry
        for w in 0..g.usize_in(1, 3) {
            let toks: Vec<i32> =
                (0..batch).map(|i| ((w + i + 3) % 16) as i32).collect();
            let t = IntTensor::new(&[batch], toks).unwrap();
            let (_, next) = backend
                .step(&t, cache.state())
                .map_err(|e| e.to_string())?;
            cache.set_state(next);
        }
        let before = probe_rows(&backend, &cache);
        let slot = g.usize_in(0, batch - 1);
        let t_len = g.usize_in(1, 9);
        let toks: Vec<i32> =
            (0..t_len).map(|_| g.usize_in(0, 15) as i32).collect();
        // reference: batched step() chain, lane `slot` only
        let mut ref_state = cache.state().clone();
        for &tok in &toks {
            let bt = IntTensor::new(&[batch], vec![tok; batch]).unwrap();
            let (_, next) = backend
                .step(&bt, &ref_state)
                .map_err(|e| e.to_string())?;
            ref_state = next;
        }
        let (_, lane) = backend
            .prefill(&IntTensor::new(&[t_len], toks).unwrap(), slot,
                     cache.state())
            .map_err(|e| e.to_string())?;
        cache.write_slot(slot, &lane).map_err(|e| e.to_string())?;
        let after = probe_rows(&backend, &cache);
        for s in 0..batch {
            if s == slot {
                continue;
            }
            rows_close(&before[s], &after[s],
                       &format!("prefill of slot {slot} drifted lane {s}"))?;
        }
        let mut ref_cache = BeliefStateCache::for_backend(&backend)
            .map_err(|e| e.to_string())?;
        ref_cache.set_state(ref_state);
        let want = probe_rows(&backend, &ref_cache);
        // the prefill ran a parallel scan (Blelloch), the reference a
        // sequential chain; the probe step then compounds the per-layer
        // 1e-5 conformance deviation through the full model once more,
        // hence the deliberately looser 1e-4 here
        for (i, (a, e)) in after[slot].iter().zip(&want[slot]).enumerate()
        {
            if !kla::testing::rel_close(*a, *e, 1e-4) {
                return Err(format!(
                    "prefilled slot {slot} != step chain at [{i}]: {a} \
                     vs {e}"));
            }
        }
        Ok(())
    });
}

#[test]
fn native_state_cache_restore_rejects_conv_kernel_mismatch() {
    // same layer count and state width, DIFFERENT conv kernel: the
    // beliefs validate, so before the conv-length check this panicked
    // inside copy_from_slice instead of returning the shape error
    let mk = |k: usize| {
        NativeBackend::seeded(
            &NativeLmConfig {
                vocab: 16,
                d_model: 8,
                n_layers: 2,
                n_state: 2,
                conv_kernel: k,
                ..Default::default()
            },
            1,
            2,
        )
    };
    let mut cache = BeliefStateCache::for_backend(&mk(3)).unwrap();
    // smaller kernel => shorter snapshot window => pre-fix, the layer-1
    // copy sliced past the end of snap.conv and panicked
    let foreign = BeliefStateCache::for_backend(&mk(2)).unwrap();
    let snap = foreign.snapshot(0);
    assert!(cache.restore(0, &snap).is_err(),
            "restore accepted a snapshot with a foreign conv window");
}

#[test]
fn native_state_cache_restore_rejects_wrong_shape() {
    let backend = NativeBackend::seeded(
        &NativeLmConfig {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_state: 2,
            conv_kernel: 3,
            ..Default::default()
        },
        1,
        2,
    );
    let other = NativeBackend::seeded(
        &NativeLmConfig {
            vocab: 16,
            d_model: 8,
            n_layers: 1, // wrong layer count
            n_state: 2,
            conv_kernel: 3,
            ..Default::default()
        },
        1,
        2,
    );
    let mut cache = BeliefStateCache::for_backend(&backend).unwrap();
    let foreign = BeliefStateCache::for_backend(&other).unwrap();
    let snap = foreign.snapshot(0);
    assert!(cache.restore(0, &snap).is_err(),
            "restore accepted a snapshot from a different model shape");
}

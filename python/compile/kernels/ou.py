"""Ornstein-Uhlenbeck prior and its exact discretisation (paper Eq. 7-8).

The continuous-time prior is dz = -a z dt + p dW.  Exact discretisation over
a step of size dt gives the Gaussian transition

    z_t | z_{t-1} ~ N( abar * z_{t-1},  pbar ),
    abar = exp(-a dt),      pbar = p^2 / (2a) * (1 - exp(-2 a dt)).

`abar`/`pbar` are coupled through the same (a, dt): the decay rate that
controls forgetting also controls how much process noise accumulates between
tokens -- the "multi-channel specialisation" of Section 4.1.

Raw (unconstrained) parameters are mapped to their constrained domains here
so every model variant shares one parameterisation:

    a  = softplus(a_raw) + A_MIN          (> 0, mean reversion rate)
    p  = softplus(p_raw)                  (>= 0, diffusion scale)
    dt = DT_LO + sigmoid(dt_raw) * (DT_HI - DT_LO)   (paper: [0.001, 0.1])
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.nn import softplus, sigmoid

A_MIN = 1e-4
DT_LO, DT_HI = 1e-3, 1e-1
PBAR_FLOOR = 1e-8


def constrain(a_raw: jnp.ndarray, p_raw: jnp.ndarray, dt_raw: jnp.ndarray):
    """Map unconstrained parameters to (a, p, dt) in their valid domains."""
    a = softplus(a_raw) + A_MIN
    p = softplus(p_raw)
    dt = DT_LO + sigmoid(dt_raw) * (DT_HI - DT_LO)
    return a, p, dt


def discretise(a: jnp.ndarray, p: jnp.ndarray, dt: jnp.ndarray):
    """Exact OU discretisation (Eq. 8).  Shapes broadcast elementwise.

    Returns (abar, pbar) with abar in (0, 1) and pbar >= PBAR_FLOOR when
    p > 0 (the floor keeps the Moebius recursion well-conditioned; with
    pbar == 0 exactly the recursion degenerates to the linear special case
    studied in the Fig. 6b ablation).
    """
    abar = jnp.exp(-a * dt)
    pbar = p * p / (2.0 * a) * (1.0 - jnp.exp(-2.0 * a * dt))
    return abar, pbar


def discretise_raw(a_raw, p_raw, dt_raw, *, process_noise: bool = True,
                   ou_exact: bool = True):
    """Full raw->(abar, pbar) pipeline with the two paper ablation switches.

    process_noise=False  -> pbar = 0 (Fig. 6b / Table 6: collapses the
                            Moebius recursion to a fixed-gate linear update).
    ou_exact=False       -> naive Euler discretisation abar = 1 - a*dt,
                            pbar = p^2 * dt (Fig. 3b: 'no OU discretisation'
                            ablation; less stable at depth).
    """
    a, p, dt = constrain(a_raw, p_raw, dt_raw)
    if ou_exact:
        abar, pbar = discretise(a, p, dt)
    else:
        abar = jnp.clip(1.0 - a * dt, 1e-4, 1.0)
        pbar = p * p * dt
    if not process_noise:
        pbar = jnp.zeros_like(pbar)
    return abar, pbar

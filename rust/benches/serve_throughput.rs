//! Serving throughput/latency: continuous batching vs batch-of-one, and
//! batching-window sensitivity — the L3 coordinator's own performance
//! characteristics (EXPERIMENTS.md §Perf / L3).

use kla::bench::Suite;
use kla::config::ServeConfig;
use kla::kla::NativeLmConfig;
use kla::runtime::{NativeBackend, Runtime};
use kla::serve::{serve, serve_native, Client, RequestOpts, StreamEvent};
use kla::util::Stats;

fn load_once(addr: &str, n_requests: usize, prompt_len: usize,
             max_new: usize) -> (f64, Stats) {
    load_once_opts(addr, n_requests, prompt_len, max_new,
                   &RequestOpts::default())
}

fn load_once_opts(addr: &str, n_requests: usize, prompt_len: usize,
                  max_new: usize, opts: &RequestOpts) -> (f64, Stats) {
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for i in 0..n_requests {
        let addr = addr.to_string();
        let mut opts = opts.clone();
        // per-request seed so sampled rows are reproducible run to run
        if opts.temperature.is_some() {
            opts.seed = Some(i as u64);
        }
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let prompt: Vec<i32> = (0..prompt_len)
                .map(|j| ((i * 13 + j) % 200) as i32)
                .collect();
            let r = c.request_opts(&prompt, max_new, &opts).unwrap();
            r.req("total_ms").unwrap().as_f64().unwrap()
        }));
    }
    let mut lat = Stats::new();
    for j in joins {
        lat.push(j.join().unwrap());
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let toks = (n_requests * max_new) as f64;
    (toks / wall_s, lat)
}

/// Time-to-first-token under the v2 streaming protocol: concurrent
/// streaming clients, each measuring submit -> first `token` event.
/// TTFT is the metric chunked scan prefill actually moves (a 64-token
/// prompt is one prefill call instead of 64 interleaved steps before
/// the first sample exists), so it gets its own row next to the
/// whole-request latency percentiles.
fn ttft_once(addr: &str, n_requests: usize, prompt_len: usize,
             max_new: usize) -> Stats {
    let mut joins = Vec::new();
    for i in 0..n_requests {
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let prompt: Vec<i32> = (0..prompt_len)
                .map(|j| ((i * 13 + j) % 200) as i32)
                .collect();
            let t0 = std::time::Instant::now();
            let mut ttft_ms = None;
            for ev in c
                .stream(&prompt, max_new, &RequestOpts::default())
                .unwrap()
            {
                if let StreamEvent::Token { index: 0, .. } = ev {
                    ttft_ms = Some(t0.elapsed().as_secs_f64() * 1e3);
                }
                // keep draining to the terminal event so the engine
                // finishes cleanly before the next load phase
            }
            ttft_ms
        }));
    }
    let mut ttft = Stats::new();
    let mut missing = 0usize;
    for j in joins {
        // a stream that ended without any token event (err / transport
        // failure) must not poison the percentile sort with a NaN —
        // count it out loud instead
        match j.join().unwrap() {
            Some(ms) => ttft.push(ms),
            None => missing += 1,
        }
    }
    if missing > 0 {
        println!("note: {missing} ttft stream(s) ended without a token");
    }
    ttft
}

fn main() {
    let mut suite = Suite::new("serve_throughput");

    // ---- native backend: always runs (no artifacts required) ----
    // prompt-heavy load (64-token prompts, 8 new tokens) so the chunked
    // scan prefill shows up: chunk=1 is the legacy token-per-iteration
    // baseline, chunk=64 consumes a whole prompt per prefill call
    for (slots, chunk, label) in
        [(8usize, 64usize, "native_batch8_chunk64"),
         (8, 1, "native_batch8_chunk1"),
         (1, 64, "native_batch1_chunk64")]
    {
        for window_us in [100u64, 1000] {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                backend: "native".into(),
                batch_window_us: window_us,
                max_new_tokens: 8,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let backend =
                NativeBackend::seeded(&NativeLmConfig::default(), 0, slots);
            let handle = serve_native(backend, &cfg).unwrap();
            let addr = handle.addr.clone();
            let _ = load_once(&addr, 2, 64, 2); // warm
            let (tps, lat) = load_once(&addr, 24, 64, 8);
            // streaming TTFT over the same 64-token prompts: chunk=1
            // pays one engine iteration per prompt token before the
            // first sample, chunk=64 one scan-prefill call
            let ttft = ttft_once(&addr, 8, 64, 8);
            let stats = handle.stop().unwrap();
            suite.metric_row(
                &format!("{label}/window{window_us}us"),
                vec![
                    ("tokens_per_s".into(), tps),
                    ("p50_ms".into(), lat.percentile(50.0)),
                    ("p99_ms".into(), lat.percentile(99.0)),
                    ("engine_step_ms".into(), stats.mean_step_ms()),
                    ("occupancy".into(),
                     stats.batch_occupancy.iter().sum::<f64>()
                         / stats.batch_occupancy.len().max(1) as f64),
                ],
            );
            // prefill throughput gets its own row, so the scan-prefill
            // win is measured separately from decode tokens/s
            suite.metric_row(
                &format!("{label}/window{window_us}us/prefill"),
                vec![
                    ("prefill_tok_s".into(),
                     stats.prefill_tokens_per_sec()),
                    ("decode_tok_s".into(), stats.tokens_per_sec()),
                    ("prefill_tokens".into(),
                     stats.prefill_tokens as f64),
                ],
            );
            // time-to-first-token through the streaming protocol — the
            // latency chunked prefill buys down for prompt-heavy load
            suite.metric_row(
                &format!("{label}/window{window_us}us/ttft"),
                vec![
                    ("ttft_p50_ms".into(), ttft.percentile(50.0)),
                    ("ttft_p99_ms".into(), ttft.percentile(99.0)),
                ],
            );
        }
    }

    // ---- sampling overhead: seeded temperature/top-p vs greedy ----
    // same load as native_batch8_chunk64/window1000us, but every request
    // samples (temperature 0.9, top_p 0.95, per-request seed), so the
    // per-lane softmax + nucleus cost shows up next to the greedy row
    {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            backend: "native".into(),
            batch_window_us: 1000,
            max_new_tokens: 8,
            prefill_chunk: 64,
            ..Default::default()
        };
        let backend =
            NativeBackend::seeded(&NativeLmConfig::default(), 0, 8);
        let handle = serve_native(backend, &cfg).unwrap();
        let addr = handle.addr.clone();
        let opts = RequestOpts {
            temperature: Some(0.9),
            top_p: Some(0.95),
            ..Default::default()
        };
        let _ = load_once_opts(&addr, 2, 64, 2, &opts); // warm
        let (tps, lat) = load_once_opts(&addr, 24, 64, 8, &opts);
        let stats = handle.stop().unwrap();
        suite.metric_row(
            "native_batch8_chunk64_sampled/window1000us",
            vec![
                ("tokens_per_s".into(), tps),
                ("p50_ms".into(), lat.percentile(50.0)),
                ("p99_ms".into(), lat.percentile(99.0)),
                ("engine_step_ms".into(), stats.mean_step_ms()),
                ("occupancy".into(),
                 stats.batch_occupancy.iter().sum::<f64>()
                     / stats.batch_occupancy.len().max(1) as f64),
            ],
        );
    }

    // ---- XLA artifact backend: skips without artifacts ----
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            println!("note: xla rows skipped (no artifacts): {e}");
            suite.finish();
            return;
        }
    };
    let init = rt.load("lm_kla_init").unwrap();
    let params = init.run(&[]).unwrap();

    for (artifact, label) in [("serve_kla_b8", "batch8"),
                              ("serve_kla_b1", "batch1")] {
        for window_us in [100u64, 1000] {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".into(),
                artifact: artifact.into(),
                batch_window_us: window_us,
                max_new_tokens: 8,
                ..Default::default()
            };
            let handle = serve(rt.dir().to_path_buf(), artifact.into(),
                               params.clone(), &cfg).unwrap();
            let addr = handle.addr.clone();
            // warm the engine (first step compiles nothing but touches
            // the executable)
            let _ = load_once(&addr, 2, 4, 2);
            let (tps, lat) = load_once(&addr, 24, 4, 8);
            let stats = handle.stop().unwrap();
            suite.metric_row(
                &format!("{label}/window{window_us}us"),
                vec![
                    ("tokens_per_s".into(), tps),
                    ("p50_ms".into(), lat.percentile(50.0)),
                    ("p99_ms".into(), lat.percentile(99.0)),
                    ("engine_step_ms".into(), stats.mean_step_ms()),
                    ("occupancy".into(),
                     stats.batch_occupancy.iter().sum::<f64>()
                         / stats.batch_occupancy.len().max(1) as f64),
                ],
            );
            suite.metric_row(
                &format!("{label}/window{window_us}us/prefill"),
                vec![
                    ("prefill_tok_s".into(),
                     stats.prefill_tokens_per_sec()),
                    ("decode_tok_s".into(), stats.tokens_per_sec()),
                    ("prefill_tokens".into(),
                     stats.prefill_tokens as f64),
                ],
            );
        }
    }
    suite.finish();
}

"""AOT bridge tests: manifest coherence, HLO emission, meta ABI."""

import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from compile.aot import build_role, to_hlo_text
from compile.specs import ArtifactSpec, default_specs, full_specs, manifest
from compile.models.lm import ModelConfig
from compile.train_step import OptConfig

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def tiny_spec(role, kind="kla"):
    m = ModelConfig(kind=kind, vocab=16, d_model=16, n_layers=1, n_state=2)
    return ArtifactSpec("test", kind, m, OptConfig(total_steps=10), 2, 8,
                        (role,))


class TestSpecs:
    def test_default_manifest_unique_names(self):
        names = [s.artifact_name(r) for s in default_specs()
                 for r in s.roles]
        assert len(names) == len(set(names))

    def test_full_superset(self):
        d = {s.base_name for s in manifest("default")}
        f = {s.base_name for s in manifest("full")}
        assert d < f

    def test_required_artifacts_present(self):
        names = {s.artifact_name(r) for s in default_specs()
                 for r in s.roles}
        for required in ("mad_kla_train", "mad_kla_eval", "mad_kla_init",
                         "mad_kla_nonoise_train", "mad_kla_noou_train",
                         "mqar_kla_d64_train", "a5_kla_l1_train",
                         "lm_hybrid_kla_train", "serve_kla_b8_decode",
                         "fig4_scan_t2048_logits", "mad_kla_variance"):
            assert required in names, required


class TestBuildRole:
    @pytest.mark.parametrize("role", ["init", "train", "eval", "score",
                                      "logits", "variance", "decode"])
    def test_lowering_produces_hlo(self, role):
        import jax
        spec = tiny_spec(role)
        fn, ex, imeta, ometa, _ = build_role(spec, role)
        text = to_hlo_text(jax.jit(fn).lower(*ex))
        assert "ENTRY" in text and "main" in text
        # input arity matches meta
        assert len(ex) == len(imeta)

    def test_train_meta_groups(self):
        spec = tiny_spec("train")
        _, ex, imeta, ometa, _ = build_role(spec, "train")
        groups = [d.get("group") for d in imeta]
        n_params = groups.count("params")
        assert groups.count("opt_m") == n_params
        assert groups.count("opt_v") == n_params
        assert [d["name"] for d in imeta[-4:]] == ["step", "tokens",
                                                   "targets", "mask"]
        assert ometa[0]["name"] == "loss"
        assert len(ometa) == 1 + 3 * n_params


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
class TestEmittedArtifacts:
    def test_manifest_files_exist(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            names = json.load(f)["artifacts"]
        assert len(names) >= 70
        for n in names:
            assert os.path.exists(os.path.join(ART, f"{n}.hlo.txt")), n
            assert os.path.exists(os.path.join(ART, f"{n}.meta.json")), n

    def test_meta_shapes_consistent(self):
        with open(os.path.join(ART, "mad_kla_train.meta.json")) as f:
            meta = json.load(f)
        assert meta["role"] == "train"
        assert meta["model"]["kind"] == "kla"
        toks = [d for d in meta["inputs"] if d["name"] == "tokens"][0]
        assert toks["shape"] == [meta["batch"], meta["seq"]]
        n_params = sum(1 for d in meta["inputs"]
                       if d.get("group") == "params")
        assert len(meta["outputs"]) == 1 + 3 * n_params

    def test_decode_meta_has_state(self):
        with open(os.path.join(ART, "serve_kla_b8_decode.meta.json")) as f:
            meta = json.load(f)
        assert [s["name"] for s in meta["state"]] == ["conv", "lam", "eta"]
        L = meta["model"]["n_layers"]
        assert meta["state"][1]["shape"][0] == L

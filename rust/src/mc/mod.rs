//! # mc — deterministic interleaving model checker (DESIGN.md §S19)
//!
//! A dependency-free, loom-style concurrency checker.  The concurrent
//! modules of this repo (`util::thread_pool`, the serve engine's
//! cancel flags, the server's `ConnSink` terminal latch) import their
//! sync primitives from [`mc::sync`](sync) instead of `std::sync`:
//!
//! - **normal builds** (`mc-shim` feature off): the re-exports ARE the
//!   `std::sync` types — same type identity, zero overhead;
//! - **model-check builds** (`cargo test --features mc-shim`): the
//!   re-exports are shims that route every acquire / release / load /
//!   store / park through a controlled scheduler, so a test can
//!   explore *every* interleaving of a small concurrent program up to
//!   a context-switch bound, or thousands of seeded random schedules.
//!
//! ## How an exploration runs
//!
//! [`sched::model`] re-runs a closure under a cooperative scheduler:
//! real OS threads, but exactly one is ever runnable — each shim
//! operation is a *scheduling point* where the running thread parks
//! and the scheduler picks who continues.  Two search policies:
//!
//! - [`sched::Policy::Dfs`] — bounded-exhaustive depth-first search
//!   over schedules, replaying a forced choice prefix and bounding
//!   *preemptions* (switching away from a runnable thread), the
//!   CHESS-style bound that finds most real bugs at 2 preemptions;
//! - [`sched::Policy::Pct`] — seeded PCT-style randomized schedules:
//!   random thread priorities plus `d` priority-change points, fully
//!   deterministic per seed so a failing seed is a pinned regression.
//!
//! A deadlock (no schedulable thread while unfinished threads exist —
//! which is also how a *lost wakeup* manifests), a panic on any model
//! thread, or a step-limit overrun aborts the execution and fails the
//! enclosing test with the schedule trace and seed.
//!
//! ## What is modelled
//!
//! Mutex acquire order, condvar wait/notify (FIFO, with *spurious
//! wakeups* for `wait_timeout` so timed waits stay live but bounded),
//! channel send/recv/disconnect, thread spawn/join, and atomic
//! access *interleavings*.  Memory orderings are accepted and
//! recorded but the model explores sequentially-consistent
//! interleavings only — ordering discipline is audited statically by
//! the `atomic-ordering` lint pass instead (DESIGN.md §S19).
//!
//! The invariant suites live in `mc::invariants` (compiled only under
//! `--features mc-shim`, test profile) and print one greppable
//! `model-check[<invariant>]: ...` line per policy for CI.

pub mod sync;
pub mod thread;

#[cfg(feature = "mc-shim")]
pub mod sched;

#[cfg(all(test, feature = "mc-shim"))]
mod invariants;

pub use sync::{channel, AtomicBool, AtomicUsize, Condvar, Mutex};

"""L2 model tests: shapes, causality, decode==parallel consistency,
ablation switches, KLA+ sampling, and the hybrid wiring."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.models.common import (causal_conv1d, conv_state_step,
                                   cross_entropy, flatten_params, l2norm,
                                   rmsnorm, sequence_logprob,
                                   token_accuracy, unflatten_params)
from compile.models.lm import (KINDS, ModelConfig, init_lm, lm_forward,
                               lm_forward_sampled, lm_variance)
from compile.models.decode import decode_init_state, decode_step

CFG = dict(vocab=32, d_model=32, n_layers=2, n_state=4)


def tiny_cfg(kind, **kw):
    return ModelConfig(kind=kind, **{**CFG, **kw})


class TestCommon:
    def test_flatten_roundtrip(self):
        cfg = tiny_cfg("kla")
        p = init_lm(cfg, 0)
        flat = flatten_params(p)
        p2 = unflatten_params(p, [a for _, a in flat])
        flat2 = flatten_params(p2)
        assert [n for n, _ in flat] == [n for n, _ in flat2]
        for (_, a), (_, b) in zip(flat, flat2):
            assert a is b

    def test_flatten_layer_order(self):
        """Zero-padded block names keep layer order under sorted keys."""
        cfg = tiny_cfg("kla", n_layers=12)
        p = init_lm(cfg, 0)
        names = [n for n, _ in flatten_params(p)]
        block_ids = []
        for n in names:
            if n.startswith("blocks."):
                block_ids.append(int(n.split(".")[1].split("_")[0]))
        assert block_ids == sorted(block_ids)

    def test_causal_conv_is_causal(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(1, 16, 4)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        y = causal_conv1d(x, w, b)
        x2 = x.at[0, 10].set(99.0)
        y2 = causal_conv1d(x2, w, b)
        np.testing.assert_allclose(np.asarray(y[0, :10]),
                                   np.asarray(y2[0, :10]), atol=1e-6)
        assert not np.allclose(np.asarray(y[0, 10]), np.asarray(y2[0, 10]))

    def test_conv_state_step_matches_parallel(self):
        rng = np.random.default_rng(1)
        B, T, D, K = 2, 12, 4, 4
        x = jnp.asarray(rng.normal(size=(B, T, D)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, D)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
        y_par = causal_conv1d(x, w, b)
        state = jnp.zeros((B, K - 1, D), jnp.float32)
        for t in range(T):
            y_t, state = conv_state_step(state, x[:, t], w, b)
            np.testing.assert_allclose(np.asarray(y_t),
                                       np.asarray(y_par[:, t]),
                                       rtol=1e-5, atol=1e-5)

    def test_cross_entropy_mask(self):
        logits = jnp.zeros((1, 4, 8), jnp.float32)
        tgt = jnp.zeros((1, 4), jnp.int32)
        full = cross_entropy(logits, tgt, jnp.ones((1, 4)))
        half = cross_entropy(logits, tgt,
                             jnp.asarray([[1.0, 1.0, 0.0, 0.0]]))
        np.testing.assert_allclose(float(full), float(half), rtol=1e-6)
        np.testing.assert_allclose(float(full), np.log(8), rtol=1e-5)

    def test_sequence_logprob(self):
        rng = np.random.default_rng(2)
        logits = jnp.asarray(rng.normal(size=(2, 4, 8)), jnp.float32)
        tgt = jnp.asarray(rng.integers(0, 8, (2, 4)), jnp.int32)
        mask = jnp.ones((2, 4), jnp.float32)
        lp = sequence_logprob(logits, tgt, mask)
        assert lp.shape == (2,)
        assert (np.asarray(lp) < 0).all()

    def test_token_accuracy(self):
        logits = jnp.eye(4)[None] * 10.0          # predicts identity
        tgt = jnp.asarray([[0, 1, 2, 0]], jnp.int32)
        correct, count = token_accuracy(logits, tgt, jnp.ones((1, 4)))
        assert float(count) == 4.0
        assert float(correct) == 3.0


class TestForward:
    @pytest.mark.parametrize("kind", list(KINDS))
    def test_shapes(self, kind):
        cfg = tiny_cfg(kind)
        p = init_lm(cfg, 0)
        toks = jnp.zeros((2, 16), jnp.int32)
        out = lm_forward(cfg, p, toks)
        assert out.shape == (2, 16, cfg.vocab)
        assert np.isfinite(np.asarray(out)).all()

    @pytest.mark.parametrize("kind", ["kla", "mamba", "gla", "gdn", "gpt",
                                      "hybrid_kla"])
    def test_causality(self, kind):
        """Changing token t must not change logits at positions < t."""
        cfg = tiny_cfg(kind)
        p = init_lm(cfg, 0)
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(0, 32, (1, 16)), jnp.int32)
        out1 = np.asarray(lm_forward(cfg, p, toks))
        toks2 = toks.at[0, 10].set((int(toks[0, 10]) + 1) % 32)
        out2 = np.asarray(lm_forward(cfg, p, toks2))
        np.testing.assert_allclose(out1[0, :10], out2[0, :10],
                                   rtol=1e-4, atol=1e-4)
        assert not np.allclose(out1[0, 10:], out2[0, 10:], atol=1e-4)

    def test_kla_impls_consistent_in_model(self):
        toks = jnp.asarray(np.arange(16)[None] % 32, jnp.int32)
        outs = []
        for impl in ("scan", "pallas", "ref"):
            cfg = tiny_cfg("kla", impl=impl)
            p = init_lm(cfg, 0)
            outs.append(np.asarray(lm_forward(cfg, p, toks)))
        np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)

    def test_ablations_change_output(self):
        toks = jnp.asarray(np.arange(16)[None] % 32, jnp.int32)
        base = np.asarray(lm_forward(tiny_cfg("kla"),
                                     init_lm(tiny_cfg("kla"), 0), toks))
        for kw in ({"process_noise": False}, {"ou_exact": False}):
            cfg = tiny_cfg("kla", **kw)
            out = np.asarray(lm_forward(cfg, init_lm(cfg, 0), toks))
            assert not np.allclose(base, out, atol=1e-5), kw

    def test_hybrid_last_block_is_kla(self):
        cfg = tiny_cfg("hybrid_kla", n_layers=3)
        p = init_lm(cfg, 0)
        kinds = [n.split("_", 1)[1] for n in sorted(p["blocks"])]
        assert kinds == ["gpt", "gpt", "kla"]

    def test_variance_positive(self):
        cfg = tiny_cfg("kla")
        p = init_lm(cfg, 0)
        toks = jnp.zeros((2, 16), jnp.int32)
        var = lm_variance(cfg, p, toks)
        assert var.shape == (2, 16)
        assert (np.asarray(var) > 0).all()

    def test_sampled_forward_varies_with_key(self):
        cfg = tiny_cfg("kla")
        p = init_lm(cfg, 0)
        toks = jnp.zeros((1, 8), jnp.int32)
        a = np.asarray(lm_forward_sampled(cfg, p, toks, jax.random.PRNGKey(0)))
        b = np.asarray(lm_forward_sampled(cfg, p, toks, jax.random.PRNGKey(1)))
        assert not np.allclose(a, b)
        assert np.isfinite(a).all()


class TestDecode:
    def test_decode_matches_parallel(self):
        """The O(1) recurrent path must reproduce the scan path token by
        token — this is the serving-correctness contract."""
        cfg = tiny_cfg("kla")
        p = init_lm(cfg, 0)
        rng = np.random.default_rng(4)
        B, T = 2, 12
        toks = jnp.asarray(rng.integers(0, 32, (B, T)), jnp.int32)
        full = np.asarray(lm_forward(cfg, p, toks))
        conv, lam, eta = decode_init_state(cfg, p, B)
        for t in range(T):
            logits, conv, lam, eta = decode_step(cfg, p, toks[:, t],
                                                 conv, lam, eta)
            np.testing.assert_allclose(np.asarray(logits), full[:, t],
                                       rtol=2e-3, atol=2e-3)

    def test_decode_state_shapes(self):
        cfg = tiny_cfg("kla", n_layers=3)
        p = init_lm(cfg, 0)
        conv, lam, eta = decode_init_state(cfg, p, 5)
        assert conv.shape == (3, 5, cfg.conv_kernel - 1, cfg.d_model)
        assert lam.shape == (3, 5, cfg.n_state, cfg.d_model)
        assert (np.asarray(lam) > 0).all()

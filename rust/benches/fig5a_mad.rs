//! Fig. 5a: MAD synthetic suite accuracy table across mixers.
//!
//! Paper: 6 tasks x {GDN, GLA, Mamba, mLSTM, KLA, KLA+}; ours drops mLSTM
//! (DESIGN.md §5) and scales epochs to the CPU budget.  Env knobs:
//! KLA_BENCH_STEPS (default 150), KLA_BENCH_SEEDS (default 1),
//! KLA_BENCH_MODELS (comma list).

use kla::bench::exp::{bench_seeds, bench_steps, have, train_mean_acc};
use kla::bench::Suite;
use kla::data::{task_by_name, MAD_TASKS};
use kla::runtime::Runtime;

fn main() {
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP fig5a: {e}");
            return;
        }
    };
    let steps = bench_steps(150);
    let seeds = bench_seeds(1);
    let models: Vec<String> = std::env::var("KLA_BENCH_MODELS")
        .map(|s| s.split(',').map(|x| x.to_string()).collect())
        .unwrap_or_else(|_| {
            ["kla", "kla_plus", "mamba", "gla", "gdn"]
                .iter().map(|s| s.to_string()).collect()
        });

    let mut suite = Suite::new("fig5a_mad");
    println!("MAD suite, {steps} steps x {seeds} seed(s)\n");
    for task_name in MAD_TASKS {
        let task = task_by_name(task_name).unwrap();
        for model in &models {
            let base = format!("mad_{model}");
            if !have(&rt, &base) {
                continue;
            }
            let (acc, step_ms) =
                train_mean_acc(&rt, &base, task.as_ref(), steps, seeds)
                    .unwrap();
            suite.metric_row(
                &format!("{task_name}/{model}"),
                vec![("acc".into(), acc), ("step_ms".into(), step_ms)],
            );
        }
    }
    suite.finish();
}

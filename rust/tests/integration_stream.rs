//! Protocol-v2 streaming integration: multiplexed sessions, per-token
//! events, and cancellation over the real TCP server on the pure-Rust
//! native backend (no artifacts — nothing here may SKIP; CI's
//! `stream-parity` step greps the result lines printed below).
//!
//! The acceptance invariants of the streaming redesign:
//!   - for any fixed seed, the concatenated `token` events of a
//!     streamed request are byte-identical to the legacy one-shot
//!     `tokens` array (and to the stream's own `done.tokens`);
//!   - two multiplexed requests on one connection receive correctly
//!     tagged, interleaved event streams;
//!   - cancelling an active request frees its slot for a queued request
//!     (the engine sweep runs before admit, so within one iteration);
//!   - a client that disconnects mid-generation is implicitly
//!     cancelled — the slot is reusable and the abandoned work shows up
//!     in the `cancelled` / `wasted_tokens` stats;
//!   - stop tokens and `max_new_tokens: 0` behave identically through
//!     the streaming path.

mod common;

use std::collections::HashMap;

use common::{native_cfg, small_lm, tokens_of};
use kla::runtime::NativeBackend;
use kla::serve::{serve_native, Client, RequestOpts, StreamEvent};

#[test]
fn native_stream_tokens_identical_to_one_shot() {
    // the headline parity invariant, for greedy AND seeded sampling,
    // across prompt shapes (empty / single / long)
    let backend = NativeBackend::seeded(&small_lm(), 17, 2);
    let handle = serve_native(backend, &native_cfg()).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let prompts: Vec<Vec<i32>> = vec![
        vec![],
        vec![3],
        (0..40).map(|i| (i * 7) % 32).collect(),
    ];
    let cases: Vec<(&str, RequestOpts)> = vec![
        ("greedy", RequestOpts::default()),
        ("sampled", RequestOpts {
            temperature: Some(0.9),
            top_p: Some(0.9),
            seed: Some(42),
            ..Default::default()
        }),
    ];
    for (pi, p) in prompts.iter().enumerate() {
        for (name, opts) in &cases {
            // legacy one-shot wrapper (stream-and-collect): its tokens
            // array is the engine-accumulated full reply
            let one = tokens_of(&c.request_opts(p, 6, opts).unwrap());
            assert_eq!(one.len(), 6);
            // explicit streaming: a fresh request under the same seed
            let mut streamed: Vec<i64> = Vec::new();
            let mut done: Option<Vec<i64>> = None;
            let mut started = false;
            let mut last_unc = -1.0;
            let mut done_unc = 0.0;
            for ev in c.stream(p, 6, opts).unwrap() {
                match ev {
                    StreamEvent::Start { queue_ms, .. } => {
                        assert!(!started, "start must come exactly once");
                        assert!(queue_ms >= 0.0);
                        started = true;
                    }
                    StreamEvent::Token { index, token, uncertainty, .. } => {
                        assert_eq!(index, streamed.len(),
                                   "token indices must be contiguous");
                        assert!(uncertainty > 0.0,
                                "every token carries its posterior");
                        last_unc = uncertainty;
                        streamed.push(token as i64);
                    }
                    StreamEvent::Done {
                        tokens, uncertainty, cancelled, ..
                    } => {
                        assert!(!cancelled);
                        done_unc = uncertainty;
                        done = Some(tokens.iter().map(|&t| t as i64)
                            .collect());
                    }
                    StreamEvent::Err { code, msg, .. } => {
                        panic!("unexpected err {code}: {msg}");
                    }
                }
            }
            assert!(started, "prompt {pi} ({name}): no start event");
            let done = done.expect("stream must end in done");
            // the acceptance bar: concatenated token events are byte-
            // identical to the one-shot tokens array (and to done.tokens)
            assert_eq!(streamed, done,
                       "prompt {pi} ({name}): token events != done.tokens");
            assert_eq!(streamed, one,
                       "prompt {pi} ({name}): streamed != one-shot");
            // the last token event's uncertainty IS the final reply's
            // (same post-step belief, read twice)
            assert!((last_unc - done_unc).abs() < 1e-9,
                    "prompt {pi} ({name}): uncertainty trajectory end \
                     {last_unc} != done {done_unc}");
        }
        println!("stream parity prompt {pi}: ok");
    }
    handle.stop().unwrap();
}

#[test]
fn native_stream_multiplex_two_requests_one_connection() {
    let backend = NativeBackend::seeded(&small_lm(), 23, 2);
    let handle = serve_native(backend, &native_cfg()).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let pa: Vec<i32> = (0..8).map(|i| (i * 3) % 32).collect();
    let pb: Vec<i32> = (0..5).map(|i| (i * 11) % 32).collect();
    // solo greedy references (deterministic per-lane on the native model)
    let ref_a = tokens_of(&c.request(&pa, 16).unwrap());
    let ref_b = tokens_of(&c.request(&pb, 16).unwrap());
    // both in flight at once on ONE connection
    let a = c.submit(&pa, 16, &RequestOpts::default()).unwrap();
    let b = c.submit(&pb, 16, &RequestOpts::default()).unwrap();
    assert_ne!(a, b);
    let mut toks: HashMap<u64, Vec<i64>> = HashMap::new();
    let mut dones: HashMap<u64, Vec<i64>> = HashMap::new();
    let mut token_order: Vec<u64> = Vec::new();
    while dones.len() < 2 {
        match c.next_event().unwrap() {
            StreamEvent::Token { id, index, token, .. } => {
                let v = toks.entry(id).or_default();
                assert_eq!(index, v.len(),
                           "indices are contiguous PER REQUEST");
                v.push(token as i64);
                token_order.push(id);
            }
            StreamEvent::Done { id, tokens, cancelled, .. } => {
                assert!(!cancelled);
                dones.insert(id,
                             tokens.iter().map(|&t| t as i64).collect());
            }
            StreamEvent::Start { .. } => {}
            StreamEvent::Err { code, msg, .. } => {
                panic!("unexpected err {code}: {msg}");
            }
        }
    }
    // correctly tagged: each id's events reproduce its own solo run,
    // unpolluted by the other stream sharing the connection
    assert_eq!(toks[&a], ref_a, "request a picked up foreign tokens");
    assert_eq!(toks[&b], ref_b, "request b picked up foreign tokens");
    assert_eq!(dones[&a], ref_a);
    assert_eq!(dones[&b], ref_b);
    // and the two streams really interleaved on the wire (both were in
    // the same batch, so b's first token lands before a's last)
    let first_b = token_order.iter().position(|&i| i == b).unwrap();
    let last_a = token_order.iter().rposition(|&i| i == a).unwrap();
    assert!(first_b < last_a,
            "event streams never interleaved: {token_order:?}");
    let stats = handle.stop().unwrap();
    assert_eq!(stats.requests, 4);
    println!("stream multiplex tagging: ok");
}

#[test]
fn native_stream_cancel_frees_slot_for_queued_request() {
    // ONE slot: request a would decode 10M tokens for minutes; b is
    // queued behind it.  Cancelling a must free the slot (the engine
    // sweep runs before admit, so b is admitted within one iteration) —
    // b completing AT ALL is the proof, no timing assumptions needed.
    let backend = NativeBackend::seeded(&small_lm(), 31, 1);
    let mut cfg = native_cfg();
    cfg.max_new_limit = 100_000_000;
    let handle = serve_native(backend, &cfg).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let a = c.submit(&[1, 2, 3], 10_000_000,
                     &RequestOpts::default()).unwrap();
    // wait until a is actively generating
    loop {
        match c.next_event().unwrap() {
            StreamEvent::Token { id, .. } if id == a => break,
            StreamEvent::Err { code, msg, .. } => {
                panic!("unexpected err {code}: {msg}");
            }
            _ => {}
        }
    }
    let b = c.submit(&[4, 5], 3, &RequestOpts::default()).unwrap();
    let ack = c.cancel(a).unwrap();
    assert!(ack.req("ok").unwrap().as_bool().unwrap(),
            "cancel must find the active request: {ack:?}");
    // drain both streams to their terminal events
    let mut a_done: Option<(Vec<i64>, bool)> = None;
    let mut b_done: Option<(Vec<i64>, bool)> = None;
    let mut b_streamed: Vec<i64> = Vec::new();
    while a_done.is_none() || b_done.is_none() {
        match c.next_event().unwrap() {
            StreamEvent::Done { id, tokens, cancelled, .. } => {
                let toks = tokens.iter().map(|&t| t as i64).collect();
                if id == a {
                    a_done = Some((toks, cancelled));
                } else if id == b {
                    b_done = Some((toks, cancelled));
                }
            }
            StreamEvent::Token { id, token, .. } if id == b => {
                b_streamed.push(token as i64);
            }
            StreamEvent::Err { code, msg, .. } => {
                panic!("unexpected err {code}: {msg}");
            }
            _ => {}
        }
    }
    let (a_tokens, a_cancelled) = a_done.unwrap();
    assert!(a_cancelled, "a's terminal done must be cancelled: true");
    assert!(!a_tokens.is_empty(), "a was generating when cancelled");
    assert!(a_tokens.len() < 10_000_000, "a must not run to max_new");
    let (b_tokens, b_cancelled) = b_done.unwrap();
    assert!(!b_cancelled);
    assert_eq!(b_tokens.len(), 3, "queued b must complete on a's slot");
    assert_eq!(b_streamed, b_tokens);
    // double-cancel of a finished id is a clean no-op
    let ack2 = c.cancel(a).unwrap();
    assert!(!ack2.req("ok").unwrap().as_bool().unwrap());
    // the abandoned work is accounted: a's decoded tokens are wasted,
    // only b's are delivered output
    let live = c.stats().unwrap();
    assert_eq!(live.req("cancelled").unwrap().as_usize().unwrap(), 1);
    assert_eq!(live.req("wasted_tokens").unwrap().as_usize().unwrap(),
               a_tokens.len());
    let stats = handle.stop().unwrap();
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.wasted_tokens, a_tokens.len());
    assert_eq!(stats.tokens_out, 3);
    println!("stream cancel frees slot: ok");
}

#[test]
fn native_stream_disconnect_mid_generation_frees_slot() {
    // regression for the dead-reply-channel leak: a client that
    // disconnects mid-generation used to leave the engine decoding to
    // max_new into the void.  ONE slot + a 10M-token request: the
    // second connection's request can only complete if the disconnect
    // implicitly cancelled the first and freed its slot.
    let backend = NativeBackend::seeded(&small_lm(), 37, 1);
    let mut cfg = native_cfg();
    cfg.max_new_limit = 100_000_000;
    let handle = serve_native(backend, &cfg).unwrap();
    {
        let mut c1 = Client::connect(&handle.addr).unwrap();
        let a = c1.submit(&[5, 6], 10_000_000,
                          &RequestOpts::default()).unwrap();
        loop {
            match c1.next_event().unwrap() {
                StreamEvent::Token { id, .. } if id == a => break,
                StreamEvent::Err { code, msg, .. } => {
                    panic!("unexpected err {code}: {msg}");
                }
                _ => {}
            }
        }
        // c1 drops here: the connection closes mid-generation
    }
    let mut c2 = Client::connect(&handle.addr).unwrap();
    let r = c2.request(&[1, 2, 3], 3).unwrap();
    assert_eq!(tokens_of(&r).len(), 3,
               "slot was not reused after client disconnect");
    // the abandoned request is visible in the stats counters
    let live = c2.stats().unwrap();
    assert_eq!(live.req("cancelled").unwrap().as_usize().unwrap(), 1);
    assert!(live.req("wasted_tokens").unwrap().as_usize().unwrap() >= 1,
            "the disconnected request decoded at least one token");
    let stats = handle.stop().unwrap();
    assert_eq!(stats.cancelled, 1);
    assert!(stats.wasted_tokens >= 1);
    assert_eq!(stats.tokens_out, 3,
               "only the delivered request counts as output");
    println!("stream disconnect slot reuse: ok");
}

#[test]
fn native_stream_stop_token_and_prefill_only() {
    let backend = NativeBackend::seeded(&small_lm(), 13, 2);
    let handle = serve_native(backend, &native_cfg()).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let prompt = vec![2, 4, 6];
    let full = tokens_of(&c.request(&prompt, 8).unwrap());
    assert_eq!(full.len(), 8);
    // stop on a token the greedy continuation is known to produce
    let stop = full[3] as i32;
    let first = full.iter().position(|&t| t == stop as i64).unwrap();
    let opts = RequestOpts {
        stop_tokens: Some(vec![stop]),
        ..Default::default()
    };
    let mut streamed: Vec<i64> = Vec::new();
    let mut done: Option<Vec<i64>> = None;
    for ev in c.stream(&prompt, 8, &opts).unwrap() {
        match ev {
            StreamEvent::Token { token, .. } => {
                streamed.push(token as i64);
            }
            StreamEvent::Done { tokens, .. } => {
                done = Some(tokens.iter().map(|&t| t as i64).collect());
            }
            StreamEvent::Start { .. } => {}
            StreamEvent::Err { code, msg, .. } => {
                panic!("unexpected err {code}: {msg}");
            }
        }
    }
    // the stream ends AT the stop token (included) — no trailing events
    assert_eq!(streamed, full[..=first].to_vec());
    assert_eq!(done.unwrap(), streamed);
    // max_new 0 through the streaming path: start + done only, empty
    // tokens, the prompt's belief uncertainty still reported
    let prefill_prompt: Vec<i32> = (0..20).map(|i| i % 32).collect();
    let events: Vec<StreamEvent> = c
        .stream(&prefill_prompt, 0, &RequestOpts::default())
        .unwrap()
        .collect();
    assert_eq!(events.len(), 2,
               "expected start + done only: {events:?}");
    assert!(matches!(events[0], StreamEvent::Start { .. }));
    let StreamEvent::Done { ref tokens, uncertainty, cancelled, .. } =
        events[1]
    else {
        panic!("terminal event must be done: {:?}", events[1]);
    };
    assert!(tokens.is_empty());
    assert!(!cancelled);
    assert!(uncertainty > 0.0);
    handle.stop().unwrap();
    println!("stream stop/max_new=0: ok");
}

#[test]
fn native_stream_duplicate_id_and_inflight_cap() {
    use std::io::{BufRead, Write};

    fn send_line(w: &mut std::net::TcpStream, line: &str) {
        w.write_all(line.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        w.flush().unwrap();
    }

    let backend = NativeBackend::seeded(&small_lm(), 3, 2);
    let mut cfg = native_cfg();
    cfg.max_inflight = 2;
    let handle = serve_native(backend, &cfg).unwrap();
    // raw socket so the wire ids are under test control
    let stream = std::net::TcpStream::connect(&handle.addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = std::io::BufReader::new(stream);
    // ids 7 and 8 run long enough (1000 tokens) to still be in flight
    // while the two rejected lines are parsed microseconds later
    send_line(&mut w,
              r#"{"id": 7, "prompt": [1, 2, 3], "max_new_tokens": 1000}"#);
    send_line(&mut w, r#"{"id": 7, "prompt": [4], "max_new_tokens": 1}"#);
    send_line(&mut w,
              r#"{"id": 8, "prompt": [5], "max_new_tokens": 1000}"#);
    send_line(&mut w, r#"{"id": 9, "prompt": [6], "max_new_tokens": 1}"#);
    // scan the multiplexed reply stream: amid id-7/id-8 events we must
    // find the duplicate-id error (echoing id 7) and the
    // too-many-inflight error (echoing id 9)
    let mut saw_dup = false;
    let mut saw_cap = false;
    let mut done = 0;
    while done < 2 {
        let mut line = String::new();
        assert!(r.read_line(&mut line).unwrap() > 0, "server hung up");
        let j = kla::util::json::parse(line.trim()).unwrap();
        if let Some(e) = j.get("err") {
            let code = e.req("code").unwrap().as_str().unwrap();
            let id = j.req("id").unwrap().as_i64().unwrap();
            match code {
                "duplicate-id" => {
                    assert_eq!(id, 7);
                    saw_dup = true;
                }
                "too-many-inflight" => {
                    assert_eq!(id, 9);
                    saw_cap = true;
                }
                other => panic!("unexpected err code {other}: {j:?}"),
            }
        } else if let Some(ev) = j.get("event") {
            if ev.as_str().unwrap_or("") == "done" {
                done += 1;
            }
        }
    }
    assert!(saw_dup, "duplicate id 7 was not rejected");
    assert!(saw_cap, "in-flight cap was not enforced");
    let stats = handle.stop().unwrap();
    // only the two accepted requests ever reached the engine
    assert_eq!(stats.requests, 2);
    println!("stream id rules (duplicate / in-flight cap): ok");
}

// Known-bad fixture for the `panic` pass.  Never compiled — only
// `include_str!`-ed by rust/src/lint/panic_free.rs tests.

fn hot_path(v: &[i32], m: &std::sync::Mutex<i32>) -> i32 {
    let first = v.first().unwrap();
    let guard = m.lock().expect("poisoned");
    if v.is_empty() {
        panic!("empty batch");
    }
    if *guard < 0 {
        todo!();
    }
    let x = v[0];
    let tail = &v[1..];
    first + x + tail.len() as i32
}

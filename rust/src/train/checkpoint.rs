//! Binary checkpoint format for model parameters.
//!
//! Layout (little-endian):
//!   magic "KLACKPT1" | u32 count |
//!   per array: u32 dtype (0=f32, 1=i32) | u32 ndim | u64 dims... |
//!              raw data bytes
//! Array order is the artifact param order (the flatten ABI), so a
//! checkpoint is valid exactly for artifacts sharing the base config.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::Value;
use crate::tensor::{IntTensor, Tensor};

const MAGIC: &[u8; 8] = b"KLACKPT1";

pub fn path_for(dir: &str, base: &str) -> PathBuf {
    Path::new(dir).join(format!("{base}.ckpt"))
}

pub fn save(dir: &str, base: &str, params: &[Value]) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = path_for(dir, base);
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for v in params {
        match v {
            Value::F32(t) => {
                f.write_all(&0u32.to_le_bytes())?;
                write_shape(&mut f, t.shape())?;
                for x in t.data() {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            Value::I32(t) => {
                f.write_all(&1u32.to_le_bytes())?;
                write_shape(&mut f, t.shape())?;
                for x in t.data() {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    f.flush()?;
    crate::log_info!("checkpoint saved to {}", path.display());
    Ok(path)
}

pub fn load(path: &Path) -> Result<Vec<Value>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a KLA checkpoint", path.display());
    }
    let count = read_u32(&mut f)? as usize;
    if count > 100_000 {
        bail!("implausible array count {count}");
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let dtype = read_u32(&mut f)?;
        let ndim = read_u32(&mut f)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            dims.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = dims.iter().product();
        match dtype {
            0 => {
                let mut data = vec![0f32; n];
                let mut buf = vec![0u8; n * 4];
                f.read_exact(&mut buf)?;
                for (i, c) in buf.chunks_exact(4).enumerate() {
                    data[i] = f32::from_le_bytes(c.try_into().unwrap());
                }
                out.push(Value::F32(Tensor::new(&dims, data)?));
            }
            1 => {
                let mut data = vec![0i32; n];
                let mut buf = vec![0u8; n * 4];
                f.read_exact(&mut buf)?;
                for (i, c) in buf.chunks_exact(4).enumerate() {
                    data[i] = i32::from_le_bytes(c.try_into().unwrap());
                }
                out.push(Value::I32(IntTensor::new(&dims, data)?));
            }
            d => bail!("unknown dtype tag {d}"),
        }
    }
    Ok(out)
}

fn write_shape<W: Write>(f: &mut W, shape: &[usize]) -> Result<()> {
    f.write_all(&(shape.len() as u32).to_le_bytes())?;
    for &d in shape {
        f.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

fn read_u32<R: Read>(f: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("kla_ckpt_test");
        let dir = dir.to_str().unwrap();
        let params = vec![
            Value::F32(Tensor::new(&[2, 3],
                                   vec![1.0, -2.5, 3.0, 0.0, 9.9, -0.1])
                .unwrap()),
            Value::I32(IntTensor::new(&[4], vec![1, -2, 3, 4]).unwrap()),
            Value::F32(Tensor::scalar(42.0)),
        ];
        let path = save(dir, "unit_test", &params).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded[0].as_f32().unwrap(), params[0].as_f32().unwrap());
        assert_eq!(loaded[1].as_i32().unwrap().data(), &[1, -2, 3, 4]);
        assert_eq!(loaded[2].as_f32().unwrap().item().unwrap(), 42.0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir();
        let path = dir.join("kla_ckpt_garbage");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}

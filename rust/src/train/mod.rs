//! Training orchestrator: drives `{base}_train` artifacts over task
//! generators, with periodic evaluation, metric logging, and checkpoints.

pub mod checkpoint;
pub mod metrics;

use anyhow::{anyhow, Result};

use crate::config::TrainConfig;
use crate::data::TaskGen;
use crate::runtime::{EvalResult, Runtime, TrainSession};
use crate::util::{Pcg64, Stats, Timer};
pub use metrics::MetricLog;

/// Outcome of a full training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub base: String,
    pub task: String,
    pub steps: usize,
    pub final_loss: f32,
    pub eval: EvalResult,
    pub step_ms: Vec<f64>,
    pub losses: Vec<(usize, f32)>,
    pub evals: Vec<(usize, f64)>, // (step, accuracy)
}

impl TrainOutcome {
    pub fn accuracy(&self) -> f64 {
        self.eval.accuracy()
    }

    pub fn mean_step_ms(&self) -> f64 {
        let mut s = Stats::new();
        for &x in &self.step_ms {
            s.push(x);
        }
        s.mean()
    }
}

/// Train `base` on `task` for `cfg.steps` steps; eval on held-out batches
/// from an independent RNG stream.
pub fn run(rt: &Runtime, cfg: &TrainConfig, task: &dyn TaskGen)
           -> Result<TrainOutcome> {
    let mut session = TrainSession::new(rt, &cfg.artifact)?;
    let (b, t) = session.batch_shape();
    let mut train_rng = Pcg64::seeded(cfg.seed.wrapping_mul(2) + 1);
    let mut eval_rng_proto = Pcg64::seeded(0xE7A1_0000 ^ cfg.seed);

    let mut log = MetricLog::new(&format!("{}_{}", cfg.artifact, task.name()));
    let mut step_ms = Vec::with_capacity(cfg.steps);
    let mut losses = Vec::new();
    let mut evals = Vec::new();
    let mut final_loss = f32::NAN;

    for step in 0..cfg.steps {
        let batch = task.batch(&mut train_rng, b, t);
        let timer = Timer::start();
        let loss = session.train_step(&batch)?;
        step_ms.push(timer.elapsed_ms());
        final_loss = loss;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            crate::log_info!("{} step {step}/{} loss {loss:.4}",
                             cfg.artifact, cfg.steps);
            log.scalar("loss", step as f64, loss as f64);
            losses.push((step, loss));
        }
        let is_eval_step = cfg.eval_every > 0
            && (step + 1) % cfg.eval_every == 0;
        if is_eval_step {
            let acc = evaluate(&session, task, &mut eval_rng_proto.split(
                step as u64), cfg.eval_batches)?;
            log.scalar("accuracy", step as f64, acc.accuracy());
            evals.push((step, acc.accuracy()));
            crate::log_info!("{} step {step} eval acc {:.4} loss {:.4}",
                             cfg.artifact, acc.accuracy(), acc.mean_loss());
            if let Some(target) = cfg.target_accuracy {
                if acc.accuracy() >= target {
                    crate::log_info!("{} hit target accuracy {target} at \
                                      step {step}", cfg.artifact);
                    break;
                }
            }
        }
    }

    // final eval on a fixed stream
    let eval = evaluate(&session, task, &mut Pcg64::seeded(0xE7A1),
                        cfg.eval_batches.max(4))?;
    if let Some(dir) = &cfg.checkpoint_dir {
        checkpoint::save(dir, &cfg.artifact, session.params())?;
    }
    log.flush()?;
    Ok(TrainOutcome {
        base: cfg.artifact.clone(),
        task: task.name().to_string(),
        steps: session.step_count(),
        final_loss,
        eval,
        step_ms,
        losses,
        evals,
    })
}

fn evaluate(session: &TrainSession, task: &dyn TaskGen, rng: &mut Pcg64,
            batches: usize) -> Result<EvalResult> {
    let (b, t) = session.batch_shape();
    let mut total = EvalResult::default();
    for _ in 0..batches.max(1) {
        let batch = task.batch(rng, b, t);
        total.merge(session.eval_batch(&batch)?);
    }
    if total.count == 0.0 {
        return Err(anyhow!("evaluation saw no supervised positions"));
    }
    Ok(total)
}

/// Public eval entry used by benches after external training.
pub fn evaluate_session(session: &TrainSession, task: &dyn TaskGen,
                        seed: u64, batches: usize) -> Result<EvalResult> {
    evaluate(session, task, &mut Pcg64::seeded(seed), batches)
}

"""Kernel correctness: ref (oracle) vs scan vs pallas, plus the pure-Python
oracle's-oracle, under hypothesis-driven shape/parameter sweeps.

This is the L1 correctness gate: assert_allclose against ref.py across
shapes, dtypes-compatible ranges, and degenerate parameter regimes.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (kla_filter, kla_filter_ref_python,
                             kla_posterior_moments)
from compile.kernels.scan import mobius_prefix_scan, affine_prefix_scan
from compile.kernels.ou import constrain, discretise, discretise_raw


def make_inputs(rng, B, T, N, D, lam_v_lo=0.1, lam_v_hi=2.0):
    return dict(
        k=rng.normal(size=(B, T, N)).astype("f4"),
        q=rng.normal(size=(B, T, N)).astype("f4"),
        v=rng.normal(size=(B, T, D)).astype("f4"),
        lam_v=rng.uniform(lam_v_lo, lam_v_hi, size=(B, T, D)).astype("f4"),
        abar=rng.uniform(0.7, 0.999, size=(N, D)).astype("f4"),
        pbar=rng.uniform(1e-3, 0.2, size=(N, D)).astype("f4"),
        lam0=rng.uniform(0.5, 2.0, size=(N, D)).astype("f4"),
        eta0=rng.normal(size=(N, D)).astype("f4") * 0.1,
    )


def run_impl(inp, impl):
    return kla_filter(inp["k"], inp["q"], inp["v"], inp["lam_v"],
                      inp["abar"], inp["pbar"], inp["lam0"], inp["eta0"],
                      impl=impl)


class TestImplsAgree:
    @pytest.mark.parametrize("impl", ["scan", "pallas"])
    @pytest.mark.parametrize("B,T,N,D", [(1, 8, 2, 4), (2, 64, 4, 8),
                                         (3, 33, 8, 16), (1, 128, 1, 1)])
    def test_matches_ref(self, impl, B, T, N, D):
        rng = np.random.default_rng(B * 1000 + T)
        inp = make_inputs(rng, B, T, N, D)
        ref = run_impl(inp, "ref")
        out = run_impl(inp, impl)
        for r, o, name in zip(ref, out, ("lam", "eta", "y")):
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=3e-4, atol=3e-5, err_msg=name)

    def test_ref_matches_pure_python(self):
        rng = np.random.default_rng(7)
        inp = make_inputs(rng, 1, 12, 3, 5)
        ref = run_impl(inp, "ref")
        py = kla_filter_ref_python(inp["k"][0], inp["q"][0], inp["v"][0],
                                   inp["lam_v"][0], inp["abar"], inp["pbar"],
                                   inp["lam0"], inp["eta0"])
        for r, p in zip(ref, py):
            np.testing.assert_allclose(np.asarray(r[0]), p, rtol=1e-5,
                                       atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(B=st.integers(1, 3), T=st.integers(1, 96), N=st.integers(1, 8),
           D=st.integers(1, 12), seed=st.integers(0, 10_000))
    def test_hypothesis_scan_vs_ref(self, B, T, N, D, seed):
        rng = np.random.default_rng(seed)
        inp = make_inputs(rng, B, T, N, D)
        ref = run_impl(inp, "ref")
        out = run_impl(inp, "scan")
        for r, o in zip(ref, out):
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=5e-4, atol=5e-5)

    @settings(max_examples=8, deadline=None)
    @given(T=st.integers(1, 64), N=st.integers(1, 4), D=st.integers(1, 8),
           seed=st.integers(0, 1000))
    def test_hypothesis_pallas_vs_ref(self, T, N, D, seed):
        rng = np.random.default_rng(seed)
        inp = make_inputs(rng, 1, T, N, D)
        ref = run_impl(inp, "ref")
        out = run_impl(inp, "pallas")
        for r, o in zip(ref, out):
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=5e-4, atol=5e-5)


class TestDegenerateRegimes:
    def test_zero_process_noise_is_linear(self):
        """pbar=0 collapses the Moebius recursion to a fixed-gate linear
        update (Fig. 6b): lam becomes input-independent of history scaling."""
        rng = np.random.default_rng(0)
        inp = make_inputs(rng, 1, 32, 2, 4)
        inp["pbar"] = np.zeros_like(inp["pbar"])
        ref = run_impl(inp, "ref")
        out = run_impl(inp, "scan")
        for r, o in zip(ref, out):
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=1e-4, atol=1e-5)

    def test_precision_positive_and_bounded(self):
        """Nonzero pbar caps accumulated precision: lam stays positive and
        below the no-noise accumulation (paper Section 5.6 stability)."""
        rng = np.random.default_rng(1)
        inp = make_inputs(rng, 1, 256, 2, 4, lam_v_lo=0.5, lam_v_hi=5.0)
        lam, _, _ = run_impl(inp, "scan")
        lam = np.asarray(lam)
        assert (lam > 0).all()
        inp0 = dict(inp, pbar=np.zeros_like(inp["pbar"]))
        lam_nonoise, _, _ = run_impl(inp0, "scan")
        # with noise, final precision must be strictly smaller (fading memory)
        assert (np.asarray(lam)[0, -1] <= np.asarray(lam_nonoise)[0, -1] + 1e-3).all()

    def test_high_value_precision_dominates(self):
        """A token with huge precision should pull mu towards v/k."""
        N, D = 1, 1
        T = 8
        k = np.ones((1, T, N), "f4")
        q = np.ones((1, T, N), "f4")
        v = np.zeros((1, T, D), "f4")
        v[0, -1, 0] = 5.0
        lam_v = np.full((1, T, D), 1e-3, "f4")
        lam_v[0, -1, 0] = 1e5
        abar = np.full((N, D), 0.9, "f4")
        pbar = np.full((N, D), 0.01, "f4")
        lam, eta, y = kla_filter(k, q, v, lam_v, abar, pbar,
                                 np.ones((N, D), "f4"),
                                 np.zeros((N, D), "f4"), impl="ref")
        assert abs(float(y[0, -1, 0]) - 5.0) < 0.05

    def test_variance_decreases_with_evidence(self):
        """Posterior variance 1/lam shrinks as consistent evidence arrives
        (the Fig. 5b mechanism)."""
        rng = np.random.default_rng(3)
        inp = make_inputs(rng, 1, 64, 2, 4, lam_v_lo=1.0, lam_v_hi=1.5)
        lam, eta, _ = run_impl(inp, "ref")
        _, y_var = kla_posterior_moments(lam, eta, jnp.asarray(inp["q"]))
        y_var = np.asarray(y_var)[0].mean(-1)
        assert y_var[-1] < y_var[0]


class TestScanPrimitives:
    def test_mobius_scan_matches_sequential(self):
        rng = np.random.default_rng(5)
        B, T, N, D = 2, 50, 3, 4
        phi = rng.uniform(0.01, 2.0, (B, T, N, D)).astype("f4")
        abar = rng.uniform(0.8, 0.99, (N, D)).astype("f4")
        pbar = rng.uniform(0.001, 0.1, (N, D)).astype("f4")
        lam0 = np.ones((N, D), "f4")
        lam = np.asarray(mobius_prefix_scan(phi, abar, pbar, lam0))
        # sequential reference
        cur = np.broadcast_to(lam0, (B, N, D)).copy()
        for t in range(T):
            rho = 1.0 / (abar**2 + pbar * cur)
            cur = rho * cur + phi[:, t]
            np.testing.assert_allclose(lam[:, t], cur, rtol=3e-4, atol=3e-5)

    def test_affine_scan_matches_sequential(self):
        rng = np.random.default_rng(6)
        B, T, N, D = 2, 40, 2, 3
        f = rng.uniform(0.5, 0.99, (B, T, N, D)).astype("f4")
        b = rng.normal(size=(B, T, N, D)).astype("f4")
        init = rng.normal(size=(N, D)).astype("f4")
        eta = np.asarray(affine_prefix_scan(f, b, init))
        cur = np.broadcast_to(init, (B, N, D)).copy()
        for t in range(T):
            cur = f[:, t] * cur + b[:, t]
            np.testing.assert_allclose(eta[:, t], cur, rtol=1e-4, atol=1e-5)

    def test_long_sequence_stability(self):
        """T=4096 prefix products stay finite thanks to the combine-time
        renormalisation (scale invariance of Moebius maps)."""
        rng = np.random.default_rng(8)
        inp = make_inputs(rng, 1, 4096, 2, 2, lam_v_lo=0.5, lam_v_hi=4.0)
        lam, eta, y = run_impl(inp, "scan")
        assert np.isfinite(np.asarray(lam)).all()
        assert np.isfinite(np.asarray(y)).all()
        ref = run_impl(inp, "ref")
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref[2]),
                                   rtol=2e-3, atol=2e-4)


class TestOU:
    def test_discretise_limits(self):
        a = jnp.asarray([[1.0]]); p = jnp.asarray([[0.5]])
        abar, pbar = discretise(a, p, jnp.asarray([[0.01]]))
        assert 0.98 < float(abar.ravel()[0]) < 1.0
        # stationary variance p^2/(2a) reached as dt -> inf
        abar2, pbar2 = discretise(a, p, jnp.asarray([[100.0]]))
        np.testing.assert_allclose(float(pbar2.ravel()[0]), 0.5**2 / 2, rtol=1e-5)
        assert float(abar2.ravel()[0]) < 1e-10

    def test_decay_noise_coupling(self):
        """Faster decay (larger a) => abar smaller AND pbar saturates faster
        — the coupled specialisation of Section 4.1."""
        p = jnp.full((1, 1), 1.0)
        dt = jnp.full((1, 1), 0.05)
        ab_slow, pb_slow = discretise(jnp.full((1, 1), 0.5), p, dt)
        ab_fast, pb_fast = discretise(jnp.full((1, 1), 8.0), p, dt)
        assert float(ab_fast.ravel()[0]) < float(ab_slow.ravel()[0])
        # fraction of stationary variance reached is higher for fast decay
        frac_slow = float(pb_slow.ravel()[0]) / (1.0 / (2 * 0.5))
        frac_fast = float(pb_fast.ravel()[0]) / (1.0 / (2 * 8.0))
        assert frac_fast > frac_slow

    def test_constrain_domains(self):
        raw = jnp.asarray(np.linspace(-10, 10, 21), jnp.float32)
        a, p, dt = constrain(raw, raw, raw)
        assert (np.asarray(a) > 0).all()
        assert (np.asarray(p) >= 0).all()
        assert (np.asarray(dt) >= 1e-3 - 1e-9).all()
        assert (np.asarray(dt) <= 0.1 + 1e-9).all()

    def test_ablation_switches(self):
        raw = jnp.zeros((2, 3), jnp.float32)
        _, pbar = discretise_raw(raw, raw, raw, process_noise=False)
        assert (np.asarray(pbar) == 0).all()
        ab_e, _ = discretise_raw(raw, raw, raw, ou_exact=False)
        ab_x, _ = discretise_raw(raw, raw, raw, ou_exact=True)
        assert not np.allclose(np.asarray(ab_e), np.asarray(ab_x))


class TestGradients:
    def test_pallas_grads_match_scan(self):
        rng = np.random.default_rng(11)
        inp = make_inputs(rng, 1, 24, 2, 4)

        def loss(impl):
            def f(k, q, v, lam_v, abar, pbar):
                lam, eta, y = kla_filter(k, q, v, lam_v, abar, pbar,
                                         inp["lam0"], inp["eta0"], impl=impl)
                return jnp.sum(y * y) + 0.01 * jnp.sum(jnp.log(lam))
            return f

        args = (inp["k"], inp["q"], inp["v"], inp["lam_v"], inp["abar"],
                inp["pbar"])
        g1 = jax.grad(loss("scan"), argnums=tuple(range(6)))(*args)
        g2 = jax.grad(loss("pallas"), argnums=tuple(range(6)))(*args)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-5)

    def test_grad_finite_through_long_scan(self):
        rng = np.random.default_rng(12)
        inp = make_inputs(rng, 1, 512, 2, 4)

        def f(k):
            _, _, y = kla_filter(k, inp["q"], inp["v"], inp["lam_v"],
                                 inp["abar"], inp["pbar"], inp["lam0"],
                                 inp["eta0"], impl="scan")
            return jnp.sum(y * y)

        g = jax.grad(f)(inp["k"])
        assert np.isfinite(np.asarray(g)).all()

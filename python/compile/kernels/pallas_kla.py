"""L1: the KLA Moebius/affine filter as a Pallas kernel (chunked scan).

Hardware adaptation (DESIGN.md §4).  The paper's CUDA kernel keeps the lifted
scan states in SRAM and never materialises them in HBM.  The TPU-shaped
equivalent implemented here:

  * grid over the batch dimension — each program owns one sequence and holds
    its (T, N) / (T, D) tiles plus the running (lam, eta) carry in VMEM;
  * a two-level **chunked scan** inside the kernel: time is processed in
    chunks of CHUNK steps; within a chunk the recurrence runs as an unrolled
    elementwise FMA chain (VPU work), while only the (lam, eta) carry crosses
    chunk boundaries.  On a real TPU the chunk loop would become a second
    grid dimension with the carry in VMEM scratch and double-buffered HBM
    loads; on the CPU backend (interpret=True — Mosaic custom-calls cannot
    execute on CPU PJRT) the single-program-per-sequence form is equivalent
    and keeps the lowered HLO compact.

The kernel only materialises what the layer actually reads out downstream:
lam and eta for every step (needed for the readout and the variance path).

Autodiff: Pallas kernels have no VJP; `kla_filter_pallas` is wrapped in
`jax.custom_vjp` whose backward pass rematerialises through the
differentiable associative-scan formulation (`scan.py`).  Training
artifacts may therefore call the Pallas forward directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LAM_MIN, LAM_MAX
from .scan import kla_filter_scan

CHUNK = 16  # intra-chunk unroll length (VMEM-resident FMA chain)


def _kla_kernel(k_ref, v_ref, lv_ref, abar_ref, pbar_ref, lam0_ref, eta0_ref,
                lam_out_ref, eta_out_ref, *, seq_len: int, chunk: int):
    """One program = one sequence.  Refs are VMEM blocks:
    k: (T, N); v, lv: (T, D); abar/pbar/lam0/eta0: (N, D);
    outputs lam, eta: (T, N, D).
    """
    abar = abar_ref[...]
    pbar = pbar_ref[...]
    abar2 = abar * abar
    n_chunks = seq_len // chunk

    def chunk_body(c, carry):
        lam_c, eta_c = carry  # (N, D) each

        def step_body(i, inner):
            lam_p, eta_p = inner
            t = c * chunk + i
            k_t = k_ref[0, t, :]                    # (N,)
            v_t = v_ref[0, t, :]                    # (D,)
            lv_t = lv_ref[0, t, :]                  # (D,)
            phi = (k_t[:, None] * k_t[:, None]) * lv_t[None, :]
            rho = 1.0 / (abar2 + pbar * lam_p)
            lam_t = jnp.clip(rho * lam_p + phi, LAM_MIN, LAM_MAX)
            eta_t = (rho * abar) * eta_p + k_t[:, None] * (lv_t * v_t)[None, :]
            lam_out_ref[0, t, :, :] = lam_t
            eta_out_ref[0, t, :, :] = eta_t
            return lam_t, eta_t

        return jax.lax.fori_loop(0, chunk, step_body, (lam_c, eta_c))

    lam0 = lam0_ref[...]
    eta0 = eta0_ref[...]
    jax.lax.fori_loop(0, n_chunks, chunk_body, (lam0, eta0))


def _pallas_filter_raw(k, v, lam_v, abar, pbar, lam0, eta0):
    """Batched Pallas call.  k: (B, T, N); v, lam_v: (B, T, D);
    abar/pbar/lam0/eta0: (N, D).  Returns lam, eta: (B, T, N, D)."""
    B, T, N = k.shape
    D = v.shape[-1]
    if T % CHUNK != 0:
        # Pad time up to a chunk multiple; extra steps are discarded.
        pad = CHUNK - T % CHUNK
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        lam_v = jnp.pad(lam_v, ((0, 0), (0, pad), (0, 0)),
                        constant_values=1.0)
        Tp = T + pad
    else:
        Tp = T

    kernel = functools.partial(_kla_kernel, seq_len=Tp, chunk=CHUNK)
    lam, eta = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Tp, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Tp, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Tp, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((N, D), lambda b: (0, 0)),
            pl.BlockSpec((N, D), lambda b: (0, 0)),
            pl.BlockSpec((N, D), lambda b: (0, 0)),
            pl.BlockSpec((N, D), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Tp, N, D), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, Tp, N, D), lambda b: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, N, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Tp, N, D), jnp.float32),
        ],
        interpret=True,
    )(k, v, lam_v, abar, pbar, lam0, eta0)
    return lam[:, :T], eta[:, :T]


@jax.custom_vjp
def kla_filter_pallas(k, q, v, lam_v, abar, pbar, lam0, eta0):
    """Pallas-forward KLA filter with scan-based backward (same signature
    and return as `kla_filter_scan`)."""
    lam, eta = _pallas_filter_raw(k, v, lam_v, abar, pbar, lam0, eta0)
    y = jnp.einsum("btn,btnd->btd", q, eta / lam)
    return lam, eta, y


def _fwd(k, q, v, lam_v, abar, pbar, lam0, eta0):
    out = kla_filter_pallas(k, q, v, lam_v, abar, pbar, lam0, eta0)
    return out, (k, q, v, lam_v, abar, pbar, lam0, eta0)


def _bwd(residuals, cotangents):
    # Rematerialise through the differentiable associative-scan formulation.
    _, vjp = jax.vjp(kla_filter_scan, *residuals)
    return vjp(cotangents)


kla_filter_pallas.defvjp(_fwd, _bwd)

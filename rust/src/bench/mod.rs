//! Benchmark harness (offline stand-in for criterion).
//!
//! Each `rust/benches/*.rs` binary (harness = false) builds a `Suite`,
//! registers closures, and calls `run()`, which warms up, measures until a
//! time budget or iteration cap is hit, and prints a criterion-style table
//! plus a machine-readable JSON report under `runs/bench/`.

pub mod exp;

use std::time::{Duration, Instant};

use crate::util::{Json, Stats};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub p50_ms: f64,
    pub max_ms: f64,
    /// Optional scalar payload (accuracy, tokens/s, ...) for table benches.
    pub metrics: Vec<(String, f64)>,
}

impl BenchResult {
    pub fn json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("std_ms", Json::num(self.std_ms)),
            ("min_ms", Json::num(self.min_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("max_ms", Json::num(self.max_ms)),
        ];
        for (k, v) in &self.metrics {
            pairs.push((k.as_str(), Json::num(*v)));
        }
        Json::obj(pairs)
    }
}

pub struct Suite {
    pub title: String,
    pub max_iters: usize,
    pub time_budget: Duration,
    pub warmup: usize,
    results: Vec<BenchResult>,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        Suite {
            title: title.to_string(),
            max_iters: 30,
            time_budget: Duration::from_secs(5),
            warmup: 2,
            results: Vec::new(),
        }
    }

    pub fn quick(title: &str) -> Self {
        let mut s = Suite::new(title);
        s.max_iters = 10;
        s.time_budget = Duration::from_secs(2);
        s.warmup = 1;
        s
    }

    /// Time `f` repeatedly; records wall-clock stats.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut stats = Stats::new();
        let budget_start = Instant::now();
        for _ in 0..self.max_iters {
            let t = Instant::now();
            f();
            stats.push(t.elapsed().as_secs_f64() * 1e3);
            if budget_start.elapsed() > self.time_budget {
                break;
            }
        }
        self.push_stats(name, stats, Vec::new())
    }

    /// Record an externally-measured sample set (e.g. per-step times from a
    /// training run) instead of re-running a closure.
    pub fn record(&mut self, name: &str, samples_ms: &[f64],
                  metrics: Vec<(String, f64)>) -> &BenchResult {
        let mut stats = Stats::new();
        for &s in samples_ms {
            stats.push(s);
        }
        self.push_stats(name, stats, metrics)
    }

    /// Record a single metric row (accuracy tables etc., no timing).
    pub fn metric_row(&mut self, name: &str, metrics: Vec<(String, f64)>) {
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: 0,
            mean_ms: f64::NAN,
            std_ms: f64::NAN,
            min_ms: f64::NAN,
            p50_ms: f64::NAN,
            max_ms: f64::NAN,
            metrics,
        });
    }

    fn push_stats(&mut self, name: &str, stats: Stats,
                  metrics: Vec<(String, f64)>) -> &BenchResult {
        let r = BenchResult {
            name: name.to_string(),
            iters: stats.count(),
            mean_ms: stats.mean(),
            std_ms: stats.std(),
            min_ms: stats.min(),
            p50_ms: stats.percentile(50.0),
            max_ms: stats.max(),
            metrics,
        };
        println!(
            "{:44} {:>6} iters  mean {:>10.3} ms  p50 {:>10.3} ms  min {:>10.3} ms",
            r.name, r.iters, r.mean_ms, r.p50_ms, r.min_ms
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the summary table and write runs/bench/<title>.json.
    pub fn finish(&self) {
        println!("\n== {} ==", self.title);
        for r in &self.results {
            let mut line = format!("{:44}", r.name);
            if r.iters > 0 {
                line.push_str(&format!(" mean {:>10.3} ms", r.mean_ms));
            }
            for (k, v) in &r.metrics {
                line.push_str(&format!("  {k}={v:.4}"));
            }
            println!("{line}");
        }
        let dir = std::path::Path::new("runs/bench");
        if std::fs::create_dir_all(dir).is_ok() {
            let json = Json::obj(vec![
                ("title", Json::str(&self.title)),
                ("results",
                 Json::Arr(self.results.iter().map(|r| r.json()).collect())),
            ]);
            let path = dir.join(format!(
                "{}.json",
                self.title.replace([' ', '/'], "_")
            ));
            let _ = std::fs::write(&path, json.to_pretty());
            println!("[bench] wrote {}", path.display());
        }
    }
}

/// Prevent the optimiser from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_stats() {
        let mut s = Suite::quick("test");
        s.max_iters = 5;
        s.bench("noop", || {
            black_box(1 + 1);
        });
        let r = &s.results()[0];
        assert!(r.iters >= 1 && r.iters <= 5);
        assert!(r.mean_ms >= 0.0);
        assert!(r.min_ms <= r.mean_ms + 1e-9);
    }

    #[test]
    fn record_external_samples() {
        let mut s = Suite::quick("test2");
        s.record("ext", &[1.0, 2.0, 3.0],
                 vec![("acc".into(), 0.9)]);
        let r = &s.results()[0];
        assert_eq!(r.iters, 3);
        assert!((r.mean_ms - 2.0).abs() < 1e-9);
        assert_eq!(r.metrics[0].1, 0.9);
    }

    #[test]
    fn json_roundtrips() {
        let mut s = Suite::quick("t3");
        s.metric_row("row", vec![("acc".into(), 0.5)]);
        let j = s.results()[0].json();
        assert_eq!(j.req("acc").unwrap().as_f64().unwrap(), 0.5);
    }
}

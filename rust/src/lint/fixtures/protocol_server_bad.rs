//! Known-bad stand-in `serve/server.rs` for the `protocol-sync` pass:
//! the doc lists `bad-phantom` / `heartbeat`, which the code never
//! emits, while the code emits `bad-json` / `token`, which the doc
//! never lists.  Never compiled — only `include_str!`-ed by
//! protocol_sync.rs tests.
//!
//! Codes: `bad-phantom` (documented, never emitted).
//!
//! Event kinds: `start`, `heartbeat`.

fn reject(line: &str) -> Json {
    err_reply(None, "bad-json", line)
}

fn events() -> Vec<Json> {
    vec![
        Json::obj(vec![("event", Json::str("start"))]),
        Json::obj(vec![("event", Json::str("token"))]),
    ]
}

//! Serving integration: real TCP server over the decode artifact —
//! request/response protocol, continuous batching under concurrent load,
//! determinism of greedy decoding, and error handling.

use kla::config::ServeConfig;
use kla::runtime::Runtime;
use kla::serve::{serve, Client};

fn setup() -> Option<(std::path::PathBuf, Vec<kla::runtime::Value>)> {
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            return None;
        }
    };
    let init = rt.load("lm_kla_init").unwrap();
    let params = init.run(&[]).unwrap();
    Some((rt.dir().to_path_buf(), params))
}

#[test]
fn serve_end_to_end() {
    let Some((dir, params)) = setup() else { return };
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(), // ephemeral port
        artifact: "serve_kla_b8".into(),
        max_batch: 8,
        batch_window_us: 200,
        max_new_tokens: 4,
        state_pool: 8,
    };
    let handle = serve(dir, "serve_kla_b8".into(), params, &cfg).unwrap();
    let addr = handle.addr.clone();

    // ping
    let mut c = Client::connect(&addr).unwrap();
    assert!(c.ping().unwrap().req("ok").unwrap().as_bool().unwrap());

    // single request
    let r = c.request(&[5, 6, 7], 4).unwrap();
    let toks = r.req("tokens").unwrap().as_arr().unwrap();
    assert_eq!(toks.len(), 4);
    assert!(r.req("total_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert!(r.req("uncertainty").unwrap().as_f64().unwrap() > 0.0);

    // greedy decoding is deterministic: same prompt -> same tokens
    let r2 = c.request(&[5, 6, 7], 4).unwrap();
    assert_eq!(r.req("tokens").unwrap(), r2.req("tokens").unwrap());

    // concurrent load: more requests than slots (12 > max_batch 8), varied
    // prompt lengths — the overflow requests must wait for a free slot,
    // which has to show up as a nonzero queue_ms (measured submit->admit;
    // the old engine stamped admit time at submit, so this was always 0).
    let mut joins = Vec::new();
    for i in 0..12u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let prompt: Vec<i32> =
                (0..(1 + i % 5)).map(|j| (i + j) as i32 % 64).collect();
            let r = c.request(&prompt, 3).unwrap();
            assert_eq!(r.req("tokens").unwrap().as_arr().unwrap().len(), 3);
            r.req("queue_ms").unwrap().as_f64().unwrap()
        }));
    }
    let queue_times: Vec<f64> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();
    let max_queue = queue_times.iter().fold(0.0f64, |a, &b| a.max(b));
    assert!(queue_times.iter().all(|&q| q >= 0.0));
    assert!(max_queue > 0.0,
            "no request waited behind the full batch (queue_ms all zero: \
             {queue_times:?})");

    // malformed request gets an error, connection stays usable
    let bad = {
        let mut c2 = Client::connect(&addr).unwrap();
        // raw invalid json via the ping path is awkward; send a request
        // missing the prompt field instead
        let reply = {
            use std::io::{BufRead, Write};
            let stream = std::net::TcpStream::connect(&addr).unwrap();
            let mut w = stream.try_clone().unwrap();
            w.write_all(b"{\"max_new_tokens\": 2}\n").unwrap();
            w.flush().unwrap();
            let mut r = std::io::BufReader::new(stream);
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            line
        };
        let _ = c2;
        reply
    };
    assert!(bad.contains("error"), "no error for bad request: {bad}");

    let stats = handle.stop().unwrap();
    assert!(stats.requests >= 14, "requests seen: {}", stats.requests);
    assert!(stats.tokens_out >= 14 * 3);
    assert!(stats.tokens_per_sec() > 0.0);
    // continuous batching actually batched something
    let max_occ = stats
        .batch_occupancy
        .iter()
        .fold(0.0f64, |a, &b| a.max(b));
    assert!(max_occ > 1.0 / 8.0 + 1e-9,
            "never batched more than one request (max occupancy {max_occ})");
}

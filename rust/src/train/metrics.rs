//! Scalar metric logging to JSONL under runs/metrics/.

use anyhow::Result;

use crate::util::Json;

/// Accumulates (metric, step, value) rows; `flush` writes one JSONL file.
pub struct MetricLog {
    run: String,
    rows: Vec<(String, f64, f64)>,
}

impl MetricLog {
    pub fn new(run: &str) -> Self {
        MetricLog { run: run.to_string(), rows: Vec::new() }
    }

    pub fn scalar(&mut self, name: &str, step: f64, value: f64) {
        self.rows.push((name.to_string(), step, value));
    }

    pub fn rows(&self) -> &[(String, f64, f64)] {
        &self.rows
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        self.rows
            .iter()
            .rev()
            .find(|(n, _, _)| n == name)
            .map(|&(_, _, v)| v)
    }

    pub fn flush(&self) -> Result<()> {
        if self.rows.is_empty() {
            return Ok(());
        }
        let dir = std::path::Path::new("runs/metrics");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.jsonl", self.run));
        let mut out = String::new();
        for (name, step, value) in &self.rows {
            out.push_str(
                &Json::obj(vec![
                    ("metric", Json::str(name)),
                    ("step", Json::num(*step)),
                    ("value", Json::num(*value)),
                ])
                .to_string(),
            );
            out.push('\n');
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn last_returns_latest() {
        let mut log = MetricLog::new("t");
        log.scalar("loss", 0.0, 5.0);
        log.scalar("loss", 1.0, 3.0);
        log.scalar("acc", 1.0, 0.5);
        assert_eq!(log.last("loss"), Some(3.0));
        assert_eq!(log.last("acc"), Some(0.5));
        assert_eq!(log.last("nope"), None);
    }
}

//! Leveled stderr logging with wall-clock offsets (stand-in for env_logger).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

pub fn set_level(level: Level) {
    // ord: Relaxed — single byte of config, no data published with it;
    // a racing logger may use the old level for one line, harmless
    // lint: allow(atomic-ordering, advisory config byte, no payload)
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_env() {
    if let Ok(v) = std::env::var("KLA_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "debug" => Level::Debug,
            "warn" => Level::Warn,
            "error" => Level::Error,
            _ => Level::Info,
        });
    }
}

pub fn enabled(level: Level) -> bool {
    // ord: Relaxed — see set_level; gating is advisory, not an edge
    // lint: allow(atomic-ordering, advisory gate; see set_level)
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info, module_path!(),
            &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug, module_path!(),
            &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn, module_path!(),
            &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error, module_path!(),
            &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}

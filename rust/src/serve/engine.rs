//! Generation engine: marries the scheduler (batcher.rs) to the XLA decode
//! step and the belief-state cache.  One engine thread owns the model; the
//! router (server.rs) talks to it over an mpsc channel.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Feed, SchedRequest, Scheduler};
use super::state_cache::BeliefStateCache;
use crate::runtime::session::DecodeSession;
use crate::tensor::IntTensor;
use crate::util::Stats;

/// A request entering the engine.
pub struct EngineRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub resp: Sender<EngineResponse>,
}

/// The reply (tokens + timing; uncertainty from the belief state).
#[derive(Clone, Debug)]
pub struct EngineResponse {
    pub tokens: Vec<i32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub uncertainty: f32,
}

/// Engine statistics (read after shutdown).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: usize,
    pub steps: usize,
    pub tokens_out: usize,
    pub step_ms: Vec<f64>,
    pub batch_occupancy: Vec<f64>,
}

impl EngineStats {
    pub fn tokens_per_sec(&self) -> f64 {
        let total_s: f64 = self.step_ms.iter().sum::<f64>() / 1e3;
        if total_s > 0.0 {
            self.tokens_out as f64 / total_s
        } else {
            0.0
        }
    }

    pub fn mean_step_ms(&self) -> f64 {
        let mut s = Stats::new();
        for &x in &self.step_ms {
            s.push(x);
        }
        s.mean()
    }
}

/// Run the engine loop until `rx` disconnects (or `shutdown` is set) and
/// all admitted work drains.  `batch_window` bounds how long we wait to
/// fill empty slots before stepping a partially-full batch.
///
/// The intake NEVER blocks indefinitely: connection-handler threads hold
/// `tx` clones for as long as their sockets live, so a blocking `recv()`
/// would deadlock `ServerHandle::stop()` against any client that keeps its
/// connection open (seen in integration_serve).
pub fn run_engine(session: &DecodeSession, rx: Receiver<EngineRequest>,
                  batch_window: Duration, shutdown: Arc<AtomicBool>)
                  -> Result<EngineStats> {
    let b = session.batch();
    let mut cache = BeliefStateCache::new(session.init_state()?);
    let mut sched = Scheduler::new(b, 0);
    let mut pending: Vec<(u64, Sender<EngineResponse>, Instant, Instant)> =
        Vec::new(); // (id, resp, submit_time, start_time)
    let mut next_id = 0u64;
    let mut stats = EngineStats::default();
    let mut disconnected = false;

    while (!disconnected && !shutdown.load(Ordering::SeqCst))
        || sched.has_work()
    {
        // intake: block briefly when idle, else drain without blocking
        let deadline = Instant::now() + batch_window;
        loop {
            let timeout = deadline.saturating_duration_since(Instant::now());
            let msg = if sched.active_count() == 0 && sched.queue.is_empty()
            {
                // fully idle: wait in short slices so shutdown is observed
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if shutdown.load(Ordering::SeqCst) {
                            disconnected = true;
                        }
                        None
                    }
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            } else if sched.queue.is_empty()
                && sched.active_count() < b
                && !timeout.is_zero()
            {
                match rx.recv_timeout(timeout) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            };
            match msg {
                Some(req) => {
                    let id = next_id;
                    next_id += 1;
                    let now = Instant::now();
                    pending.push((id, req.resp, now, now));
                    sched.submit(SchedRequest {
                        id,
                        prompt: req.prompt,
                        max_new: req.max_new,
                    });
                    stats.requests += 1;
                }
                None => break,
            }
            if sched.queue.len() >= b {
                break;
            }
        }
        if !sched.has_work() {
            continue;
        }

        // admit into slots; reset belief state for new slots
        for slot in sched.admit() {
            cache.reset_slot(slot);
        }

        // build the token vector for this iteration
        let feeds = sched.feeds();
        let tokens: Vec<i32> = feeds
            .iter()
            .map(|f| match f {
                Feed::Prefill(t) | Feed::Decode(t) => *t,
                Feed::Idle => sched.pad(),
            })
            .collect();

        let t0 = Instant::now();
        let (logits, new_state) =
            session.step(&IntTensor::new(&[b], tokens)?, cache.state())?;
        cache.set_state(new_state);
        stats.step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        stats.steps += 1;
        stats.batch_occupancy
            .push(sched.active_count() as f64 / b as f64);

        // greedy sampling per slot
        let am = logits.argmax_last();
        let sampled: Vec<i32> = am.data().to_vec();
        let finished = sched.advance(&sampled);
        for f in &finished {
            stats.tokens_out += f.tokens.len();
            let uncertainty = cache.slot_uncertainty(f.slot);
            cache.reset_slot(f.slot);
            sched.release(f.slot);
            if let Some(pos) = pending.iter().position(|(id, ..)| *id == f.id)
            {
                let (_, resp, submit, start) = pending.swap_remove(pos);
                let _ = resp.send(EngineResponse {
                    tokens: f.tokens.clone(),
                    queue_ms: (start - submit).as_secs_f64() * 1e3,
                    total_ms: submit.elapsed().as_secs_f64() * 1e3,
                    uncertainty,
                });
            }
        }
    }
    Ok(stats)
}

use kla::api::{Filter, KlaFilter, ScanPlan};
use kla::kla::{random_inputs, random_params};
use kla::util::{Pcg64, Timer};

fn main() {
    for &(t, n, d) in &[(2048usize, 8usize, 64usize), (8192, 8, 64), (32768, 8, 64)] {
        let mut rng = Pcg64::seeded(t as u64);
        let p = random_params(&mut rng, n, d);
        let inp = random_inputs(&mut rng, t, n, d);
        let prior = KlaFilter::init(&p);
        // warmup
        let _ = KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        let tm = Timer::start();
        for _ in 0..3 {
            std::hint::black_box(KlaFilter::prefix(&p, &inp, &prior,
                                                   &ScanPlan::sequential()));
        }
        let seq = tm.elapsed_ms() / 3.0;
        for th in [1, 2, 4, 8, 16] {
            let plan = ScanPlan::chunked(th);
            let tm = Timer::start();
            for _ in 0..3 {
                std::hint::black_box(KlaFilter::prefix(&p, &inp, &prior, &plan));
            }
            let par = tm.elapsed_ms() / 3.0;
            println!("T={t} th={th}: seq {seq:.1} ms chunked {par:.1} ms ({:.2}x)", seq / par);
        }
    }
}

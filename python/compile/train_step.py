"""Fused training/eval/scoring graphs (the L2 -> L3 ABI).

Everything the Rust trainer needs per optimisation step is fused into ONE
XLA module: forward, masked cross-entropy (optionally the KLA+ Monte-Carlo
marginal-likelihood loss), backward, global-norm gradient clipping, the
trapezoidal learning-rate schedule, and the AdamW update.  The coordinator
keeps params and optimiser state device-resident and only ships the batch
up / the loss scalar down (DESIGN.md §7 L3).

Artifact signatures (all arrays fp32 unless noted; params/m/v are the
flattened sorted-key param list of models.common.flatten_params):

  init:   ()                                       -> (*params)
  train:  (*params, *m, *v, step f32[], tokens i32[B,T], targets i32[B,T],
           mask f32[B,T])                          -> (loss f32[], *params, *m, *v)
  eval:   (*params, tokens, targets, mask)         -> (loss_sum, correct, count)
  score:  (*params, tokens, targets, mask)         -> seq_logprob f32[B]
  logits: (*params, tokens)                        -> logits f32[B,T,V]
  variance: (*params, tokens)                      -> y_var f32[B,T]
  decode: (*params, token i32[B], conv, lam, eta)  -> (logits, conv', lam', eta')
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .models.common import (cross_entropy, flatten_params, sequence_logprob,
                            token_accuracy, unflatten_params)
from .models.lm import ModelConfig, lm_forward, lm_forward_sampled, lm_variance
from .models.decode import decode_step


@dataclass(frozen=True)
class OptConfig:
    """AdamW + schedule, following the paper's nanochat-style recipe
    (Appendix G.6) scaled to this testbed."""
    lr: float = 1e-3
    beta1: float = 0.8
    beta2: float = 0.95
    eps: float = 1e-10
    weight_decay: float = 0.1
    grad_clip: float = 3.0
    warmdown_frac: float = 0.4     # final fraction of steps: linear decay
    total_steps: int = 1000
    ssm_lr_mult: float = 0.1       # state-space params (a, p, dt, lam0)
    mc_seed: int = 1234            # KLA+ sampling seed base

    def to_dict(self):
        from dataclasses import asdict
        return asdict(self)


_SSM_KEYS = ("a_raw", "p_raw", "dt_raw", "lam0_raw", "a_log")
_NO_DECAY_SUBSTR = ("norm", "_b", "conv_b", "blam", "b_f", "b_alpha",
                    "b_beta", "b_dt", "skip_d", "embed")


def _param_groups(names):
    """Per-parameter (lr_mult, wd_mult) following Appendix G.6: state-space
    group at 0.1x LR with zero weight decay; 1-D/bias/norm/embed params
    without weight decay."""
    lr_mults, wd_mults = [], []
    for name in names:
        leaf = name.rsplit(".", 1)[-1]
        if leaf in _SSM_KEYS:
            lr_mults.append(0.1)
            wd_mults.append(0.0)
        elif any(s in leaf for s in _NO_DECAY_SUBSTR):
            lr_mults.append(1.0)
            wd_mults.append(0.0)
        else:
            lr_mults.append(1.0)
            wd_mults.append(1.0)
    return lr_mults, wd_mults


def _schedule(step: jnp.ndarray, opt: OptConfig):
    """Trapezoidal: constant, then linear warmdown over the final
    `warmdown_frac` of training (no warmup), as in Appendix G.6."""
    total = float(opt.total_steps)
    down_start = total * (1.0 - opt.warmdown_frac)
    frac = jnp.clip((total - step) / jnp.maximum(total - down_start, 1.0),
                    0.0, 1.0)
    return opt.lr * frac


def make_loss_fn(cfg: ModelConfig, opt: OptConfig):
    def loss_fn(params, tokens, targets, mask, step):
        if cfg.mc_samples > 0:
            # KLA+ : -log(1/S sum_s p(o_t | y_t^(s)))  (paper Eq. 24-25)
            key = jax.random.fold_in(jax.random.PRNGKey(opt.mc_seed),
                                     step.astype(jnp.int32))
            logps = []
            for s in range(cfg.mc_samples):
                logits_s = lm_forward_sampled(cfg, params, tokens,
                                              jax.random.fold_in(key, s))
                logp = jax.nn.log_softmax(logits_s, axis=-1)
                ll = jnp.take_along_axis(logp, targets[..., None],
                                         axis=-1)[..., 0]
                logps.append(ll)
            # logsumexp over samples minus log S, per token
            ll = jax.scipy.special.logsumexp(jnp.stack(logps), axis=0)
            ll = ll - jnp.log(float(cfg.mc_samples))
            total = jnp.maximum(jnp.sum(mask), 1.0)
            return -jnp.sum(ll * mask) / total
        logits = lm_forward(cfg, params, tokens)
        return cross_entropy(logits, targets, mask)
    return loss_fn


def build_train_step(cfg: ModelConfig, opt: OptConfig, template: dict):
    """Returns fn(flat_params, flat_m, flat_v, step, tokens, targets, mask)
    -> (loss, flat_params', flat_m', flat_v')."""
    names = [n for n, _ in flatten_params(template)]
    lr_mults, wd_mults = _param_groups(names)
    loss_fn = make_loss_fn(cfg, opt)

    def train_step(flat_params, flat_m, flat_v, step, tokens, targets, mask):
        params = unflatten_params(template, flat_params)
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, targets,
                                                  mask, step)
        flat_grads = [g for _, g in flatten_params(grads)]
        # global-norm clip (paper: 3.0)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in flat_grads) + 1e-12)
        scale = jnp.minimum(1.0, opt.grad_clip / gnorm)
        lr = _schedule(step, opt)
        t = step + 1.0
        bc1 = 1.0 - opt.beta1 ** t
        bc2 = 1.0 - opt.beta2 ** t
        new_p, new_m, new_v = [], [], []
        for p, m, v, g, lm_, wm in zip(flat_params, flat_m, flat_v,
                                       flat_grads, lr_mults, wd_mults):
            g = g * scale
            m = opt.beta1 * m + (1.0 - opt.beta1) * g
            v = opt.beta2 * v + (1.0 - opt.beta2) * g * g
            update = (m / bc1) / (jnp.sqrt(v / bc2) + opt.eps)
            p = p - lr * lm_ * (update + opt.weight_decay * wm * p)
            new_p.append(p)
            new_m.append(m)
            new_v.append(v)
        return (loss, new_p, new_m, new_v)

    return train_step


def build_eval_step(cfg: ModelConfig, template: dict):
    def eval_step(flat_params, tokens, targets, mask):
        params = unflatten_params(template, flat_params)
        logits = lm_forward(cfg, params, tokens)
        loss = cross_entropy(logits, targets, mask)
        correct, count = token_accuracy(logits, targets, mask)
        return loss * jnp.maximum(jnp.sum(mask), 1.0), correct, count
    return eval_step


def build_score_step(cfg: ModelConfig, template: dict):
    def score_step(flat_params, tokens, targets, mask):
        params = unflatten_params(template, flat_params)
        logits = lm_forward(cfg, params, tokens)
        return sequence_logprob(logits, targets, mask)
    return score_step


def build_logits(cfg: ModelConfig, template: dict):
    def logits_fn(flat_params, tokens):
        params = unflatten_params(template, flat_params)
        return lm_forward(cfg, params, tokens)
    return logits_fn


def build_variance(cfg: ModelConfig, template: dict):
    def var_fn(flat_params, tokens):
        params = unflatten_params(template, flat_params)
        return lm_variance(cfg, params, tokens)
    return var_fn


def build_decode(cfg: ModelConfig, template: dict):
    def decode_fn(flat_params, token, conv, lam, eta):
        params = unflatten_params(template, flat_params)
        return decode_step(cfg, params, token, conv, lam, eta)
    return decode_fn

// Clean fixture for the `unsafe` pass: the same three `unsafe` sites
// as unsafe_bad.rs, each covered by a SAFETY comment within the
// attachment window.  Never compiled — only `include_str!`-ed by
// unsafe_audit.rs tests.

struct RawPtr(*mut f32);

// SAFETY: fixture — the pointer targets disjoint indices per thread,
// so sharing the wrapper across threads cannot race.
unsafe impl Send for RawPtr {}
// SAFETY: fixture — see the Send argument above; reads are disjoint
// from writes by construction.
unsafe impl Sync for RawPtr {}

fn write(p: &RawPtr, i: usize, x: f32) {
    // SAFETY: fixture — `i` is bounds-checked by the caller.
    unsafe { *p.0.add(i) = x };
}

//! Minimal dense f32/i32 tensors for host-side data plumbing.
//!
//! This is intentionally small: the heavy math runs inside XLA (L2/L1) or
//! the native KLA kernels (`crate::kla`); tensors here are row-major
//! containers with just enough shape algebra for batching, metrics, and
//! literal conversion.

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("item() on tensor of {} elems", self.data.len());
        }
        Ok(self.data[0])
    }

    /// Row-major flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &s)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < s, "index {x} out of bounds {s} at dim {i}");
            off = off * s + x;
        }
        off
    }

    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Mean absolute difference (test helper).
    pub fn mad(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / n as f32
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs().max(a.abs()))
    }

    /// argmax over the last axis; returns an IntTensor of the leading shape.
    /// NaN-aware via [`argmax_row`]: NaN entries can never win (the old
    /// loop compared `x > row[best]` against a NaN seed at position 0,
    /// which made every comparison false and silently returned token 0).
    pub fn argmax_last(&self) -> IntTensor {
        let last = *self.shape.last().expect("argmax on scalar");
        let lead: Vec<usize> = self.shape[..self.shape.len() - 1].to_vec();
        let rows = self.data.len() / last;
        let mut out = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &self.data[r * last..(r + 1) * last];
            out.push(argmax_row(row) as i32);
        }
        IntTensor { shape: lead, data: out }
    }
}

/// NaN-aware argmax over one row: NaN entries are skipped, ties go to the
/// lowest index.  An all-NaN row is a model bug — debug-asserted, and 0
/// is returned as a release-mode fallback.  Shared by
/// [`Tensor::argmax_last`] and the greedy path of `serve::sampling`.
pub fn argmax_row(row: &[f32]) -> usize {
    let mut best: Option<usize> = None;
    for (i, &x) in row.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            Some(b) if x <= row[b] => {}
            _ => best = Some(i),
        }
    }
    debug_assert!(best.is_some(), "argmax over an all-NaN row");
    best.unwrap_or(0)
}

/// Row-major i32 tensor (token ids, masks as 0/1).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        IntTensor {
            shape: shape.to_vec(),
            data: vec![0; shape.iter().product()],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }

    pub fn get(&self, idx: &[usize]) -> i32 {
        let mut off = 0;
        for (&x, &s) in idx.iter().zip(&self.shape) {
            off = off * s + x;
        }
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: i32) {
        let mut off = 0;
        for (&x, &s) in idx.iter().zip(&self.shape) {
            off = off * s + x;
        }
        self.data[off] = v;
    }

    pub fn to_f32(&self) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| x as f32).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
        let t = Tensor::zeros(&[2, 3]);
        assert!(t.clone().reshape(&[3, 2]).is_ok());
        assert!(t.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn indexing_row_major() {
        let t = Tensor::new(&[2, 3], (0..6).map(|x| x as f32).collect())
            .unwrap();
        assert_eq!(t.get(&[0, 0]), 0.0);
        assert_eq!(t.get(&[0, 2]), 2.0);
        assert_eq!(t.get(&[1, 0]), 3.0);
        assert_eq!(t.get(&[1, 2]), 5.0);
    }

    #[test]
    fn argmax() {
        let t = Tensor::new(&[2, 3], vec![1.0, 5.0, 2.0, 9.0, 0.0, 3.0])
            .unwrap();
        let am = t.argmax_last();
        assert_eq!(am.shape(), &[2]);
        assert_eq!(am.data(), &[1, 0]);
    }

    #[test]
    fn argmax_skips_nans() {
        // NaN in position 0 used to poison the whole row: every `x >
        // row[best]` comparison against the NaN seed was false, so the
        // argmax silently returned token 0
        assert_eq!(argmax_row(&[f32::NAN, 1.0, 3.0, 2.0]), 2);
        assert_eq!(argmax_row(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax_row(&[f32::NEG_INFINITY, f32::NAN, -1.0]), 2);
        let t = Tensor::new(&[2, 3],
                            vec![f32::NAN, 5.0, 2.0, 9.0, f32::NAN, 3.0])
            .unwrap();
        assert_eq!(t.argmax_last().data(), &[1, 0]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "all-NaN")]
    fn argmax_all_nan_row_asserts_in_debug() {
        argmax_row(&[f32::NAN, f32::NAN]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::new(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[2], vec![1.0001, 2.0001]).unwrap();
        assert!(a.allclose(&b, 1e-3, 1e-3));
        assert!(!a.allclose(&b, 1e-6, 1e-6));
    }

    #[test]
    fn int_tensor_roundtrip() {
        let mut t = IntTensor::zeros(&[2, 2]);
        t.set(&[1, 0], 7);
        assert_eq!(t.get(&[1, 0]), 7);
        assert_eq!(t.to_f32().get(&[1, 0]), 7.0);
    }
}

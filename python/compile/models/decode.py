"""O(1) recurrent decode step for KLA LMs (the serving hot path, and the
Fig. 4 / Fig. 9 'naive recurrent (time-stepped) Kalman' baseline when the
coordinator drives it once per token).

State per KLA block:
    conv: (B, K-1, D)   causal-conv window
    lam:  (B, N, D)     posterior precision
    eta:  (B, N, D)     posterior information mean
Stacked over layers into (L, B, ...) arrays so the artifact ABI stays flat
regardless of depth.  Only pure-KLA models are supported on the recurrent
path (hybrids contain softmax attention, which has no O(1) state).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.nn import softplus

from .common import rmsnorm
from .kla import LAM0_FLOOR, kla_block_step
from .lm import ModelConfig


def decode_init_state(cfg: ModelConfig, params: dict, batch: int):
    """Fresh belief state for `batch` sequences: (conv, lam, eta) stacked
    over layers.  lam starts at the learned prior precision lam0."""
    L, K, D, N = (cfg.n_layers, cfg.conv_kernel, cfg.d_model, cfg.n_state)
    conv = jnp.zeros((L, batch, K - 1, D), jnp.float32)
    lams, etas = [], []
    for name in sorted(params["blocks"].keys()):
        bp = params["blocks"][name]
        lam0 = softplus(bp["lam0_raw"]) + LAM0_FLOOR          # (N, D)
        lams.append(jnp.broadcast_to(lam0, (batch, N, D)))
        etas.append(jnp.zeros((batch, N, D), jnp.float32))
    return conv, jnp.stack(lams), jnp.stack(etas)


def decode_step(cfg: ModelConfig, params: dict, token: jnp.ndarray,
                conv: jnp.ndarray, lam: jnp.ndarray, eta: jnp.ndarray):
    """One autoregressive step.

    token: (B,) int32; conv: (L,B,K-1,D); lam, eta: (L,B,N,D).
    Returns (logits (B, V), conv', lam', eta')."""
    assert cfg.kind in ("kla", "kla_plus"), "recurrent path is KLA-only"
    h = params["embed"][token]                                # (B, D)
    convs, lams, etas = [], [], []
    for i, name in enumerate(sorted(params["blocks"].keys())):
        bp = params["blocks"][name]
        h, c_i, l_i, e_i = kla_block_step(
            bp, h, conv[i], lam[i], eta[i],
            process_noise=cfg.process_noise, ou_exact=cfg.ou_exact)
        convs.append(c_i)
        lams.append(l_i)
        etas.append(e_i)
    h = rmsnorm(h, params["norm_f"])
    logits = h @ params["head"]
    return logits, jnp.stack(convs), jnp.stack(lams), jnp.stack(etas)

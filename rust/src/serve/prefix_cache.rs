//! Content-addressed belief-state prefix cache (DESIGN.md §S15).
//!
//! KLA's constant-size belief state makes prompt caching trivially
//! cheap: reusing a shared prefix is a per-layer posterior snapshot
//! restore ([`SlotSnapshot`], a few KB), not a sequence-length KV copy.
//! This module is the content-addressed map behind that: token prefix →
//! the exact [`BeliefStateCache`](super::BeliefStateCache) snapshot the
//! cold chunked prefill produced at that offset.
//!
//! Keying.  Every entry is addressed by an FNV-1a hash folded over the
//! prefix's token bytes, SEEDED by a [`ModelFingerprint`] hash (vocab,
//! backend kind, layer geometry, engine seed) — and both the fingerprint
//! and the exact tokens are compared on lookup, so hash collisions and
//! model mismatches can never restore a wrong snapshot into a slot.
//!
//! Granularity.  Snapshots are inserted at `block`-aligned prefill
//! cursors plus the end of prefill, and lookup tries the longest
//! candidate first: the request's full usable prefix (exact-prompt
//! full hit), then descending `block` multiples (shared-prefix partial
//! hit).  With `block == prefill_chunk` (the default) every cached
//! offset is chunk-aligned, which is the generation-identity condition
//! the e2e `native_prefix_cache_*` tests pin.
//!
//! Eviction.  Byte-accounted LRU under a fixed budget: each entry's cost
//! is its snapshot payload ([`SlotSnapshot::bytes`]) plus its key tokens
//! plus a fixed overhead, and inserts evict least-recently-used entries
//! (global min insert/hit tick) until the total fits.  The budget is an
//! invariant, not a target: `bytes() <= budget` after every operation,
//! and an entry that alone exceeds the budget is refused outright.

use std::collections::HashMap;

use anyhow::Result;

use super::state_cache::SlotSnapshot;
use crate::runtime::backend::DecodeBackend;

/// Identity of the model a snapshot was taken under.  Snapshots restore
/// raw per-layer state, so every geometric degree of freedom (and the
/// engine seed, which selects the weights for seeded native backends)
/// participates: a cache can never hand a snapshot to a mismatched
/// model, even across server restarts with a different config.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelFingerprint {
    pub vocab: usize,
    /// Backend kind string (`DecodeBackend::kind`): "native" / "xla".
    pub backend: &'static str,
    pub layers: usize,
    /// Causal-conv window length, K-1.
    pub conv_window: usize,
    pub d_model: usize,
    pub n_state: usize,
    /// Engine seed ([`super::engine::EngineOptions::seed`]) — for seeded
    /// native backends this selects the weights themselves.
    pub seed: u64,
}

impl ModelFingerprint {
    /// Derive the fingerprint from a backend's prior state shapes:
    /// conv is (L, B, K-1, D) and lam is (L, B, N, D).
    pub fn for_backend<B: DecodeBackend + ?Sized>(backend: &B, seed: u64)
                                                  -> Result<Self> {
        let init = backend.init_state()?;
        let cs = init.conv.shape();
        let ps = init.lam.shape();
        Ok(ModelFingerprint {
            vocab: backend.vocab(),
            backend: backend.kind(),
            layers: cs[0],
            conv_window: cs[2],
            d_model: cs[3],
            n_state: ps[2],
            seed,
        })
    }

    /// Seed value for this fingerprint's prefix keys.
    fn hash(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for part in [self.vocab as u64, self.layers as u64,
                     self.conv_window as u64, self.d_model as u64,
                     self.n_state as u64, self.seed]
        {
            h = fnv_fold(h, &part.to_le_bytes());
        }
        fnv_fold(h, self.backend.as_bytes())
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content address of a token prefix under a fingerprint: FNV-1a over
/// the little-endian token bytes, seeded by the fingerprint hash.
fn prefix_key(fp_hash: u64, tokens: &[i32]) -> u64 {
    let mut h = fp_hash;
    for &t in tokens {
        h = fnv_fold(h, &t.to_le_bytes());
    }
    h
}

/// Cache counters, mirrored into the engine's stats and the
/// `{"cmd":"stats"}` protocol reply.  `bytes`/`entries` are the CURRENT
/// residency; everything else is cumulative.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Lookups whose match covered the full usable prefix.
    pub hits: usize,
    /// Lookups matched at a shorter block-aligned offset.
    pub partial_hits: usize,
    pub misses: usize,
    pub evictions: usize,
    pub insertions: usize,
    /// Prompt tokens covered by restored snapshots (prefill work saved).
    pub cached_tokens: usize,
    pub bytes: usize,
    pub entries: usize,
}

struct Entry {
    fp: ModelFingerprint,
    tokens: Vec<i32>,
    snap: SlotSnapshot,
    /// Byte cost charged against the budget.
    cost: usize,
    /// Last insert-or-hit tick — the LRU ordering key.
    tick: u64,
}

/// Fixed per-entry overhead charged on top of the snapshot payload and
/// key tokens (Entry bookkeeping + map slot, order-of-magnitude).
const ENTRY_OVERHEAD: usize = 96;

/// The cache proper.  Single-owner (lives on the engine thread next to
/// the slot pool); the router sees only its counters via `LiveStats`.
pub struct PrefixCache {
    buckets: HashMap<u64, Vec<Entry>>,
    block: usize,
    budget: usize,
    bytes: usize,
    tick: u64,
    stats: PrefixCacheStats,
}

impl PrefixCache {
    /// `block`: lookup/insert offset granularity in prompt tokens
    /// (clamped to >= 1).  `budget`: LRU byte budget; 0 disables inserts
    /// entirely (every lookup misses on the empty cache).
    pub fn new(block: usize, budget: usize) -> Self {
        PrefixCache {
            buckets: HashMap::new(),
            block: block.max(1),
            budget,
            bytes: 0,
            tick: 0,
            stats: PrefixCacheStats::default(),
        }
    }

    pub fn block(&self) -> usize {
        self.block
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Current byte residency (always <= budget).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.buckets.values().map(|v| v.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// Counters with the current residency filled in.
    pub fn stats(&self) -> PrefixCacheStats {
        let mut s = self.stats;
        s.bytes = self.bytes;
        s.entries = self.len();
        s
    }

    /// Candidate match offsets for a prompt whose prefill will consume
    /// `usable` tokens, longest first: `usable` itself (exact-prompt
    /// full hit — end-of-prefill snapshots land at arbitrary offsets,
    /// so this candidate is not restricted to block multiples), then
    /// every block multiple strictly below it, descending.
    fn candidates(&self, usable: usize) -> Vec<usize> {
        let mut offs = Vec::new();
        if usable == 0 {
            return offs;
        }
        offs.push(usable);
        let mut m = (usable / self.block) * self.block;
        if m == usable {
            m = m.saturating_sub(self.block);
        }
        while m > 0 {
            offs.push(m);
            m = m.saturating_sub(self.block);
        }
        offs
    }

    /// Longest-prefix lookup: the longest candidate offset whose exact
    /// tokens (and fingerprint) are cached.  A hit bumps the entry's LRU
    /// tick and returns `(offset, snapshot)`; the snapshot covers
    /// exactly `tokens[..offset]`.  The returned borrow is tied to the
    /// cache only, never to `tokens`.
    pub fn lookup<'a>(&'a mut self, fp: &ModelFingerprint, tokens: &[i32],
                      usable: usize)
                      -> Option<(usize, &'a SlotSnapshot)> {
        self.tick += 1;
        let usable = usable.min(tokens.len());
        let fp_hash = fp.hash();
        // phase 1: locate the longest match without holding a mutable
        // borrow across candidate probes
        let mut found: Option<(usize, u64, usize)> = None;
        'outer: for off in self.candidates(usable) {
            let key = prefix_key(fp_hash, &tokens[..off]);
            if let Some(bucket) = self.buckets.get(&key) {
                for (i, e) in bucket.iter().enumerate() {
                    if e.fp == *fp && e.tokens[..] == tokens[..off] {
                        found = Some((off, key, i));
                        break 'outer;
                    }
                }
            }
        }
        let Some((off, key, i)) = found else {
            self.stats.misses += 1;
            return None;
        };
        if off == usable {
            self.stats.hits += 1;
        } else {
            self.stats.partial_hits += 1;
        }
        self.stats.cached_tokens += off;
        // phase 2: bump recency and hand out the snapshot
        let e = &mut self.buckets.get_mut(&key).expect("bucket exists")[i];
        e.tick = self.tick;
        Some((off, &e.snap))
    }

    /// Insert `snap` as the state after consuming exactly `tokens`.
    /// Returns whether a NEW entry was stored — false for a disabled
    /// cache, an empty prefix, a duplicate (recency refreshed, existing
    /// snapshot kept: both cover the same cold-path state), or an entry
    /// that alone exceeds the budget (evicting everything else could
    /// never make it fit).
    pub fn insert(&mut self, fp: &ModelFingerprint, tokens: &[i32],
                  snap: SlotSnapshot) -> bool {
        if self.budget == 0 || tokens.is_empty() {
            return false;
        }
        self.tick += 1;
        let key = prefix_key(fp.hash(), tokens);
        if let Some(bucket) = self.buckets.get_mut(&key) {
            if let Some(e) = bucket
                .iter_mut()
                .find(|e| e.fp == *fp && e.tokens[..] == *tokens)
            {
                e.tick = self.tick;
                return false;
            }
        }
        let cost = snap.bytes()
            + tokens.len() * std::mem::size_of::<i32>()
            + ENTRY_OVERHEAD;
        if cost > self.budget {
            return false;
        }
        self.buckets.entry(key).or_default().push(Entry {
            fp: fp.clone(),
            tokens: tokens.to_vec(),
            snap,
            cost,
            tick: self.tick,
        });
        self.bytes += cost;
        self.stats.insertions += 1;
        self.evict_to_budget();
        true
    }

    /// Evict least-recently-used entries (global min tick) until the
    /// byte budget holds again.  The entry just inserted carries the
    /// maximal tick, so it survives unless it is the only one left —
    /// and `insert` already refused anything that alone exceeds the
    /// budget.
    fn evict_to_budget(&mut self) {
        while self.bytes > self.budget {
            let mut victim: Option<(u64, usize, u64)> = None;
            for (&key, bucket) in &self.buckets {
                for (i, e) in bucket.iter().enumerate() {
                    let older = match victim {
                        None => true,
                        Some((_, _, t)) => e.tick < t,
                    };
                    if older {
                        victim = Some((key, i, e.tick));
                    }
                }
            }
            let Some((key, i, _)) = victim else { break };
            let bucket = self.buckets.get_mut(&key).expect("victim bucket");
            let e = bucket.swap_remove(i);
            if bucket.is_empty() {
                self.buckets.remove(&key);
            }
            self.bytes -= e.cost;
            self.stats.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::KlaBelief;

    fn fp() -> ModelFingerprint {
        ModelFingerprint {
            vocab: 32,
            backend: "native",
            layers: 2,
            conv_window: 3,
            d_model: 4,
            n_state: 2,
            seed: 7,
        }
    }

    /// A 2-layer snapshot tagged with a recognisable fill value:
    /// 24 conv floats + 2 * (8 + 8) posterior floats = 224 bytes.
    fn snap(tag: f32) -> SlotSnapshot {
        SlotSnapshot {
            conv: vec![tag; 2 * 3 * 4],
            beliefs: (0..2)
                .map(|_| KlaBelief::from_parts(vec![tag; 8], vec![tag; 8]))
                .collect(),
        }
    }

    /// Entry cost for a `snap()` keyed by `n` tokens.
    fn cost(n: usize) -> usize {
        224 + 4 * n + ENTRY_OVERHEAD
    }

    #[test]
    fn full_hit_returns_the_inserted_snapshot() {
        let mut pc = PrefixCache::new(4, 1 << 20);
        let toks = vec![1, 2, 3, 4];
        assert!(pc.insert(&fp(), &toks, snap(0.5)));
        let (off, s) = pc.lookup(&fp(), &toks, 4).expect("full hit");
        assert_eq!(off, 4);
        assert_eq!(s.conv[0], 0.5);
        let st = pc.stats();
        assert_eq!((st.hits, st.partial_hits, st.misses), (1, 0, 0));
        assert_eq!(st.cached_tokens, 4);
        assert_eq!(st.entries, 1);
        assert_eq!(st.bytes, cost(4));
    }

    #[test]
    fn longest_prefix_wins_and_shorter_prefixes_partial_hit() {
        let mut pc = PrefixCache::new(2, 1 << 20);
        pc.insert(&fp(), &[1, 2], snap(0.2));
        pc.insert(&fp(), &[1, 2, 3, 4], snap(0.4));
        // longer entry wins even though the shorter also matches
        let (off, s) = pc.lookup(&fp(), &[1, 2, 3, 4, 9, 9], 6).unwrap();
        assert_eq!(off, 4);
        assert_eq!(s.conv[0], 0.4);
        assert_eq!(pc.stats().partial_hits, 1);
        // diverging suffix falls back to the shared 2-token prefix
        let (off, s) = pc.lookup(&fp(), &[1, 2, 7, 8], 4).unwrap();
        assert_eq!(off, 2);
        assert_eq!(s.conv[0], 0.2);
        assert_eq!(pc.stats().partial_hits, 2);
        // nothing shared at any block offset: miss
        assert!(pc.lookup(&fp(), &[9, 9, 9, 9], 4).is_none());
        assert_eq!(pc.stats().misses, 1);
    }

    #[test]
    fn lookup_probes_block_multiples_plus_usable_only() {
        let mut pc = PrefixCache::new(4, 1 << 20);
        let toks: Vec<i32> = (0..10).collect();
        // an end-of-prefill entry at the NON-block offset 6
        pc.insert(&fp(), &toks[..6], snap(0.6));
        // usable 10 probes 10, 8, 4 — never 6
        assert!(pc.lookup(&fp(), &toks, 10).is_none());
        // but a request whose usable IS 6 full-hits it
        let (off, _) = pc.lookup(&fp(), &toks[..7], 6).unwrap();
        assert_eq!(off, 6);
        assert_eq!(pc.stats().hits, 1);
    }

    #[test]
    fn fingerprint_mismatch_never_hits() {
        let mut pc = PrefixCache::new(4, 1 << 20);
        let toks = vec![1, 2, 3, 4];
        pc.insert(&fp(), &toks, snap(1.0));
        for wrong in [
            ModelFingerprint { seed: 8, ..fp() },
            ModelFingerprint { layers: 3, ..fp() },
            ModelFingerprint { vocab: 64, ..fp() },
            ModelFingerprint { backend: "xla", ..fp() },
        ] {
            assert!(pc.lookup(&wrong, &toks, 4).is_none(),
                    "{wrong:?} must not match");
        }
        assert_eq!(pc.stats().misses, 4);
        // the right fingerprint still hits
        assert!(pc.lookup(&fp(), &toks, 4).is_some());
    }

    #[test]
    fn duplicate_insert_refreshes_recency_without_growing() {
        let mut pc = PrefixCache::new(4, 1 << 20);
        let toks = vec![1, 2, 3, 4];
        assert!(pc.insert(&fp(), &toks, snap(0.1)));
        assert!(!pc.insert(&fp(), &toks, snap(0.9)));
        let st = pc.stats();
        assert_eq!(st.insertions, 1);
        assert_eq!(st.entries, 1);
        assert_eq!(st.bytes, cost(4));
        // the ORIGINAL snapshot is kept (same cold-path state either way)
        assert_eq!(pc.lookup(&fp(), &toks, 4).unwrap().1.conv[0], 0.1);
    }

    #[test]
    fn lru_evicts_least_recently_used_under_budget() {
        // budget fits exactly two 4-token entries
        let mut pc = PrefixCache::new(4, 2 * cost(4));
        pc.insert(&fp(), &[1, 1, 1, 1], snap(0.1));
        pc.insert(&fp(), &[2, 2, 2, 2], snap(0.2));
        assert_eq!(pc.len(), 2);
        // touch the first so the SECOND becomes LRU
        assert!(pc.lookup(&fp(), &[1, 1, 1, 1], 4).is_some());
        pc.insert(&fp(), &[3, 3, 3, 3], snap(0.3));
        assert_eq!(pc.stats().evictions, 1);
        assert_eq!(pc.len(), 2);
        assert!(pc.bytes() <= pc.budget());
        assert!(pc.lookup(&fp(), &[2, 2, 2, 2], 4).is_none(),
                "LRU entry must be gone");
        assert!(pc.lookup(&fp(), &[1, 1, 1, 1], 4).is_some());
        assert!(pc.lookup(&fp(), &[3, 3, 3, 3], 4).is_some());
    }

    #[test]
    fn oversized_entry_is_refused_not_thrashed() {
        let mut pc = PrefixCache::new(4, cost(4) - 1);
        assert!(!pc.insert(&fp(), &[1, 2, 3, 4], snap(0.5)));
        assert_eq!(pc.bytes(), 0);
        assert_eq!(pc.stats().evictions, 0);
        assert!(pc.is_empty());
    }

    #[test]
    fn zero_budget_disables_and_empty_prefix_is_never_cached() {
        let mut pc = PrefixCache::new(4, 0);
        assert!(!pc.insert(&fp(), &[1, 2, 3, 4], snap(0.5)));
        assert!(pc.lookup(&fp(), &[1, 2, 3, 4], 4).is_none());
        let mut pc = PrefixCache::new(4, 1 << 20);
        assert!(!pc.insert(&fp(), &[], snap(0.5)));
        assert!(pc.is_empty());
    }

    #[test]
    fn candidates_are_longest_first_block_aligned() {
        let pc = PrefixCache::new(4, 1);
        assert_eq!(pc.candidates(10), vec![10, 8, 4]);
        assert_eq!(pc.candidates(8), vec![8, 4]);
        assert_eq!(pc.candidates(4), vec![4]);
        assert_eq!(pc.candidates(3), vec![3]);
        assert!(pc.candidates(0).is_empty());
        // block 1: every offset, descending
        let pc = PrefixCache::new(1, 1);
        assert_eq!(pc.candidates(3), vec![3, 2, 1]);
        // block 0 clamps to 1
        assert_eq!(PrefixCache::new(0, 1).block(), 1);
    }
}

//! Generic associative prefix-scan primitives.
//!
//! `blelloch_inclusive` is the work-efficient tree scan (up-sweep +
//! down-sweep, O(n) work / O(log n) depth) shared by the `Blelloch`
//! execution strategy of both native filters: the KLA Moebius scan
//! (`kla::scan`) and the GLA affine scan (`baselines`).  It is generic
//! over any associative combiner, so the same tree drives Moebius maps,
//! affine (F, B) pairs, and plain sums alike.

/// In-place inclusive prefix scan with a work-efficient tree schedule.
///
/// `op(earlier, later)` combines the aggregate of an earlier index range
/// with the aggregate of the adjacent later range; it must be associative
/// but need not be commutative.  After the call, `xs[i]` holds
/// `op(op(..op(x0, x1).., ), xi)` — the inclusive prefix through `i`.
///
/// Up-sweep: for each power-of-two stride `d`, fold the left sibling into
/// the right (`xs[2d-1 + k*2d] = op(xs[.. - d], xs[..])`), building
/// subtree reductions.  Down-sweep: descending strides propagate the
/// prefix ending at `i - d` into the interior positions (`i = 3d-1 +
/// k*2d`).  Handles arbitrary (non-power-of-two) lengths.
pub fn blelloch_inclusive<M: Copy, F: Fn(&M, &M) -> M>(xs: &mut [M], op: F) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    // up-sweep
    let mut d = 1usize;
    while d < n {
        let step = d * 2;
        let mut i = step - 1;
        while i < n {
            xs[i] = op(&xs[i - d], &xs[i]);
            i += step;
        }
        d = step;
    }
    // down-sweep (inclusive variant: fill interior prefixes)
    d /= 2;
    while d > 0 {
        let step = d * 2;
        let mut i = 3 * d - 1;
        while i < n {
            xs[i] = op(&xs[i - d], &xs[i]);
            i += step;
        }
        d /= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_sequential_sum_all_lengths() {
        for n in 0..130usize {
            let mut xs: Vec<u64> = (0..n as u64).map(|i| i + 1).collect();
            blelloch_inclusive(&mut xs, |a, b| a + b);
            let mut acc = 0u64;
            for (i, &x) in xs.iter().enumerate() {
                acc += i as u64 + 1;
                assert_eq!(x, acc, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn non_commutative_op_keeps_order() {
        // 2x2 integer matrix product: associative, NOT commutative, exact.
        type M = [i64; 4];
        fn matmul(a: &M, b: &M) -> M {
            // combined = later * earlier (apply earlier first)
            [
                b[0] * a[0] + b[1] * a[2],
                b[0] * a[1] + b[1] * a[3],
                b[2] * a[0] + b[3] * a[2],
                b[2] * a[1] + b[3] * a[3],
            ]
        }
        for n in 1..40usize {
            let mats: Vec<M> = (0..n as i64)
                .map(|i| [1, i % 3, (i + 1) % 2, 1])
                .collect();
            let mut xs = mats.clone();
            blelloch_inclusive(&mut xs, matmul);
            let mut acc = [1i64, 0, 0, 1];
            for (i, m) in mats.iter().enumerate() {
                acc = matmul(&acc, m);
                assert_eq!(xs[i], acc, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn affine_pairs_compose() {
        // (F2, B2) ∘ (F1, B1) = (F2*F1, F2*B1 + B2), exact over integers
        let n = 37usize;
        let pairs: Vec<(i64, i64)> =
            (0..n as i64).map(|i| (1 + i % 2, i - 3)).collect();
        let mut xs = pairs.clone();
        blelloch_inclusive(&mut xs, |a, b| (b.0 * a.0, b.0 * a.1 + b.1));
        let mut acc = (1i64, 0i64);
        for (i, p) in pairs.iter().enumerate() {
            acc = (p.0 * acc.0, p.0 * acc.1 + p.1);
            assert_eq!(xs[i], acc, "i={i}");
        }
    }
}

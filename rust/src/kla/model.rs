//! Pure-Rust KLA language model — the native decode substrate.
//!
//! Mirrors `python/compile/models/{lm,kla,decode}.py` at the (B, T) level:
//! embed -> L x [RMSNorm -> causal conv(K) -> SiLU -> (k, q, v, lam_v)
//! projections -> information-filter update -> gated output -> residual]
//! -> RMSNorm -> head.  The filter update is NOT re-implemented here: the
//! full-sequence `prefix()` and the O(1) `step()` both go through
//! `kla::api::Filter` (`KlaFilter`), so model-level step-vs-prefix parity
//! reduces to the carry laws the conformance suite already pins at the
//! filter level.  Every per-position op (norm, conv window, projections)
//! is one shared helper used by both paths, so the parity is exact up to
//! identical f32 op order.
//!
//! Weights come from a deterministic seeded init (`NativeLm::seeded`,
//! mirroring `init_lm`'s scales) or from the train-checkpoint / init
//! artifact flatten ABI (`NativeLm::from_values`): per layer, the sorted
//! block keys [a_raw, blam, conv_b, conv_w, dt_raw, lam0_raw, norm,
//! p_raw, wg, wk, wlam, wo, wq, wv], then embed, head, norm_f.

use anyhow::{bail, Result};

use crate::api::{Filter, KlaBelief, KlaFilter, ScanPlan, Strategy};
use crate::kla::ou::{discretise_raw, sigmoid, softplus};
use crate::kla::scan::{FilterInputs, FilterParams};
use crate::runtime::backend::DecodeState;
use crate::runtime::Value;
use crate::tensor::{IntTensor, Tensor};
use crate::util::Pcg64;

/// Value-precision floor (python `models/kla.py::LAMV_FLOOR`).
pub const LAMV_FLOOR: f32 = 1e-4;
/// Prior-precision floor (python `models/kla.py::LAM0_FLOOR`).
pub const LAM0_FLOOR: f32 = 1e-3;

/// Arrays per KLA block in the flatten ABI (sorted block keys).
const BLOCK_ARRAYS: usize = 14;

/// Hyperparameters of a native KLA LM (the pure-KLA subset of the Python
/// `ModelConfig`; hybrids contain softmax attention and have no O(1)
/// recurrent state, so they stay on the XLA path).
#[derive(Clone, Copy, Debug)]
pub struct NativeLmConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_state: usize,
    pub conv_kernel: usize,
    pub process_noise: bool,
    pub ou_exact: bool,
}

impl Default for NativeLmConfig {
    fn default() -> Self {
        NativeLmConfig {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_state: 4,
            conv_kernel: 4,
            process_noise: true,
            ou_exact: true,
        }
    }
}

/// One KLA mixer block: raw weights plus the OU dynamics already
/// discretised into `filter` (abar, pbar, lam0; eta0 = 0) — the same
/// `FilterParams` the native scan strategies consume.
#[derive(Clone, Debug)]
pub struct KlaBlock {
    pub norm: Vec<f32>,     // (D)
    pub conv_w: Vec<f32>,   // (K, D) row-major
    pub conv_b: Vec<f32>,   // (D)
    pub wk: Vec<f32>,       // (D, N)
    pub wq: Vec<f32>,       // (D, N)
    pub wv: Vec<f32>,       // (D, D)
    pub wlam: Vec<f32>,     // (D, D)
    pub blam: Vec<f32>,     // (D)
    pub wg: Vec<f32>,       // (D, D)
    pub wo: Vec<f32>,       // (D, D)
    // raw OU / prior params (kept for checkpoint round-tripping)
    pub a_raw: Vec<f32>,    // (N, D)
    pub p_raw: Vec<f32>,    // (N, D)
    pub dt_raw: Vec<f32>,   // (N, D)
    pub lam0_raw: Vec<f32>, // (N, D)
    pub filter: FilterParams,
}

impl KlaBlock {
    fn seeded(cfg: &NativeLmConfig, rng: &mut Pcg64) -> Self {
        let (d, n, k) = (cfg.d_model, cfg.n_state, cfg.conv_kernel);
        let a_raw: Vec<f32> =
            (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let p_raw = vec![-4.6f32; n * d]; // softplus^-1(0.01), paper G.2
        let dt_raw: Vec<f32> =
            (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let lam0_raw = vec![0.5413f32; n * d]; // softplus(0.5413) = 1.0
        let filter = build_filter(cfg, &a_raw, &p_raw, &dt_raw, &lam0_raw);
        KlaBlock {
            norm: vec![1.0; d],
            conv_w: (0..k * d).map(|_| rng.normal_f32() * 0.2).collect(),
            conv_b: vec![0.0; d],
            wk: dense(rng, d, n, 1.0),
            wq: dense(rng, d, n, 1.0),
            wv: dense(rng, d, d, 1.0),
            wlam: dense(rng, d, d, 0.5),
            blam: vec![0.5413; d],
            wg: dense(rng, d, d, 1.0),
            wo: dense(rng, d, d, 0.5),
            a_raw,
            p_raw,
            dt_raw,
            lam0_raw,
            filter,
        }
    }
}

/// LeCun-normal dense init, std = scale / sqrt(d_in), row-major
/// (d_in, d_out) — same scales as `models/common.py::dense_init`.
fn dense(rng: &mut Pcg64, d_in: usize, d_out: usize, scale: f32)
         -> Vec<f32> {
    let std = scale / (d_in as f32).sqrt();
    (0..d_in * d_out).map(|_| rng.normal_f32() * std).collect()
}

/// Discretise the raw OU params into the native `FilterParams` carry.
fn build_filter(cfg: &NativeLmConfig, a_raw: &[f32], p_raw: &[f32],
                dt_raw: &[f32], lam0_raw: &[f32]) -> FilterParams {
    let s = cfg.n_state * cfg.d_model;
    let mut abar = vec![0.0f32; s];
    let mut pbar = vec![0.0f32; s];
    let mut lam0 = vec![0.0f32; s];
    for i in 0..s {
        let (ab, pb) = discretise_raw(a_raw[i], p_raw[i], dt_raw[i],
                                      cfg.process_noise, cfg.ou_exact);
        abar[i] = ab;
        pbar[i] = pb;
        lam0[i] = softplus(lam0_raw[i]) + LAM0_FLOOR;
    }
    FilterParams {
        n: cfg.n_state,
        d: cfg.d_model,
        abar,
        pbar,
        lam0,
        eta0: vec![0.0; s],
    }
}

/// The native KLA language model.
#[derive(Clone, Debug)]
pub struct NativeLm {
    pub cfg: NativeLmConfig,
    pub embed: Vec<f32>,  // (V, D)
    pub blocks: Vec<KlaBlock>,
    pub norm_f: Vec<f32>, // (D)
    pub head: Vec<f32>,   // (D, V)
}

// ------------------------------------------------- per-position helpers ---
// One set of helpers used by BOTH prefix() and step(), in the same op
// order, so the two paths agree bit-for-bit (the model-level analogue of
// the filter carry-split law).

fn rmsnorm_row(x: &[f32], scale: &[f32]) -> Vec<f32> {
    let d = x.len();
    let ms: f32 = x.iter().map(|&v| v * v).sum::<f32>() / d as f32;
    let r = 1.0 / (ms + 1e-6).sqrt();
    x.iter().zip(scale).map(|(&v, &s)| v * r * s).collect()
}

fn l2norm_row(x: &mut [f32]) {
    let n: f32 = x.iter().map(|&v| v * v).sum::<f32>();
    let r = 1.0 / (n + 1e-6).sqrt();
    for v in x.iter_mut() {
        *v *= r;
    }
}

fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// out[j] = sum_i x[i] * w[i * d_out + j]  (w row-major (d_in, d_out)).
fn matvec(x: &[f32], w: &[f32], d_in: usize, d_out: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    let mut out = vec![0.0f32; d_out];
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * d_out..(i + 1) * d_out];
        for (o, &wij) in out.iter_mut().zip(row) {
            *o += xi * wij;
        }
    }
    out
}

/// Causal-conv output at one position: `window` holds the K-1 previous
/// normed inputs (oldest first), `xn` the current one — the O(1) mirror
/// of `causal_conv1d` (python `conv_state_step`).
fn conv_row(conv_w: &[f32], conv_b: &[f32], window: &[f32], xn: &[f32],
            k_sz: usize, d: usize) -> Vec<f32> {
    let mut cy = vec![0.0f32; d];
    for ki in 0..k_sz - 1 {
        let wrow = &conv_w[ki * d..(ki + 1) * d];
        let srow = &window[ki * d..(ki + 1) * d];
        for di in 0..d {
            cy[di] += wrow[di] * srow[di];
        }
    }
    let wlast = &conv_w[(k_sz - 1) * d..k_sz * d];
    for di in 0..d {
        cy[di] += wlast[di] * xn[di] + conv_b[di];
    }
    cy
}

/// Shift the conv window left by one row and append `xn`.
fn push_window(window: &mut [f32], xn: &[f32], k_sz: usize, d: usize) {
    if k_sz < 2 {
        return;
    }
    window.copy_within(d.., 0);
    window[(k_sz - 2) * d..].copy_from_slice(xn);
}

/// One position's projections for one sequence; advances `window`.
struct RowProj {
    k: Vec<f32>,     // (N)
    q: Vec<f32>,     // (N)
    v: Vec<f32>,     // (D)
    lam_v: Vec<f32>, // (D)
    gate: Vec<f32>,  // (D)
}

fn project_row(blk: &KlaBlock, x: &[f32], window: &mut [f32], d: usize,
               n: usize, k_sz: usize) -> RowProj {
    let xn = rmsnorm_row(x, &blk.norm);
    let mut c = conv_row(&blk.conv_w, &blk.conv_b, window, &xn, k_sz, d);
    push_window(window, &xn, k_sz, d);
    for v in c.iter_mut() {
        *v = silu(*v);
    }
    let mut k = matvec(&c, &blk.wk, d, n);
    l2norm_row(&mut k);
    let mut q = matvec(&c, &blk.wq, d, n);
    l2norm_row(&mut q);
    let v = matvec(&c, &blk.wv, d, d);
    let mut lam_v = matvec(&c, &blk.wlam, d, d);
    for (lv, &b) in lam_v.iter_mut().zip(&blk.blam) {
        *lv = softplus(*lv + b) + LAMV_FLOOR;
    }
    let mut gate = matvec(&xn, &blk.wg, d, d);
    for g in gate.iter_mut() {
        *g = silu(*g);
    }
    RowProj { k, q, v, lam_v, gate }
}

impl NativeLm {
    /// Deterministic seeded init, mirroring `init_lm`'s scales.
    pub fn seeded(cfg: &NativeLmConfig, seed: u64) -> Self {
        assert!(cfg.vocab >= 2 && cfg.d_model >= 1 && cfg.n_layers >= 1
                    && cfg.n_state >= 1 && cfg.conv_kernel >= 1,
                "degenerate NativeLmConfig {cfg:?}");
        let mut rng = Pcg64::seeded(seed);
        let (v, d) = (cfg.vocab, cfg.d_model);
        let embed: Vec<f32> =
            (0..v * d).map(|_| rng.normal_f32() * 0.02).collect();
        let blocks = (0..cfg.n_layers)
            .map(|_| KlaBlock::seeded(cfg, &mut rng))
            .collect();
        let norm_f = vec![1.0; d];
        let head = dense(&mut rng, d, v, 0.5);
        NativeLm { cfg: *cfg, embed, blocks, norm_f, head }
    }

    /// Load from the flatten-ABI param list (the order `{base}_init`
    /// emits and `train::checkpoint` stores).  Dimensions are inferred
    /// from the array shapes; the two ablation switches are not recorded
    /// in the ABI and must be supplied.
    pub fn from_values(values: &[Value], process_noise: bool,
                       ou_exact: bool) -> Result<Self> {
        if values.len() < BLOCK_ARRAYS + 3
            || (values.len() - 3) % BLOCK_ARRAYS != 0
        {
            bail!("param list of {} arrays is not a KLA LM \
                   ({BLOCK_ARRAYS} per block + embed/head/norm_f)",
                  values.len());
        }
        let n_layers = (values.len() - 3) / BLOCK_ARRAYS;
        let embed_t = values[n_layers * BLOCK_ARRAYS].as_f32()?;
        let es = embed_t.shape();
        if es.len() != 2 {
            bail!("embed must be 2-D, got {es:?}");
        }
        let (vocab, d_model) = (es[0], es[1]);
        let a0 = values[0].as_f32()?;
        if a0.shape().len() != 2 || a0.shape()[1] != d_model {
            bail!("a_raw shape {:?} inconsistent with d_model {d_model}",
                  a0.shape());
        }
        let n_state = a0.shape()[0];
        let cw0 = values[3].as_f32()?;
        if cw0.shape().len() != 2 || cw0.shape()[1] != d_model {
            bail!("conv_w shape {:?} inconsistent with d_model {d_model}",
                  cw0.shape());
        }
        let conv_kernel = cw0.shape()[0];
        if vocab < 2 || d_model < 1 || n_state < 1 || conv_kernel < 1 {
            bail!("degenerate inferred dims: vocab={vocab} d={d_model} \
                   n={n_state} k={conv_kernel}");
        }
        let cfg = NativeLmConfig {
            vocab,
            d_model,
            n_layers,
            n_state,
            conv_kernel,
            process_noise,
            ou_exact,
        };
        let (d, n, k) = (d_model, n_state, conv_kernel);
        let grab = |i: usize, shape: &[usize], what: &str|
                    -> Result<Vec<f32>> {
            let t = values[i].as_f32()?;
            if t.shape() != shape {
                bail!("{what} (param {i}): shape {:?}, expected {shape:?}",
                      t.shape());
            }
            Ok(t.data().to_vec())
        };
        let mut blocks = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let b = l * BLOCK_ARRAYS;
            let a_raw = grab(b, &[n, d], "a_raw")?;
            let blam = grab(b + 1, &[d], "blam")?;
            let conv_b = grab(b + 2, &[d], "conv_b")?;
            let conv_w = grab(b + 3, &[k, d], "conv_w")?;
            let dt_raw = grab(b + 4, &[n, d], "dt_raw")?;
            let lam0_raw = grab(b + 5, &[n, d], "lam0_raw")?;
            let norm = grab(b + 6, &[d], "norm")?;
            let p_raw = grab(b + 7, &[n, d], "p_raw")?;
            let wg = grab(b + 8, &[d, d], "wg")?;
            let wk = grab(b + 9, &[d, n], "wk")?;
            let wlam = grab(b + 10, &[d, d], "wlam")?;
            let wo = grab(b + 11, &[d, d], "wo")?;
            let wq = grab(b + 12, &[d, n], "wq")?;
            let wv = grab(b + 13, &[d, d], "wv")?;
            let filter =
                build_filter(&cfg, &a_raw, &p_raw, &dt_raw, &lam0_raw);
            blocks.push(KlaBlock {
                norm, conv_w, conv_b, wk, wq, wv, wlam, blam, wg, wo,
                a_raw, p_raw, dt_raw, lam0_raw, filter,
            });
        }
        let base = n_layers * BLOCK_ARRAYS;
        let embed = grab(base, &[vocab, d], "embed")?;
        let head = grab(base + 1, &[d, vocab], "head")?;
        let norm_f = grab(base + 2, &[d], "norm_f")?;
        Ok(NativeLm { cfg, embed, blocks, norm_f, head })
    }

    /// Export in the same flatten ABI (inverse of `from_values`), e.g.
    /// for `train::checkpoint::save`.
    pub fn to_values(&self) -> Vec<Value> {
        let (v, d, n, k) = (self.cfg.vocab, self.cfg.d_model,
                            self.cfg.n_state, self.cfg.conv_kernel);
        let t = |shape: &[usize], data: &[f32]| {
            Value::F32(Tensor::new(shape, data.to_vec())
                .expect("consistent model shapes"))
        };
        let mut out = Vec::with_capacity(
            self.blocks.len() * BLOCK_ARRAYS + 3);
        for blk in &self.blocks {
            out.push(t(&[n, d], &blk.a_raw));
            out.push(t(&[d], &blk.blam));
            out.push(t(&[d], &blk.conv_b));
            out.push(t(&[k, d], &blk.conv_w));
            out.push(t(&[n, d], &blk.dt_raw));
            out.push(t(&[n, d], &blk.lam0_raw));
            out.push(t(&[d], &blk.norm));
            out.push(t(&[n, d], &blk.p_raw));
            out.push(t(&[d, d], &blk.wg));
            out.push(t(&[d, n], &blk.wk));
            out.push(t(&[d, d], &blk.wlam));
            out.push(t(&[d, d], &blk.wo));
            out.push(t(&[d, n], &blk.wq));
            out.push(t(&[d, d], &blk.wv));
        }
        out.push(t(&[v, d], &self.embed));
        out.push(t(&[d, v], &self.head));
        out.push(t(&[d], &self.norm_f));
        out
    }

    /// Embedding row for a token id, clamped into [0, vocab) — network
    /// clients can send arbitrary ids.
    fn embed_row(&self, tok: i32) -> &[f32] {
        let d = self.cfg.d_model;
        let id = (tok.max(0) as usize).min(self.cfg.vocab - 1);
        &self.embed[id * d..(id + 1) * d]
    }

    /// Fresh decode state for `batch` sequences: conv window zeros,
    /// precision at the learned prior lam0, information mean zero —
    /// the native mirror of `decode.py::decode_init_state`.
    pub fn init_state(&self, batch: usize) -> DecodeState {
        let (l, d, n, k) = (self.cfg.n_layers, self.cfg.d_model,
                            self.cfg.n_state, self.cfg.conv_kernel);
        let conv = Tensor::zeros(&[l, batch, k - 1, d]);
        let mut lam = Tensor::zeros(&[l, batch, n, d]);
        for (li, blk) in self.blocks.iter().enumerate() {
            for bi in 0..batch {
                let off = (li * batch + bi) * n * d;
                lam.data_mut()[off..off + n * d]
                    .copy_from_slice(&blk.filter.lam0);
            }
        }
        let eta = Tensor::zeros(&[l, batch, n, d]);
        DecodeState { conv, lam, eta }
    }

    /// Batched full-sequence forward: tokens (B, T) -> logits (B, T, V).
    /// Runs [`Self::prefix_from`] from the zero-history prior state under
    /// the sequential plan — bit-identical to chained `step()`.
    pub fn prefix(&self, tokens: &IntTensor) -> Result<Tensor> {
        let ts = tokens.shape();
        if ts.len() != 2 {
            bail!("prefix wants (B, T) tokens, got {ts:?}");
        }
        let state = self.init_state(ts[0]);
        let (logits, _) =
            self.prefix_from(tokens, &state, &ScanPlan::sequential())?;
        Ok(logits)
    }

    /// Batched full-sequence forward FROM a carried decode state: tokens
    /// (B, T) + state -> (logits (B, T, V), advanced state) — the
    /// batched-prefix entry behind scan-based chunked prefill.  Each
    /// block runs the per-position projections (norm, conv window,
    /// k/q/v/lam_v, gate) through the same helpers `step()` uses, then
    /// one `KlaFilter::prefix` per lane under `plan`: the sequential
    /// strategy is bit-identical to chained `step()`, Chunked/Blelloch
    /// agree within the 1e-5 conformance tolerance (the `Filter` trait
    /// laws), so prefilling a prompt in one call is generation-equivalent
    /// to feeding it token by token.
    pub fn prefix_from(&self, tokens: &IntTensor, state: &DecodeState,
                       plan: &ScanPlan) -> Result<(Tensor, DecodeState)> {
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let (h, next) = self.forward_from(tokens, state, plan)?;
        let (b, t) = (tokens.shape()[0], tokens.shape()[1]);
        let mut logits = vec![0.0f32; b * t * v];
        for r in 0..b * t {
            let hn = rmsnorm_row(&h[r * d..(r + 1) * d], &self.norm_f);
            let lrow = matvec(&hn, &self.head, d, v);
            logits[r * v..(r + 1) * v].copy_from_slice(&lrow);
        }
        Ok((Tensor::new(&[b, t, v], logits)?, next))
    }

    /// Scan-based prefill of ONE batch lane: consume `tokens` (T,) for
    /// `slot` starting from that lane's carried state, returning the
    /// logits (V,) after the last token and the advanced single-lane
    /// (B=1) state.  Lanes are independent, so no other lane of `state`
    /// is read or advanced — the serving engine prefills freshly admitted
    /// slots without stepping the whole batch, and only the last
    /// position's head projection is computed (prefill outputs before the
    /// final token are never sampled).
    pub fn prefill_slot(&self, tokens: &IntTensor, slot: usize,
                        state: &DecodeState, plan: &ScanPlan)
                        -> Result<(Tensor, DecodeState)> {
        let ts = tokens.shape();
        if ts.len() != 1 || ts[0] == 0 {
            bail!("prefill_slot wants non-empty (T,) tokens, got {ts:?}");
        }
        let t = ts[0];
        let lane = state.slot(slot)?;
        let toks = IntTensor::new(&[1, t], tokens.data().to_vec())?;
        let (h, next) = self.forward_from(&toks, &lane, plan)?;
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let hn = rmsnorm_row(&h[(t - 1) * d..t * d], &self.norm_f);
        let lrow = matvec(&hn, &self.head, d, v);
        Ok((Tensor::new(&[v], lrow)?, next))
    }

    /// Fused multi-dimensional (slots × time) prefill: one ragged token
    /// chunk per lane — `lanes[i] = (slot, tokens)`, slots distinct,
    /// every chunk non-empty — scanned together from the carried
    /// batched `state`.  Returns, per lane, `(slot, last-position
    /// logits (V,), advanced single-lane state)` in submission order.
    /// No lane outside `lanes` is read or advanced.
    ///
    /// Execution resolves through [`ScanPlan::resolve_lanes`]: under
    /// `Strategy::Chained { threads }` (what `Auto` picks for two or
    /// more lanes) the lanes are distributed across the shared
    /// persistent pool (`util::thread_pool`) — the row-chained layout
    /// of a multi-dimensional scan, each lane's time axis one
    /// sequential chain, so every lane is bit-exact against
    /// [`Self::prefill_slot`] under the sequential plan.  Any other
    /// resolved strategy runs the lanes in submission order with that
    /// per-lane time strategy, making an explicit Blelloch/Chunked
    /// plan behave exactly like per-slot prefill.
    pub fn prefill_ragged(&self, lanes: &[(usize, &[i32])],
                          state: &DecodeState, plan: &ScanPlan)
                          -> Result<Vec<(usize, Tensor, DecodeState)>> {
        if lanes.is_empty() {
            return Ok(Vec::new());
        }
        let b = state.batch();
        let mut used = vec![false; b];
        let mut max_t = 0usize;
        for &(slot, toks) in lanes {
            if toks.is_empty() {
                bail!("prefill_ragged: empty token lane for slot {slot}");
            }
            if slot >= b {
                bail!("prefill_ragged: slot {slot} out of range for \
                       batch {b}");
            }
            if used[slot] {
                bail!("prefill_ragged: slot {slot} appears twice");
            }
            used[slot] = true;
            max_t = max_t.max(toks.len());
        }
        let (workers, lane_plan) =
            match plan.resolve_lanes(lanes.len(), max_t) {
                Strategy::Chained { threads } => {
                    (threads.min(lanes.len()), ScanPlan::sequential())
                }
                s => (1, ScanPlan::new().with_strategy(s)),
            };
        let run = |&(slot, toks): &(usize, &[i32])|
                   -> Result<(usize, Tensor, DecodeState)> {
            let tok_t = IntTensor::new(&[toks.len()], toks.to_vec())?;
            let (logits, lane) =
                self.prefill_slot(&tok_t, slot, state, &lane_plan)?;
            Ok((slot, logits, lane))
        };
        if workers <= 1 {
            return lanes.iter().map(run).collect();
        }
        let mut out: Vec<Option<Result<(usize, Tensor, DecodeState)>>> =
            Vec::new();
        out.resize_with(lanes.len(), || None);
        let chunk = lanes.len().div_ceil(workers);
        crate::util::thread_pool::ThreadPool::global().scope(|scope| {
            let mut rest = &mut out[..];
            let mut base = 0usize;
            while !rest.is_empty() {
                let take = chunk.min(rest.len());
                let (head, tail) = rest.split_at_mut(take);
                rest = tail;
                let run = &run;
                scope.spawn(move || {
                    for (off, cell) in head.iter_mut().enumerate() {
                        *cell = Some(run(&lanes[base + off]));
                    }
                });
                base += take;
            }
        });
        out.into_iter()
            .map(|cell| cell.expect("every lane ran"))
            .collect()
    }

    /// Shared forward core of [`Self::prefix_from`] / [`Self::prefill_slot`]:
    /// residual stream h (B, T, D) plus the advanced state, head not yet
    /// applied.  The conv window in `state` seeds each lane's projection
    /// history and the (lam, eta) lanes seed each layer's filter belief,
    /// so a forward over a token slice composes exactly like the carry-
    /// split law of the underlying `Filter`.
    fn forward_from(&self, tokens: &IntTensor, state: &DecodeState,
                    plan: &ScanPlan) -> Result<(Vec<f32>, DecodeState)> {
        let ts = tokens.shape();
        if ts.len() != 2 {
            bail!("forward wants (B, T) tokens, got {ts:?}");
        }
        let (b, t) = (ts[0], ts[1]);
        let (l_n, d, n, k_sz) =
            (self.cfg.n_layers, self.cfg.d_model, self.cfg.n_state,
             self.cfg.conv_kernel);
        if state.conv.shape() != [l_n, b, k_sz - 1, d]
            || state.lam.shape() != [l_n, b, n, d]
            || state.eta.shape() != [l_n, b, n, d]
        {
            bail!("decode state shapes {:?}/{:?}/{:?} do not match model \
                   (L={l_n}, B={b}, K={k_sz}, N={n}, D={d})",
                  state.conv.shape(), state.lam.shape(),
                  state.eta.shape());
        }
        let conv_sz = (k_sz - 1) * d;
        let post_sz = n * d;
        let mut next = state.clone();
        let mut h = vec![0.0f32; b * t * d];
        for (i, &tok) in tokens.data().iter().enumerate() {
            h[i * d..(i + 1) * d].copy_from_slice(self.embed_row(tok));
        }
        if t == 0 {
            return Ok((h, next));
        }
        for (li, blk) in self.blocks.iter().enumerate() {
            for bi in 0..b {
                let coff = (li * b + bi) * conv_sz;
                let poff = (li * b + bi) * post_sz;
                let mut k_all = Vec::with_capacity(t * n);
                let mut q_all = Vec::with_capacity(t * n);
                let mut v_all = Vec::with_capacity(t * d);
                let mut lamv_all = Vec::with_capacity(t * d);
                let mut gate_all = Vec::with_capacity(t * d);
                {
                    let window =
                        &mut next.conv.data_mut()[coff..coff + conv_sz];
                    for ti in 0..t {
                        let row =
                            &h[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                        let pr = project_row(blk, row, window, d, n, k_sz);
                        k_all.extend_from_slice(&pr.k);
                        q_all.extend_from_slice(&pr.q);
                        v_all.extend_from_slice(&pr.v);
                        lamv_all.extend_from_slice(&pr.lam_v);
                        gate_all.extend_from_slice(&pr.gate);
                    }
                }
                let inp = FilterInputs {
                    t,
                    k: k_all,
                    q: q_all,
                    v: v_all,
                    lam_v: lamv_all,
                };
                let belief = KlaBelief::from_parts(
                    next.lam.data()[poff..poff + post_sz].to_vec(),
                    next.eta.data()[poff..poff + post_sz].to_vec(),
                );
                let (out, carried) =
                    KlaFilter::prefix(&blk.filter, &inp, &belief, plan);
                next.lam.data_mut()[poff..poff + post_sz]
                    .copy_from_slice(&carried.lam);
                next.eta.data_mut()[poff..poff + post_sz]
                    .copy_from_slice(&carried.eta);
                for ti in 0..t {
                    let y = &out.y[ti * d..(ti + 1) * d];
                    let gate = &gate_all[ti * d..(ti + 1) * d];
                    let yg: Vec<f32> =
                        y.iter().zip(gate).map(|(&a, &g)| a * g).collect();
                    let delta = matvec(&yg, &blk.wo, d, d);
                    let row =
                        &mut h[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                    for di in 0..d {
                        row[di] += delta[di];
                    }
                }
            }
        }
        Ok((h, next))
    }

    /// One autoregressive step: tokens (B,) + state -> (logits (B, V),
    /// new state).  State layout (L,B,K-1,D) / (L,B,N,D) — the same one
    /// the XLA decode artifact uses, so `BeliefStateCache` works
    /// unchanged on either backend.
    pub fn step(&self, tokens: &IntTensor, state: &DecodeState)
                -> Result<(Tensor, DecodeState)> {
        let ts = tokens.shape();
        if ts.len() != 1 {
            bail!("step wants (B,) tokens, got {ts:?}");
        }
        let b = ts[0];
        let (l_n, d, n, k_sz, v) =
            (self.cfg.n_layers, self.cfg.d_model, self.cfg.n_state,
             self.cfg.conv_kernel, self.cfg.vocab);
        if state.conv.shape() != [l_n, b, k_sz - 1, d]
            || state.lam.shape() != [l_n, b, n, d]
            || state.eta.shape() != [l_n, b, n, d]
        {
            bail!("decode state shapes {:?}/{:?}/{:?} do not match model \
                   (L={l_n}, B={b}, K={k_sz}, N={n}, D={d})",
                  state.conv.shape(), state.lam.shape(),
                  state.eta.shape());
        }
        let conv_sz = (k_sz - 1) * d;
        let post_sz = n * d;
        let mut next = state.clone();
        let mut logits = vec![0.0f32; b * v];
        for bi in 0..b {
            let mut x = self.embed_row(tokens.data()[bi]).to_vec();
            for (li, blk) in self.blocks.iter().enumerate() {
                let coff = (li * b + bi) * conv_sz;
                let poff = (li * b + bi) * post_sz;
                let pr = {
                    let window =
                        &mut next.conv.data_mut()[coff..coff + conv_sz];
                    project_row(blk, &x, window, d, n, k_sz)
                };
                let mut belief = KlaBelief::from_parts(
                    next.lam.data()[poff..poff + post_sz].to_vec(),
                    next.eta.data()[poff..poff + post_sz].to_vec(),
                );
                let inp = FilterInputs {
                    t: 1,
                    k: pr.k,
                    q: pr.q,
                    v: pr.v,
                    lam_v: pr.lam_v,
                };
                let y = KlaFilter::step(&blk.filter, &inp, 0, &mut belief);
                next.lam.data_mut()[poff..poff + post_sz]
                    .copy_from_slice(&belief.lam);
                next.eta.data_mut()[poff..poff + post_sz]
                    .copy_from_slice(&belief.eta);
                let yg: Vec<f32> = y
                    .iter()
                    .zip(&pr.gate)
                    .map(|(&a, &g)| a * g)
                    .collect();
                let delta = matvec(&yg, &blk.wo, d, d);
                for di in 0..d {
                    x[di] += delta[di];
                }
            }
            let hn = rmsnorm_row(&x, &self.norm_f);
            let lrow = matvec(&hn, &self.head, d, v);
            logits[bi * v..(bi + 1) * v].copy_from_slice(&lrow);
        }
        Ok((Tensor::new(&[b, v], logits)?, next))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NativeLmConfig {
        NativeLmConfig {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_state: 2,
            conv_kernel: 3,
            process_noise: true,
            ou_exact: true,
        }
    }

    #[test]
    fn seeded_init_is_deterministic_and_seed_sensitive() {
        let a = NativeLm::seeded(&tiny(), 5);
        let b = NativeLm::seeded(&tiny(), 5);
        assert_eq!(a.embed, b.embed);
        assert_eq!(a.head, b.head);
        assert_eq!(a.blocks[1].wk, b.blocks[1].wk);
        let c = NativeLm::seeded(&tiny(), 6);
        assert_ne!(a.embed, c.embed);
    }

    #[test]
    fn prefix_logits_finite_and_spread() {
        let lm = NativeLm::seeded(&tiny(), 1);
        let toks = IntTensor::new(&[2, 9],
                                  (0..18).map(|i| i % 16).collect())
            .unwrap();
        let logits = lm.prefix(&toks).unwrap();
        assert_eq!(logits.shape(), &[2, 9, 16]);
        assert!(logits.data().iter().all(|x| x.is_finite()));
        let (lo, hi) = logits
            .data()
            .iter()
            .fold((f32::MAX, f32::MIN), |(l, h), &x| (l.min(x), h.max(x)));
        assert!(hi - lo > 1e-4, "uniform logits: [{lo}, {hi}]");
    }

    #[test]
    fn step_chain_matches_prefix_exactly() {
        let lm = NativeLm::seeded(&tiny(), 2);
        let (b, t) = (2usize, 7usize);
        let toks: Vec<i32> = (0..b * t).map(|i| (i * 5 % 16) as i32)
            .collect();
        let full = lm
            .prefix(&IntTensor::new(&[b, t], toks.clone()).unwrap())
            .unwrap();
        let mut state = lm.init_state(b);
        for ti in 0..t {
            let col: Vec<i32> =
                (0..b).map(|bi| toks[bi * t + ti]).collect();
            let (logits, next) = lm
                .step(&IntTensor::new(&[b], col).unwrap(), &state)
                .unwrap();
            state = next;
            for bi in 0..b {
                for vi in 0..16 {
                    assert_eq!(logits.get(&[bi, vi]),
                               full.get(&[bi, ti, vi]),
                               "bi={bi} ti={ti} vi={vi}");
                }
            }
        }
    }

    #[test]
    fn out_of_range_tokens_clamp() {
        let lm = NativeLm::seeded(&tiny(), 3);
        let state = lm.init_state(1);
        let lo = lm.step(&IntTensor::new(&[1], vec![-7]).unwrap(), &state)
            .unwrap();
        let lo0 = lm.step(&IntTensor::new(&[1], vec![0]).unwrap(), &state)
            .unwrap();
        assert_eq!(lo.0.data(), lo0.0.data());
        let hi = lm.step(&IntTensor::new(&[1], vec![999]).unwrap(), &state)
            .unwrap();
        let hi0 = lm.step(&IntTensor::new(&[1], vec![15]).unwrap(), &state)
            .unwrap();
        assert_eq!(hi.0.data(), hi0.0.data());
    }

    #[test]
    fn values_roundtrip_preserves_model() {
        let lm = NativeLm::seeded(&tiny(), 4);
        let vals = lm.to_values();
        assert_eq!(vals.len(), 2 * 14 + 3);
        let lm2 = NativeLm::from_values(&vals, true, true).unwrap();
        assert_eq!(lm2.cfg.vocab, 16);
        assert_eq!(lm2.cfg.n_layers, 2);
        assert_eq!(lm2.cfg.conv_kernel, 3);
        let toks = IntTensor::new(&[1, 6], vec![1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(lm.prefix(&toks).unwrap().data(),
                   lm2.prefix(&toks).unwrap().data());
    }

    #[test]
    fn from_values_rejects_malformed_lists() {
        let lm = NativeLm::seeded(&tiny(), 4);
        let mut vals = lm.to_values();
        vals.pop();
        assert!(NativeLm::from_values(&vals, true, true).is_err());
    }

    #[test]
    fn prefix_from_chaining_is_exact_on_sequential() {
        // carry-split at the model level: running a prompt in two
        // prefix_from calls through the carried state reproduces the
        // one-shot prefix bit-for-bit on the sequential plan
        let lm = NativeLm::seeded(&tiny(), 11);
        let (b, t) = (2usize, 11usize);
        let toks: Vec<i32> =
            (0..b * t).map(|i| (i * 3 % 16) as i32).collect();
        let full = lm
            .prefix(&IntTensor::new(&[b, t], toks.clone()).unwrap())
            .unwrap();
        for cut in [0usize, 1, 5, t - 1, t] {
            let plan = ScanPlan::sequential();
            let state = lm.init_state(b);
            let head: Vec<i32> = (0..b)
                .flat_map(|bi| toks[bi * t..bi * t + cut].to_vec())
                .collect();
            let tail: Vec<i32> = (0..b)
                .flat_map(|bi| toks[bi * t + cut..(bi + 1) * t].to_vec())
                .collect();
            let (lo, mid) = lm
                .prefix_from(&IntTensor::new(&[b, cut], head).unwrap(),
                             &state, &plan)
                .unwrap();
            let (hi, _) = lm
                .prefix_from(&IntTensor::new(&[b, t - cut], tail).unwrap(),
                             &mid, &plan)
                .unwrap();
            for bi in 0..b {
                for ti in 0..t {
                    for vi in 0..16 {
                        let got = if ti < cut {
                            lo.get(&[bi, ti, vi])
                        } else {
                            hi.get(&[bi, ti - cut, vi])
                        };
                        assert_eq!(got, full.get(&[bi, ti, vi]),
                                   "cut={cut} bi={bi} ti={ti} vi={vi}");
                    }
                }
            }
        }
    }

    #[test]
    fn prefill_slot_matches_step_chain() {
        let lm = NativeLm::seeded(&tiny(), 21);
        let b = 3usize;
        let t = 13usize;
        let toks: Vec<i32> = (0..t).map(|i| (i * 5 % 16) as i32).collect();
        // dirty the state first so the prefill resumes a real carry
        let mut state = lm.init_state(b);
        for warm in [2i32, 9, 4] {
            let (_, next) = lm
                .step(&IntTensor::new(&[b], vec![warm; b]).unwrap(), &state)
                .unwrap();
            state = next;
        }
        // reference: chain step() feeding the same token to every lane
        let mut ref_state = state.clone();
        let mut ref_logits = Tensor::zeros(&[b, 16]);
        for &tok in &toks {
            let (lg, next) = lm
                .step(&IntTensor::new(&[b], vec![tok; b]).unwrap(),
                      &ref_state)
                .unwrap();
            ref_state = next;
            ref_logits = lg;
        }
        let slot = 1usize;
        let ref_lane = ref_state.slot(slot).unwrap();
        let tok_t = IntTensor::new(&[t], toks.clone()).unwrap();
        // sequential plan: exact
        let (lg, lane) = lm
            .prefill_slot(&tok_t, slot, &state, &ScanPlan::sequential())
            .unwrap();
        assert_eq!(lg.shape(), &[16]);
        for vi in 0..16 {
            assert_eq!(lg.get(&[vi]), ref_logits.get(&[slot, vi]), "{vi}");
        }
        assert_eq!(lane.lam.data(), ref_lane.lam.data());
        assert_eq!(lane.eta.data(), ref_lane.eta.data());
        assert_eq!(lane.conv.data(), ref_lane.conv.data());
        // parallel plans: the 1e-5 conformance tolerance
        for plan in [ScanPlan::blelloch(), ScanPlan::chunked(2)] {
            let (lg, lane) =
                lm.prefill_slot(&tok_t, slot, &state, &plan).unwrap();
            let close =
                |a: f32, e: f32| crate::testing::rel_close(a, e, 1e-5);
            for vi in 0..16 {
                assert!(close(lg.get(&[vi]), ref_logits.get(&[slot, vi])),
                        "plan={plan:?} vi={vi}");
            }
            for (a, e) in lane.lam.data().iter().zip(ref_lane.lam.data()) {
                assert!(close(*a, *e), "plan={plan:?} lam {a} vs {e}");
            }
            for (a, e) in lane.eta.data().iter().zip(ref_lane.eta.data()) {
                assert!(close(*a, *e), "plan={plan:?} eta {a} vs {e}");
            }
        }
    }

    #[test]
    fn prefill_ragged_matches_per_slot_bit_exact() {
        // the fused (slots × time) round is the row-chained layout:
        // each lane sequential, so per-lane results are bit-identical
        // to prefill_slot under the sequential plan
        let lm = NativeLm::seeded(&tiny(), 31);
        let b = 4usize;
        // dirty the carry so lanes differ
        let mut state = lm.init_state(b);
        for warm in [3i32, 7] {
            let col: Vec<i32> = (0..b).map(|bi| warm + bi as i32).collect();
            let (_, next) = lm
                .step(&IntTensor::new(&[b], col).unwrap(), &state)
                .unwrap();
            state = next;
        }
        let chunks: Vec<Vec<i32>> = vec![
            (0..9).map(|i| (i * 5 % 16) as i32).collect(),
            vec![2],
            (0..17).map(|i| (i * 3 % 16) as i32).collect(),
        ];
        // ragged lanes on slots {0, 2, 3}; slot 1 untouched
        let lanes: Vec<(usize, &[i32])> = vec![
            (0, &chunks[0][..]),
            (2, &chunks[1][..]),
            (3, &chunks[2][..]),
        ];
        for plan in [ScanPlan::auto(), ScanPlan::chained(3),
                     ScanPlan::chained(1)] {
            let fused = lm.prefill_ragged(&lanes, &state, &plan).unwrap();
            assert_eq!(fused.len(), lanes.len(), "plan={plan:?}");
            for ((slot, toks), (fslot, flg, flane)) in
                lanes.iter().zip(&fused)
            {
                assert_eq!(slot, fslot);
                let tok_t =
                    IntTensor::new(&[toks.len()], toks.to_vec()).unwrap();
                let (lg, lane) = lm
                    .prefill_slot(&tok_t, *slot, &state,
                                  &ScanPlan::sequential())
                    .unwrap();
                assert_eq!(flg.data(), lg.data(),
                           "plan={plan:?} slot={slot}");
                assert_eq!(flane.lam.data(), lane.lam.data());
                assert_eq!(flane.eta.data(), lane.eta.data());
                assert_eq!(flane.conv.data(), lane.conv.data());
            }
        }
    }

    #[test]
    fn prefill_ragged_explicit_plan_behaves_like_per_slot() {
        // an explicit Blelloch plan runs each lane with that time
        // strategy — identical to prefill_slot under the same plan
        let lm = NativeLm::seeded(&tiny(), 32);
        let state = lm.init_state(2);
        let a: Vec<i32> = (0..11).map(|i| (i % 16) as i32).collect();
        let lanes: Vec<(usize, &[i32])> = vec![(1, &a[..])];
        let fused = lm
            .prefill_ragged(&lanes, &state, &ScanPlan::blelloch())
            .unwrap();
        let tok_t = IntTensor::new(&[a.len()], a.clone()).unwrap();
        let (lg, lane) = lm
            .prefill_slot(&tok_t, 1, &state, &ScanPlan::blelloch())
            .unwrap();
        assert_eq!(fused[0].1.data(), lg.data());
        assert_eq!(fused[0].2.lam.data(), lane.lam.data());
    }

    #[test]
    fn prefill_ragged_validates_lanes() {
        let lm = NativeLm::seeded(&tiny(), 33);
        let state = lm.init_state(2);
        let a = [1i32, 2, 3];
        // empty lane set is fine
        assert!(lm
            .prefill_ragged(&[], &state, &ScanPlan::auto())
            .unwrap()
            .is_empty());
        // empty chunk
        assert!(lm
            .prefill_ragged(&[(0, &[][..])], &state, &ScanPlan::auto())
            .is_err());
        // slot out of range
        assert!(lm
            .prefill_ragged(&[(2, &a[..])], &state, &ScanPlan::auto())
            .is_err());
        // duplicate slot
        assert!(lm
            .prefill_ragged(&[(0, &a[..]), (0, &a[..])], &state,
                            &ScanPlan::auto())
            .is_err());
    }

    #[test]
    fn prefill_slot_rejects_empty_and_bad_slot() {
        let lm = NativeLm::seeded(&tiny(), 22);
        let state = lm.init_state(2);
        let empty = IntTensor::new(&[0], vec![]).unwrap();
        assert!(lm
            .prefill_slot(&empty, 0, &state, &ScanPlan::sequential())
            .is_err());
        let one = IntTensor::new(&[1], vec![3]).unwrap();
        assert!(lm
            .prefill_slot(&one, 2, &state, &ScanPlan::sequential())
            .is_err());
    }

    #[test]
    fn step_increases_precision() {
        let lm = NativeLm::seeded(&tiny(), 8);
        let state = lm.init_state(1);
        let lam0: f32 = state.lam.data().iter().sum();
        let (_, next) = lm
            .step(&IntTensor::new(&[1], vec![3]).unwrap(), &state)
            .unwrap();
        let lam1: f32 = next.lam.data().iter().sum();
        assert!(lam1.is_finite() && (lam1 - lam0).abs() > 1e-9,
                "step left precision untouched: {lam0} -> {lam1}");
    }
}

//! Stateful sessions over artifacts: training, scoring, O(1) decoding.
//!
//! A `TrainSession` owns the model + optimiser state for one artifact base
//! (e.g. "mad_kla"): parameters initialised from the `_init` artifact, the
//! `_train` step advancing (params, m, v, step) and returning the loss, and
//! `_eval` computing masked loss/accuracy.  State stays in host `Value`s
//! between steps (the CPU PJRT "device" shares host memory, so uploads are
//! memcpys; see EXPERIMENTS.md §Perf for the measured step breakdown).

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::{Artifact, Runtime, Value};
use crate::data::Batch;
use crate::tensor::{IntTensor, Tensor};

/// Aggregated evaluation counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub loss_sum: f64,
    pub correct: f64,
    pub count: f64,
}

impl EvalResult {
    pub fn accuracy(&self) -> f64 {
        if self.count > 0.0 {
            self.correct / self.count
        } else {
            f64::NAN
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.count > 0.0 {
            self.loss_sum / self.count
        } else {
            f64::NAN
        }
    }

    pub fn merge(&mut self, other: EvalResult) {
        self.loss_sum += other.loss_sum;
        self.correct += other.correct;
        self.count += other.count;
    }
}

/// Training session over `{base}_init` / `{base}_train` / `{base}_eval`.
pub struct TrainSession {
    pub base: String,
    train: Rc<Artifact>,
    eval: Rc<Artifact>,
    params: Vec<Value>,
    opt_m: Vec<Value>,
    opt_v: Vec<Value>,
    step: usize,
}

impl TrainSession {
    pub fn new(rt: &Runtime, base: &str) -> Result<Self> {
        let init = rt
            .load(&format!("{base}_init"))
            .with_context(|| format!("loading {base}_init"))?;
        let train = rt.load(&format!("{base}_train"))?;
        let eval = rt.load(&format!("{base}_eval"))?;
        let params = init.run(&[])?;
        let n = train.meta.n_params();
        if params.len() != n {
            bail!("{base}: init gave {} params, train wants {n}",
                  params.len());
        }
        let zeros: Vec<Value> = params
            .iter()
            .map(|p| match p {
                Value::F32(t) => Value::F32(Tensor::zeros(t.shape())),
                Value::I32(_) => unreachable!("params are f32"),
            })
            .collect();
        Ok(TrainSession {
            base: base.to_string(),
            train,
            eval,
            params,
            opt_m: zeros.clone(),
            opt_v: zeros,
            step: 0,
        })
    }

    pub fn meta(&self) -> &super::ArtifactMeta {
        &self.train.meta
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.train.meta.batch, self.train.meta.seq)
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// One fused optimisation step; returns the training loss.
    pub fn train_step(&mut self, batch: &Batch) -> Result<f32> {
        let (b, t) = self.batch_shape();
        if batch.tokens.shape() != [b, t] {
            bail!("batch shape {:?} != artifact ({b}, {t})",
                  batch.tokens.shape());
        }
        let mut args = Vec::with_capacity(self.params.len() * 3 + 4);
        args.extend(self.params.iter().cloned());
        args.extend(self.opt_m.iter().cloned());
        args.extend(self.opt_v.iter().cloned());
        args.push(Value::scalar_f32(self.step as f32));
        args.push(Value::I32(batch.tokens.clone()));
        args.push(Value::I32(batch.targets.clone()));
        args.push(Value::F32(batch.mask.clone()));
        let mut out = self.train.run(&args)?;
        let n = self.params.len();
        let loss = out[0].item()?;
        // outputs: loss, params..., m..., v...
        let rest = out.split_off(1);
        let mut it = rest.into_iter();
        self.params = (&mut it).take(n).collect();
        self.opt_m = (&mut it).take(n).collect();
        self.opt_v = (&mut it).take(n).collect();
        self.step += 1;
        if !loss.is_finite() {
            bail!("{}: non-finite loss {loss} at step {}", self.base,
                  self.step);
        }
        Ok(loss)
    }

    /// Masked loss/accuracy on one batch.
    pub fn eval_batch(&self, batch: &Batch) -> Result<EvalResult> {
        let mut args = Vec::with_capacity(self.params.len() + 3);
        args.extend(self.params.iter().cloned());
        args.push(Value::I32(batch.tokens.clone()));
        args.push(Value::I32(batch.targets.clone()));
        args.push(Value::F32(batch.mask.clone()));
        let out = self.eval.run(&args)?;
        Ok(EvalResult {
            loss_sum: out[0].item()? as f64,
            correct: out[1].item()? as f64,
            count: out[2].item()? as f64,
        })
    }

    pub fn params(&self) -> &[Value] {
        &self.params
    }

    pub fn set_params(&mut self, params: Vec<Value>) -> Result<()> {
        if params.len() != self.params.len() {
            bail!("param count mismatch: {} vs {}", params.len(),
                  self.params.len());
        }
        self.params = params;
        Ok(())
    }

    /// Run an auxiliary artifact (`{base}_{role}`) with the session's
    /// current parameters followed by `extra` inputs.
    pub fn run_role(&self, rt: &Runtime, role: &str, extra: &[Value])
                    -> Result<Vec<Value>> {
        let art = rt.load(&format!("{}_{role}", self.base))?;
        let mut args = Vec::with_capacity(self.params.len() + extra.len());
        args.extend(self.params.iter().cloned());
        args.extend(extra.iter().cloned());
        art.run(&args)
    }
}

/// Zero-shot scoring session over a `{base}_score` artifact.
pub struct ScoreSession {
    score: Rc<Artifact>,
    params: Vec<Value>,
}

impl ScoreSession {
    pub fn new(rt: &Runtime, base: &str, params: Vec<Value>) -> Result<Self> {
        Ok(ScoreSession { score: rt.load(&format!("{base}_score"))?, params })
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.score.meta.batch, self.score.meta.seq)
    }

    /// Per-sequence summed logprob of `targets` under the model.
    pub fn score(&self, tokens: &IntTensor, targets: &IntTensor,
                 mask: &Tensor) -> Result<Vec<f32>> {
        let mut args = Vec::with_capacity(self.params.len() + 3);
        args.extend(self.params.iter().cloned());
        args.push(Value::I32(tokens.clone()));
        args.push(Value::I32(targets.clone()));
        args.push(Value::F32(mask.clone()));
        let out = self.score.run(&args)?;
        Ok(out[0].as_f32()?.data().to_vec())
    }
}

/// O(1) recurrent decoding session over a `{base}_decode` artifact — the
/// XLA implementation of [`crate::runtime::backend::DecodeBackend`].
/// The belief state (conv window, precision, information mean) is owned by
/// the caller (see `crate::serve::state_cache`), making this session
/// stateless and shareable across requests.
pub struct DecodeSession {
    decode: Rc<Artifact>,
    params: Vec<Value>,
}

pub use super::backend::DecodeState;

impl DecodeSession {
    pub fn new(rt: &Runtime, base: &str, params: Vec<Value>) -> Result<Self> {
        let decode = rt.load(&format!("{base}_decode"))?;
        let n = decode.meta.n_params();
        if params.len() != n {
            bail!("decode {base}: {} params given, wants {n}", params.len());
        }
        Ok(DecodeSession { decode, params })
    }

    pub fn meta(&self) -> &super::ArtifactMeta {
        &self.decode.meta
    }

    pub fn batch(&self) -> usize {
        self.decode.meta.batch
    }

    /// Fresh state for the artifact's batch size: lam starts at the learned
    /// prior precision, which the decode artifact encodes in its inputs —
    /// we reconstruct it from the `lam0_raw` parameter (softplus + floor),
    /// matching `python/compile/models/decode.py::decode_init_state`.
    pub fn init_state(&self) -> Result<DecodeState> {
        let meta = &self.decode.meta;
        let (l, b) = (meta.model.n_layers, meta.batch);
        let (k, d, n) = (meta.model.conv_kernel, meta.model.d_model,
                         meta.model.n_state);
        let conv = Tensor::zeros(&[l, b, k - 1, d]);
        let mut lam = Tensor::zeros(&[l, b, n, d]);
        // collect per-layer lam0_raw params in layer order
        let mut layer = 0usize;
        for (val, am) in self.params.iter().zip(meta.param_inputs()) {
            if am.name.ends_with(".lam0_raw") {
                let raw = val.as_f32()?;
                for bi in 0..b {
                    for i in 0..n * d {
                        let x = raw.data()[i];
                        let lam0 = crate::kla::ou::softplus(x) + 1e-3;
                        lam.data_mut()[((layer * b) + bi) * n * d + i] = lam0;
                    }
                }
                layer += 1;
            }
        }
        if layer != l {
            bail!("found {layer} lam0_raw params, expected {l} layers");
        }
        let eta = Tensor::zeros(&[l, b, n, d]);
        Ok(DecodeState { conv, lam, eta })
    }

    /// One autoregressive step for the whole batch.
    /// tokens: (B,) -> (logits (B, V), new state).
    pub fn step(&self, tokens: &IntTensor, state: &DecodeState)
                -> Result<(Tensor, DecodeState)> {
        let mut args = Vec::with_capacity(self.params.len() + 4);
        args.extend(self.params.iter().cloned());
        args.push(Value::I32(tokens.clone()));
        args.push(Value::F32(state.conv.clone()));
        args.push(Value::F32(state.lam.clone()));
        args.push(Value::F32(state.eta.clone()));
        let mut out = self.decode.run(&args)?;
        if out.len() != 4 {
            bail!("decode returned {} outputs", out.len());
        }
        let eta = out.pop().unwrap();
        let lam = out.pop().unwrap();
        let conv = out.pop().unwrap();
        let logits = out.pop().unwrap();
        Ok((
            logits.as_f32()?.clone(),
            DecodeState {
                conv: conv.as_f32()?.clone(),
                lam: lam.as_f32()?.clone(),
                eta: eta.as_f32()?.clone(),
            },
        ))
    }
}

/// The XLA artifact path behind the shared backend seam — the serving
/// engine is generic over `DecodeBackend`, so this session and the
/// native model are interchangeable there.  (Inherent methods win method
/// resolution, so the delegations below are not self-recursive.)
impl super::backend::DecodeBackend for DecodeSession {
    fn batch(&self) -> usize {
        self.batch()
    }

    fn vocab(&self) -> usize {
        self.decode.meta.model.vocab
    }

    fn kind(&self) -> &'static str {
        "xla"
    }

    fn init_state(&self) -> Result<DecodeState> {
        self.init_state()
    }

    fn step(&self, tokens: &IntTensor, state: &DecodeState)
            -> Result<(Tensor, DecodeState)> {
        self.step(tokens, state)
    }
}

fn main() { println!("todo"); }

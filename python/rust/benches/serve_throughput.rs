fn main() {}

//! Compile-only stub of the `xla` PJRT bindings.
//!
//! The offline build environment has neither crates.io access nor a
//! libxla shared object, so this crate mirrors the API surface used by
//! `kla::runtime` and returns a descriptive error from every entry point
//! that would touch PJRT.  That is safe because the runtime gates all
//! artifact execution behind `Runtime::discover()`, which fails on the
//! missing `artifacts/manifest.json` long before any of these stubs run;
//! artifact-dependent tests and benches then skip gracefully.
//!
//! Swapping in the real bindings is a Cargo.toml change only — the types
//! and signatures here match the subset of the real crate that the repo
//! calls.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT backend not available in this build \
         (offline stub — see vendor/xla)"
    )))
}

/// Element types the runtime can pattern-match on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    U64,
    F16,
    F32,
    F64,
}

/// Array shape: dimensions plus element type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side literal (stub: carries no data).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
#[derive(Clone, Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P)
                                          -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_entry_points_error_descriptively() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline stub"), "{e}");
        let e = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("offline stub"), "{e}");
    }

    #[test]
    fn literal_construction_is_infallible() {
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}

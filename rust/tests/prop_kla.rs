//! Property tests over the native KLA filter and the serving scheduler —
//! the coordinator invariants (routing, batching, state) plus the filter
//! algebra at scale.

use kla::api::{Filter, GlaFilter, GlaInputs, GlaParams, KlaFilter,
               ScanPlan};
use kla::kla::{random_inputs, random_params, Mobius};
use kla::serve::batcher::{Feed, SchedRequest, Scheduler};
use kla::testing::property;

#[test]
fn prop_scan_strategies_equal_sequential() {
    property("strategies==sequential", 40, |g| {
        let t = g.usize_in(1, 200);
        let n = g.usize_in(1, 6);
        let d = g.usize_in(1, 10);
        let threads = g.usize_in(1, 9);
        let p = random_params(g.rng, n, d);
        let inp = random_inputs(g.rng, t, n, d);
        let prior = KlaFilter::init(&p);
        let (seq, _) =
            KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        for plan in [ScanPlan::chunked(threads), ScanPlan::blelloch()] {
            let (par, _) = KlaFilter::prefix(&p, &inp, &prior, &plan);
            for (i, (a, b)) in seq.y.iter().zip(&par.y).enumerate() {
                if (a - b).abs() > 1e-3 * (1.0 + a.abs()) {
                    return Err(format!(
                        "t={t} n={n} d={d} plan={plan:?} y[{i}]: {a} vs {b}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_carried_belief_resumes_scan() {
    // prefix(head) + prefix(tail, carry) == prefix(full) for any split —
    // the carry-split law, at property-test scale.
    property("carry-split", 40, |g| {
        let t = g.usize_in(2, 120);
        let n = g.usize_in(1, 4);
        let d = g.usize_in(1, 6);
        let cut = g.usize_in(1, t - 1);
        let p = random_params(g.rng, n, d);
        let inp = random_inputs(g.rng, t, n, d);
        let prior = KlaFilter::init(&p);
        let plan = ScanPlan::sequential();
        let (full, _) = KlaFilter::prefix(&p, &inp, &prior, &plan);
        let head = KlaFilter::slice(&inp, 0, cut);
        let tail = KlaFilter::slice(&inp, cut, t);
        let (_, carry) = KlaFilter::prefix(&p, &head, &prior, &plan);
        let (rest, _) = KlaFilter::prefix(&p, &tail, &carry, &plan);
        let s = p.state();
        for (i, (a, b)) in
            full.lam[cut * s..].iter().zip(&rest.lam).enumerate()
        {
            if a != b {
                return Err(format!(
                    "t={t} cut={cut} lam[{i}]: {a} vs {b} (not exact)"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_precision_bounded_by_noise_floor() {
    // with pbar > 0, steady-state precision is bounded: the Moebius map has
    // an attracting fixed point, so lam stays within a computable range.
    property("lam bounded", 40, |g| {
        let n = 1;
        let d = 1;
        let abar = g.f32_in(0.5, 0.99);
        let pbar = g.f32_in(0.01, 0.5);
        let phi_max = 4.0f32;
        let mut p = random_params(g.rng, n, d);
        p.abar[0] = abar;
        p.pbar[0] = pbar;
        p.lam0[0] = g.f32_in(0.1, 2.0);
        let t = g.usize_in(10, 400);
        let mut inp = random_inputs(g.rng, t, n, d);
        for x in inp.lam_v.iter_mut() {
            *x = x.clamp(0.05, 1.0);
        }
        for x in inp.k.iter_mut() {
            *x = x.clamp(-2.0, 2.0);
        }
        let (out, _) = KlaFilter::prefix(&p, &inp, &KlaFilter::init(&p),
                                         &ScanPlan::sequential());
        // upper bound: lam <= 1/pbar' + phi_max where prior precision can
        // never exceed 1/pbar (predict step adds pbar variance)
        let bound = 1.0 / pbar + phi_max + 1.0;
        for (i, &l) in out.lam.iter().enumerate() {
            if l <= 0.0 || l > bound {
                return Err(format!(
                    "lam[{i}]={l} outside (0, {bound}] (abar={abar}, \
                     pbar={pbar})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mobius_prefix_equals_stepwise() {
    property("prefix==stepwise", 60, |g| {
        let t = g.usize_in(1, 128);
        let mut maps = Vec::with_capacity(t);
        for _ in 0..t {
            maps.push(Mobius::kla_step(
                g.f32_in(0.6, 0.99),
                g.f32_in(1e-3, 0.3),
                g.f32_in(1e-3, 3.0),
            ));
        }
        let lam0 = g.f32_in(0.2, 3.0);
        // stepwise
        let mut lam = lam0;
        for m in &maps {
            lam = m.apply(lam);
        }
        // composed
        let mut acc = Mobius::IDENTITY;
        for m in &maps {
            acc = m.compose(&acc);
        }
        let lam2 = acc.apply(lam0);
        if (lam - lam2).abs() > 2e-3 * (1.0 + lam.abs()) {
            return Err(format!("t={t}: stepwise {lam} vs composed {lam2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_linear_scan_strategies_agree() {
    property("linear scan strategies", 40, |g| {
        let t = g.usize_in(1, 300);
        let s = g.usize_in(1, 32);
        let threads = g.usize_in(1, 8);
        let p = GlaParams { s, h0: g.vec_normal(s) };
        let inp = GlaInputs {
            t,
            f: g.vec_f32(t * s, 0.2, 0.99),
            b: g.vec_normal(t * s),
        };
        let prior = GlaFilter::init(&p);
        let (seq, _) =
            GlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        for plan in [ScanPlan::chunked(threads), ScanPlan::blelloch()] {
            let (par, _) = GlaFilter::prefix(&p, &inp, &prior, &plan);
            for (i, (x, y)) in seq.iter().zip(&par).enumerate() {
                if (x - y).abs() > 1e-3 * (1.0 + x.abs()) {
                    return Err(format!("plan={plan:?} [{i}] {x} vs {y}"));
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------ scheduler invariants ---

#[test]
fn prop_scheduler_conserves_requests() {
    // every submitted request finishes exactly once with exactly max_new
    // tokens, regardless of slot count / prompt length / arrival pattern.
    property("scheduler conservation", 60, |g| {
        let n_slots = g.usize_in(1, 6);
        let n_reqs = g.usize_in(1, 20);
        let mut sched = Scheduler::new(n_slots, 0);
        let mut expected = std::collections::HashMap::new();
        let mut submitted = 0usize;
        let mut finished = std::collections::HashMap::new();
        let mut iter = 0usize;
        loop {
            // random arrivals
            while submitted < n_reqs && g.rng.bool(0.5) {
                let plen = g.usize_in(0, 8);
                // max_new 0 included: prefill-only requests must finish
                // exactly once with exactly zero tokens (no silent clamp)
                let max_new = g.usize_in(0, 6);
                let prompt = (0..plen).map(|_| g.rng.below(64) as i32)
                    .collect::<Vec<_>>();
                sched.submit(SchedRequest::greedy(
                    submitted as u64, prompt, max_new));
                expected.insert(submitted as u64, max_new);
                submitted += 1;
            }
            sched.admit();
            let feeds = sched.feeds();
            // invariant: active slots never exceed capacity
            if sched.active_count() > n_slots {
                return Err("slot overflow".into());
            }
            let sampled: Vec<i32> =
                feeds.iter().map(|_| g.rng.below(64) as i32).collect();
            for f in sched.advance(&sampled) {
                if finished.insert(f.id, f.tokens.len()).is_some() {
                    return Err(format!("request {} finished twice", f.id));
                }
                sched.release(f.slot);
            }
            iter += 1;
            if submitted == n_reqs && !sched.has_work() {
                break;
            }
            if iter > 10_000 {
                return Err("scheduler livelock".into());
            }
        }
        if finished.len() != n_reqs {
            return Err(format!("{} of {n_reqs} finished", finished.len()));
        }
        for (id, want) in &expected {
            if finished[id] != *want {
                return Err(format!(
                    "req {id}: {} tokens, wanted {want}", finished[id]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_feeds_prompt_in_order() {
    property("prompt order", 30, |g| {
        let plen = g.usize_in(1, 10);
        let prompt: Vec<i32> =
            (0..plen).map(|i| 100 + i as i32).collect();
        let mut sched = Scheduler::new(1, 0);
        sched.submit(SchedRequest::greedy(0, prompt.clone(), 2));
        sched.admit();
        let mut fed = Vec::new();
        for _ in 0..plen {
            match sched.feeds()[0] {
                Feed::Prefill(t) | Feed::Decode(t) => fed.push(t),
                Feed::Idle => return Err("idle during prompt".into()),
            }
            sched.advance(&[999]);
        }
        if fed != prompt {
            return Err(format!("fed {fed:?} != prompt {prompt:?}"));
        }
        Ok(())
    });
}

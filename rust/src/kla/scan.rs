//! Native KLA information filter: sequential, Blelloch-parallel, and
//! chunked multi-threaded scans over a (T, N, D) state grid.
//!
//! This is the L3-side mirror of the L1 kernels — used by the Fig. 4
//! compute-scaling study (recurrent vs scan on CPU cores), by the property
//! tests, and cross-validated against the Python oracle via pinned
//! test vectors (`integration_cross_validation.rs`).
//!
//! Data layout: time-major contiguous rows of S = N*D channels, i.e.
//! `k[t*N + n]`, `v[t*D + d]`, `lam[t*S + n*D + d]` — matching the (B=1)
//! slices of the Python implementation.

use crate::kla::mobius::Mobius;

pub const LAM_MIN: f32 = 1e-6;
pub const LAM_MAX: f32 = 1e8;

/// Per-(N,D)-grid filter parameters.
#[derive(Clone, Debug)]
pub struct FilterParams {
    pub n: usize,
    pub d: usize,
    pub abar: Vec<f32>, // (N*D)
    pub pbar: Vec<f32>, // (N*D)
    pub lam0: Vec<f32>, // (N*D)
    pub eta0: Vec<f32>, // (N*D)
}

impl FilterParams {
    pub fn uniform(n: usize, d: usize, abar: f32, pbar: f32) -> Self {
        FilterParams {
            n,
            d,
            abar: vec![abar; n * d],
            pbar: vec![pbar; n * d],
            lam0: vec![1.0; n * d],
            eta0: vec![0.0; n * d],
        }
    }

    pub fn state(&self) -> usize {
        self.n * self.d
    }
}

/// Filter inputs for one sequence: k (T,N), q (T,N), v (T,D), lam_v (T,D).
#[derive(Clone, Debug)]
pub struct FilterInputs {
    pub t: usize,
    pub k: Vec<f32>,
    pub q: Vec<f32>,
    pub v: Vec<f32>,
    pub lam_v: Vec<f32>,
}

/// Filter outputs: lam, eta (T, N, D) and readout y (T, D).
#[derive(Clone, Debug, PartialEq)]
pub struct FilterOutputs {
    pub lam: Vec<f32>,
    pub eta: Vec<f32>,
    pub y: Vec<f32>,
}

#[inline]
fn readout(p: &FilterParams, inp: &FilterInputs, lam: &[f32], eta: &[f32],
           y: &mut [f32]) {
    let (n, d, s) = (p.n, p.d, p.state());
    for t in 0..inp.t {
        let (lam_t, eta_t) = (&lam[t * s..(t + 1) * s], &eta[t * s..(t + 1) * s]);
        let y_t = &mut y[t * d..(t + 1) * d];
        for ni in 0..n {
            let qn = inp.q[t * n + ni];
            if qn == 0.0 {
                continue;
            }
            let row = ni * d;
            for di in 0..d {
                y_t[di] += qn * eta_t[row + di] / lam_t[row + di];
            }
        }
    }
}

/// The naive recurrent (time-stepped) Kalman update — the Fig. 4 baseline.
/// O(T) sequential steps, each O(N*D).
pub fn filter_sequential(p: &FilterParams, inp: &FilterInputs)
                         -> FilterOutputs {
    let (n, d, s, t_len) = (p.n, p.d, p.state(), inp.t);
    let mut lam = vec![0.0f32; t_len * s];
    let mut eta = vec![0.0f32; t_len * s];
    let mut lam_prev = p.lam0.clone();
    let mut eta_prev = p.eta0.clone();
    for t in 0..t_len {
        let k_t = &inp.k[t * n..(t + 1) * n];
        let v_t = &inp.v[t * d..(t + 1) * d];
        let lv_t = &inp.lam_v[t * d..(t + 1) * d];
        for ni in 0..n {
            let k2 = k_t[ni] * k_t[ni];
            let row = ni * d;
            for di in 0..d {
                let idx = row + di;
                let abar = p.abar[idx];
                let rho = 1.0 / (abar * abar + p.pbar[idx] * lam_prev[idx]);
                let lam_t = (rho * lam_prev[idx] + k2 * lv_t[di])
                    .clamp(LAM_MIN, LAM_MAX);
                let eta_t = rho * abar * eta_prev[idx]
                    + k_t[ni] * lv_t[di] * v_t[di];
                lam[t * s + idx] = lam_t;
                eta[t * s + idx] = eta_t;
                lam_prev[idx] = lam_t;
                eta_prev[idx] = eta_t;
            }
        }
    }
    let mut y = vec![0.0f32; t_len * d];
    readout(p, inp, &lam, &eta, &mut y);
    FilterOutputs { lam, eta, y }
}

/// Work-efficient parallel form: two associative prefix scans
/// (Moebius for lam, affine for eta), single-threaded.  Exposes the same
/// O(T) work / O(log T) depth structure as the L1 kernel; `filter_chunked`
/// adds the multi-core execution.
pub fn filter_scan(p: &FilterParams, inp: &FilterInputs) -> FilterOutputs {
    filter_chunked(p, inp, 1)
}

/// Chunked two-level scan over `threads` cores (the CUDA-kernel analogue
/// from DESIGN.md §4).  Three passes, all O(T·S):
///   1. (parallel) per-chunk Moebius composition  -> chunk precision maps;
///   2. (serial, cheap) chunk carries for lam and, later, eta;
///   3. (parallel, fused) per-chunk replay producing lam, a zero-carry
///      eta_partial AND the running gate-prefix G; a final light fixup adds
///      G[t] * eta_carry so eta needs no second heavy scan.
/// Exact (Moebius maps compose associatively); matches `filter_sequential`
/// to f32 roundoff.
pub fn filter_chunked(p: &FilterParams, inp: &FilterInputs, threads: usize)
                      -> FilterOutputs {
    let (n, d, s, t_len) = (p.n, p.d, p.state(), inp.t);
    if t_len == 0 {
        return FilterOutputs { lam: vec![], eta: vec![], y: vec![] };
    }
    let threads = threads.clamp(1, t_len);
    let chunk_len = t_len.div_ceil(threads);
    let n_chunks = t_len.div_ceil(chunk_len); // may be < threads

    if n_chunks == 1 {
        return filter_sequential(p, inp);
    }
    let dbg = std::env::var("KLA_SCAN_DEBUG").is_ok();
    let t0 = std::time::Instant::now();

    // ---- Pass 1 (parallel): per-chunk Moebius composition ----
    let mut summaries: Vec<Vec<Mobius>> = vec![Vec::new(); n_chunks];
    parallel_chunk_exec(&mut summaries[..], |c, out| {
        let start = c * chunk_len;
        let end = ((c + 1) * chunk_len).min(t_len);
        let mut mob = vec![Mobius::IDENTITY; s];
        for t in start..end {
            let k_t = &inp.k[t * n..(t + 1) * n];
            let lv_t = &inp.lam_v[t * d..(t + 1) * d];
            for ni in 0..n {
                let k2 = k_t[ni] * k_t[ni];
                let row = ni * d;
                for di in 0..d {
                    let idx = row + di;
                    let m = Mobius::kla_step(p.abar[idx], p.pbar[idx],
                                             k2 * lv_t[di]);
                    mob[idx] = m.compose(&mob[idx]);
                }
            }
        }
        *out = mob;
    });

    if dbg { eprintln!("pass1 compose: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    let t0 = std::time::Instant::now();
    // ---- Pass 2a (serial, cheap): lam carries ----
    let mut carry_lam = vec![p.lam0.clone()];
    for c in 0..n_chunks - 1 {
        let prev = carry_lam.last().unwrap();
        let mut next = vec![0.0f32; s];
        for idx in 0..s {
            next[idx] = summaries[c][idx].apply(prev[idx])
                .clamp(LAM_MIN, LAM_MAX);
        }
        carry_lam.push(next);
    }

    if dbg { eprintln!("pass2a carries: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    let t0 = std::time::Instant::now();
    // ---- Pass 3 (parallel, fused): replay lam + eta_partial + gates ----
    let mut lam = vec![0.0f32; t_len * s];
    let mut eta = vec![0.0f32; t_len * s];     // zero-carry partial for now
    let mut gates = vec![0.0f32; t_len * s];   // prefix gate products G[t]
    let mut chunk_fb: Vec<(Vec<f32>, Vec<f32>)> =
        vec![(Vec::new(), Vec::new()); n_chunks];
    {
        let mut parts: Vec<(usize, &mut [f32], &mut [f32], &mut [f32],
                            &mut (Vec<f32>, Vec<f32>))> = Vec::new();
        let (mut lr, mut er, mut gr) =
            (&mut lam[..], &mut eta[..], &mut gates[..]);
        let mut fb_rest = &mut chunk_fb[..];
        for c in 0..n_chunks {
            let start = c * chunk_len;
            let end = ((c + 1) * chunk_len).min(t_len);
            let take = (end - start) * s;
            let (lh, lt) = lr.split_at_mut(take);
            let (eh, et) = er.split_at_mut(take);
            let (gh, gt) = gr.split_at_mut(take);
            let (fbh, fbt) = fb_rest.split_at_mut(1);
            parts.push((c, lh, eh, gh, &mut fbh[0]));
            lr = lt;
            er = et;
            gr = gt;
            fb_rest = fbt;
        }
        std::thread::scope(|scope| {
            for (c, lam_out, eta_out, g_out, fb) in parts {
                let lam_carry = carry_lam[c].clone();
                scope.spawn(move || {
                    let start = c * chunk_len;
                    let end = ((c + 1) * chunk_len).min(t_len);
                    let mut cur_l = lam_carry;
                    let mut cur_e = vec![0.0f32; s]; // zero-carry partial
                    let mut cur_g = vec![1.0f32; s];
                    for (ti, t) in (start..end).enumerate() {
                        let k_t = &inp.k[t * n..(t + 1) * n];
                        let v_t = &inp.v[t * d..(t + 1) * d];
                        let lv_t = &inp.lam_v[t * d..(t + 1) * d];
                        let row_out = ti * s;
                        for ni in 0..n {
                            let kk = k_t[ni];
                            let k2 = kk * kk;
                            let row = ni * d;
                            for di in 0..d {
                                let idx = row + di;
                                let abar = p.abar[idx];
                                let rho = 1.0
                                    / (abar * abar
                                        + p.pbar[idx] * cur_l[idx]);
                                let l = (rho * cur_l[idx] + k2 * lv_t[di])
                                    .clamp(LAM_MIN, LAM_MAX);
                                let gate = rho * abar;
                                let e = gate * cur_e[idx]
                                    + kk * lv_t[di] * v_t[di];
                                // prefix gate products decay geometrically;
                                // flush to zero before they go DENORMAL
                                // (denormal multiplies are ~100x slower,
                                // and the fixup contribution is ~0 anyway)
                                let mut g = gate * cur_g[idx];
                                if g < 1e-30 {
                                    g = 0.0;
                                }
                                lam_out[row_out + idx] = l;
                                eta_out[row_out + idx] = e;
                                g_out[row_out + idx] = g;
                                cur_l[idx] = l;
                                cur_e[idx] = e;
                                cur_g[idx] = g;
                            }
                        }
                    }
                    *fb = (cur_g, cur_e);
                });
            }
        });
    }

    if dbg { eprintln!("pass3 replay: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    let t0 = std::time::Instant::now();
    // ---- Pass 2b (serial, cheap): eta carries from (F, B) ----
    let mut carry_eta = vec![p.eta0.clone()];
    for c in 0..n_chunks - 1 {
        let prev = carry_eta.last().unwrap();
        let (f_c, b_c) = &chunk_fb[c];
        let mut next = vec![0.0f32; s];
        for idx in 0..s {
            next[idx] = f_c[idx] * prev[idx] + b_c[idx];
        }
        carry_eta.push(next);
    }

    // ---- Pass 4 (parallel, light): eta fixup with gate prefixes ----
    {
        let mut parts: Vec<(usize, &mut [f32], &[f32])> = Vec::new();
        let mut er = &mut eta[..];
        let mut gr = &gates[..];
        for c in 0..n_chunks {
            let start = c * chunk_len;
            let end = ((c + 1) * chunk_len).min(t_len);
            let take = (end - start) * s;
            let (eh, et) = er.split_at_mut(take);
            let (gh, gt) = gr.split_at(take);
            parts.push((c, eh, gh));
            er = et;
            gr = gt;
        }
        std::thread::scope(|scope| {
            for (c, eta_out, g_in) in parts {
                let carry = carry_eta[c].clone();
                scope.spawn(move || {
                    if carry.iter().all(|&x| x == 0.0) {
                        return; // first chunk (or zero prior): no fixup
                    }
                    let rows = eta_out.len() / s;
                    for ti in 0..rows {
                        let off = ti * s;
                        for idx in 0..s {
                            eta_out[off + idx] +=
                                g_in[off + idx] * carry[idx];
                        }
                    }
                });
            }
        });
    }

    if dbg { eprintln!("pass2b+4 eta: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    let t0 = std::time::Instant::now();
    let mut y = vec![0.0f32; t_len * d];
    readout(p, inp, &lam, &eta, &mut y);
    if dbg { eprintln!("readout: {:.1} ms", t0.elapsed().as_secs_f64()*1e3); }
    FilterOutputs { lam, eta, y }
}

/// Run `f(c, &mut out[c])` for each element on its own scoped thread.
fn parallel_chunk_exec<T: Send, F>(out: &mut [T], f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    std::thread::scope(|scope| {
        for (c, slot) in out.iter_mut().enumerate() {
            let f = &f;
            scope.spawn(move || f(c, slot));
        }
    });
}

/// Convenience: random filter inputs for tests/benches.
pub fn random_inputs(rng: &mut crate::util::Pcg64, t: usize, n: usize,
                     d: usize) -> FilterInputs {
    FilterInputs {
        t,
        k: (0..t * n).map(|_| rng.normal_f32()).collect(),
        q: (0..t * n).map(|_| rng.normal_f32()).collect(),
        v: (0..t * d).map(|_| rng.normal_f32()).collect(),
        lam_v: (0..t * d).map(|_| rng.range_f32(0.1, 2.0)).collect(),
    }
}

pub fn random_params(rng: &mut crate::util::Pcg64, n: usize, d: usize)
                     -> FilterParams {
    FilterParams {
        n,
        d,
        abar: (0..n * d).map(|_| rng.range_f32(0.7, 0.999)).collect(),
        pbar: (0..n * d).map(|_| rng.range_f32(1e-3, 0.2)).collect(),
        lam0: (0..n * d).map(|_| rng.range_f32(0.5, 2.0)).collect(),
        eta0: (0..n * d).map(|_| rng.normal_f32() * 0.1).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
        if a.len() != b.len() {
            return Err(format!("len {} vs {}", a.len(), b.len()));
        }
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
                return Err(format!("idx {i}: {x} vs {y}"));
            }
        }
        Ok(())
    }

    #[test]
    fn chunked_matches_sequential_various_threads() {
        let mut rng = Pcg64::seeded(1);
        for &(t, n, d) in &[(1, 1, 1), (7, 2, 3), (64, 4, 8), (129, 3, 5)] {
            let p = random_params(&mut rng, n, d);
            let inp = random_inputs(&mut rng, t, n, d);
            let seq = filter_sequential(&p, &inp);
            for threads in [1, 2, 4, 7] {
                let par = filter_chunked(&p, &inp, threads);
                close(&par.lam, &seq.lam, 1e-4)
                    .unwrap_or_else(|e| panic!("lam t={t} th={threads}: {e}"));
                close(&par.eta, &seq.eta, 1e-4)
                    .unwrap_or_else(|e| panic!("eta t={t} th={threads}: {e}"));
                close(&par.y, &seq.y, 1e-3)
                    .unwrap_or_else(|e| panic!("y t={t} th={threads}: {e}"));
            }
        }
    }

    #[test]
    fn zero_noise_linear_case() {
        let mut rng = Pcg64::seeded(2);
        let mut p = random_params(&mut rng, 2, 4);
        p.pbar.iter_mut().for_each(|x| *x = 0.0);
        let inp = random_inputs(&mut rng, 48, 2, 4);
        let seq = filter_sequential(&p, &inp);
        let par = filter_chunked(&p, &inp, 4);
        close(&par.lam, &seq.lam, 1e-4).unwrap();
        close(&par.eta, &seq.eta, 1e-4).unwrap();
    }

    #[test]
    fn precision_monotone_without_forgetting() {
        // abar = 1, pbar = 0: precision accumulates monotonically
        let n = 1;
        let d = 1;
        let p = FilterParams {
            n, d,
            abar: vec![1.0],
            pbar: vec![0.0],
            lam0: vec![1.0],
            eta0: vec![0.0],
        };
        let mut rng = Pcg64::seeded(3);
        let inp = random_inputs(&mut rng, 32, n, d);
        let out = filter_sequential(&p, &inp);
        for t in 1..32 {
            assert!(out.lam[t] >= out.lam[t - 1] - 1e-6);
        }
    }

    #[test]
    fn empty_sequence() {
        let p = FilterParams::uniform(2, 2, 0.9, 0.01);
        let inp = FilterInputs { t: 0, k: vec![], q: vec![], v: vec![],
                                 lam_v: vec![] };
        let out = filter_chunked(&p, &inp, 4);
        assert!(out.lam.is_empty() && out.y.is_empty());
    }
}

//! `repro-lint` front-end: run the repo's static-analysis passes over
//! the tree and exit non-zero on any finding (stale waivers included).
//!
//! Usage:
//!
//! ```text
//! cargo run --bin repro_lint --            # lint the repo this binary
//!                                          # was built from
//! cargo run --bin repro_lint -- <root>     # lint a checkout at <root>
//! ```
//!
//! Output is the per-pass result lines CI grep-pins
//! (`repro-lint[<pass>]: N findings, M waivers used`), each surviving
//! finding as `path:line: [pass] message`, and a final
//! `repro-lint: clean (N files scanned)` / `repro-lint: DIRTY (..)`
//! verdict.  See `rust/src/lint/mod.rs` and DESIGN.md §S18 for the
//! pass and waiver semantics.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    let report = match kla::lint::run_repo(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!(
                "repro-lint: cannot scan {}: {e}",
                root.display()
            );
            return ExitCode::from(2);
        }
    };
    print!("{}", report.render());
    if report.is_clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

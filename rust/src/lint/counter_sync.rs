//! Pass `counter-sync`: the stats counters cannot drift.
//!
//! Every *counter* field of `serve::engine::EngineStats` (the `usize`
//! fields — the `Vec<f64>` timing series are aggregates with no live
//! mirror) must appear in all four places that promise it:
//!
//! 1. as a `LiveStats` field (the lock-free mirror the server reads);
//! 2. as a string key in `serve/server.rs` (the `{"cmd":"stats"}`
//!    reply);
//! 3. in the protocol doc atop `serve/server.rs`;
//! 4. in DESIGN.md.
//!
//! The reverse direction is checked for `LiveStats`: a mirror field
//! with no `EngineStats` counter behind it is dead weight and is
//! flagged too.  This is exactly the drift class PRs 5–7 kept fixing
//! by hand (a counter added to `EngineStats` but forgotten in the
//! reply or the docs).

use super::{Finding, LintInput, SourceFile};
use crate::lint::lexer::Token;

/// A struct field: name, 1-based line, first identifier of its type.
pub(crate) struct Field {
    pub name: String,
    pub line: usize,
    pub ty: String,
}

/// Parse the named struct's fields from a comment-free token stream.
/// Returns `None` when the struct is not defined in `code`.
pub(crate) fn struct_fields(code: &[Token], name: &str) -> Option<Vec<Field>> {
    let mut i = 0usize;
    loop {
        let t = code.get(i)?;
        if t.ident() == Some("struct")
            && code.get(i + 1).and_then(|t| t.ident()) == Some(name)
        {
            break;
        }
        i += 1;
    }
    // Find the opening brace (skip generics — none in this repo, but
    // walking to `{` costs nothing); a `;` first means a unit/tuple
    // struct with no named fields.
    let mut j = i + 2;
    loop {
        let t = code.get(j)?;
        if t.is_punct('{') {
            break;
        }
        if t.is_punct(';') {
            return Some(Vec::new());
        }
        j += 1;
    }
    let mut fields = Vec::new();
    let mut depth = 1usize;
    let mut k = j + 1;
    while depth > 0 {
        let t = code.get(k)?;
        if t.is_punct('{') || t.is_punct('(') || t.is_punct('<') {
            depth += 1;
        } else if t.is_punct('}') || t.is_punct(')') || t.is_punct('>') {
            depth -= 1;
        } else if depth == 1
            && t.ident().is_some()
            && t.ident() != Some("pub")
            && code.get(k + 1).is_some_and(|n| n.is_punct(':'))
            && !code.get(k + 2).is_some_and(|n| n.is_punct(':'))
        {
            let ty = code
                .get(k + 2)
                .and_then(|t| t.ident())
                .unwrap_or("")
                .to_string();
            fields.push(Field {
                name: t.ident().unwrap_or("").to_string(),
                line: t.line,
                ty,
            });
        }
        k += 1;
    }
    Some(fields)
}

pub fn run(input: &LintInput) -> Vec<Finding> {
    let mut out = Vec::new();
    // The file defining EngineStats (engine.rs on the real tree; the
    // fixtures use a stand-in path).  No definition => pass is vacuous.
    let Some((engine, engine_fields)) =
        input.files.iter().find_map(|f| {
            struct_fields(&f.code, "EngineStats").map(|fs| (f, fs))
        })
    else {
        return out;
    };
    let live_fields = input
        .files
        .iter()
        .find_map(|f| struct_fields(&f.code, "LiveStats"))
        .unwrap_or_default();
    let server = input
        .files
        .iter()
        .find(|f| f.path_ends_with("serve/server.rs"));

    let counters: Vec<&Field> =
        engine_fields.iter().filter(|f| f.ty == "usize").collect();

    if !counters.is_empty() && input.design_md.is_empty() {
        out.push(finding(
            engine,
            counters[0].line,
            "DESIGN.md is missing or empty, so no counter can be \
             documented"
                .to_string(),
        ));
    }

    for c in &counters {
        if !live_fields.iter().any(|l| l.name == c.name) {
            out.push(finding(
                engine,
                c.line,
                format!(
                    "EngineStats counter `{}` has no LiveStats mirror",
                    c.name
                ),
            ));
        }
        if let Some(server) = server {
            if !has_str(server, &c.name) {
                out.push(finding(
                    engine,
                    c.line,
                    format!(
                        "EngineStats counter `{}` is not a key in the \
                         {{\"cmd\":\"stats\"}} reply in {}",
                        c.name, server.path
                    ),
                ));
            }
            if !server.module_doc().contains(&c.name) {
                out.push(finding(
                    engine,
                    c.line,
                    format!(
                        "EngineStats counter `{}` is not documented in \
                         the protocol doc atop {}",
                        c.name, server.path
                    ),
                ));
            }
        }
        if !input.design_md.is_empty()
            && !input.design_md.contains(&c.name)
        {
            out.push(finding(
                engine,
                c.line,
                format!(
                    "EngineStats counter `{}` is not documented in \
                     DESIGN.md",
                    c.name
                ),
            ));
        }
    }

    for l in &live_fields {
        if !counters.iter().any(|c| c.name == l.name) {
            out.push(finding(
                engine,
                l.line,
                format!(
                    "LiveStats field `{}` mirrors no EngineStats \
                     counter",
                    l.name
                ),
            ));
        }
    }
    out
}

fn has_str(file: &SourceFile, name: &str) -> bool {
    file.code.iter().any(|t| {
        matches!(&t.tok, crate::lint::lexer::Tok::Str(s) if s == name)
    })
}

fn finding(file: &SourceFile, line: usize, message: String) -> Finding {
    Finding {
        pass: "counter-sync",
        file: file.path.clone(),
        line,
        message,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{run as run_all, LintInput, SourceFile};

    fn input_from_fixture(engine_src: &str) -> LintInput {
        let server_src = include_str!("fixtures/counter_server.rs");
        LintInput {
            files: vec![
                SourceFile::from_source(
                    "rust/src/serve/engine.rs",
                    engine_src,
                ),
                SourceFile::from_source(
                    "rust/src/serve/server.rs",
                    server_src,
                ),
            ],
            // documents every counter except `dropped_frames`
            design_md: "the `requests` and `steps` counters".to_string(),
        }
    }

    #[test]
    fn fixture_fires_on_every_drift_direction() {
        let engine_src = include_str!("fixtures/counter_engine_bad.rs");
        let fs = run(&input_from_fixture(engine_src));
        let msgs: Vec<&str> =
            fs.iter().map(|f| f.message.as_str()).collect();
        // `dropped_frames` is missing everywhere downstream
        assert!(
            msgs.iter().any(|m| m.contains("dropped_frames")
                && m.contains("LiveStats")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("dropped_frames")
                && m.contains("stats")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("dropped_frames")
                && m.contains("DESIGN.md")),
            "{msgs:?}"
        );
        // `ghost` is a LiveStats field with no EngineStats counter
        assert!(
            msgs.iter()
                .any(|m| m.contains("ghost") && m.contains("mirrors no")),
            "{msgs:?}"
        );
        // timing series are not counters: never reported
        assert!(!msgs.iter().any(|m| m.contains("step_ms")), "{msgs:?}");
    }

    #[test]
    fn fixture_waiver_suppresses_the_drift() {
        let engine_src = include_str!("fixtures/counter_engine_waived.rs");
        let report = run_all(&input_from_fixture(engine_src));
        let counter_findings: Vec<_> = report
            .findings
            .iter()
            .filter(|f| f.pass == "counter-sync")
            .collect();
        assert!(
            counter_findings.is_empty(),
            "waived fixture should be clean: {counter_findings:?}"
        );
        let s = report
            .summaries
            .iter()
            .find(|s| s.pass == "counter-sync")
            .unwrap_or_else(|| panic!("no counter-sync summary"));
        assert!(s.waivers_used >= 1);
    }

    #[test]
    fn coherent_structs_are_clean() {
        let engine_src = "\
pub struct EngineStats {\n\
    pub requests: usize,\n\
    pub steps: usize,\n\
    pub step_ms: Vec<f64>,\n\
}\n\
pub struct LiveStats {\n\
    pub requests: AtomicUsize,\n\
    pub steps: AtomicUsize,\n\
}\n";
        let fs = run(&input_from_fixture(engine_src));
        assert!(fs.is_empty(), "{fs:?}");
    }
}

fn main() {}

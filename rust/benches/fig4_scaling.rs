//! Fig. 4 / Fig. 9: compute scaling of parallel KLA vs the recurrent
//! (time-stepped) Kalman baseline.
//!
//! All native points go through the unified `kla::api` surface: one
//! `Filter` implementation per family (KLA information filter, GLA
//! baseline) with the execution strategy selected per point via
//! `ScanPlan` — which is exactly the paper's axis of variation.
//!
//! Implementations benchmarked (paper's four, mapped to this testbed):
//!   recurrent/native      — ScanPlan::sequential() (naive time-stepped)
//!   recurrent/xla-step    — XLA decode artifact driven once per token
//!                           (the production recurrent path)
//!   scan/native-1t        — ScanPlan::chunked(1) ("Torch scan" analogue:
//!                           associative math only, one thread)
//!   scan/native-blelloch  — ScanPlan::blelloch() (tree-depth reference)
//!   scan/native-chunked   — ScanPlan::chunked(threads) ("CUDA kernel"
//!                           analogue: math + parallel hardware)
//!   gla/native-*          — the GLA baseline through the same plans, at
//!                           identical state size and layout
//!   batch/native          — prefix_batch: B rows under one plan
//!   scan/xla              — AOT scan artifact forward (T in {128..2048})
//!   scan/xla-pallas       — AOT Pallas-kernel artifact (T=512)

use kla::api::{prefix_batch, Filter, GlaFilter, GlaInputs, GlaParams,
               KlaFilter, ScanPlan};
use kla::bench::{black_box, Suite};
use kla::kla::{random_inputs, random_params};
use kla::runtime::{Runtime, Value};
use kla::util::{Json, Pcg64};

fn main() {
    let mut suite = Suite::new("fig4_scaling");
    suite.max_iters = 12;
    suite.time_budget = std::time::Duration::from_secs(4);
    let threads = kla::util::pool::default_threads();
    let (n, d) = (8, 64);

    // ---- native paths across T, strategy selected via ScanPlan ----
    for &t in &[128usize, 512, 2048, 8192, 32768] {
        let mut rng = Pcg64::seeded(t as u64);
        let p = random_params(&mut rng, n, d);
        let inp = random_inputs(&mut rng, t, n, d);
        let prior = KlaFilter::init(&p);
        suite.bench(&format!("recurrent/native T={t}"), || {
            black_box(KlaFilter::prefix(&p, &inp, &prior,
                                        &ScanPlan::sequential()));
        });
        suite.bench(&format!("scan/native-1t T={t}"), || {
            black_box(KlaFilter::prefix(&p, &inp, &prior,
                                        &ScanPlan::chunked(1)));
        });
        if t <= 2048 {
            suite.bench(&format!("scan/native-blelloch T={t}"), || {
                black_box(KlaFilter::prefix(&p, &inp, &prior,
                                            &ScanPlan::blelloch()));
            });
        }
        suite.bench(&format!("scan/native-chunked({threads}t) T={t}"), || {
            black_box(KlaFilter::prefix(&p, &inp, &prior,
                                        &ScanPlan::chunked(threads)));
        });
    }

    // ---- GLA baseline through the same Filter trait, same state size ----
    let s = n * d;
    for &t in &[2048usize, 8192] {
        let mut rng = Pcg64::seeded(t as u64 ^ 0x61_6c67);
        let gp = GlaParams::zeros(s);
        let ginp = GlaInputs {
            t,
            f: (0..t * s).map(|_| rng.range_f32(0.3, 0.99)).collect(),
            b: (0..t * s).map(|_| rng.normal_f32()).collect(),
        };
        let gprior = GlaFilter::init(&gp);
        suite.bench(&format!("gla/native-seq T={t}"), || {
            black_box(GlaFilter::prefix(&gp, &ginp, &gprior,
                                        &ScanPlan::sequential()));
        });
        suite.bench(&format!("gla/native-chunked({threads}t) T={t}"), || {
            black_box(GlaFilter::prefix(&gp, &ginp, &gprior,
                                        &ScanPlan::chunked(threads)));
        });
    }

    // ---- batched entry point: B rows, one plan ----
    {
        let b = 8usize;
        let t = 2048usize;
        let mut rng = Pcg64::seeded(99);
        let p = random_params(&mut rng, n, d);
        let rows: Vec<_> =
            (0..b).map(|_| random_inputs(&mut rng, t, n, d)).collect();
        let beliefs: Vec<_> = (0..b).map(|_| KlaFilter::init(&p)).collect();
        let plan = ScanPlan::chunked(threads).with_batch(b);
        suite.bench(&format!("batch/native B={b} T={t}"), || {
            black_box(prefix_batch::<KlaFilter>(&p, &rows, &beliefs,
                                                &plan));
        });
    }

    // ---- XLA paths (artifacts) ----
    match Runtime::discover() {
        Err(e) => println!("(skipping XLA points: {e})"),
        Ok(rt) => {
            // scan artifacts: full KLA block forward at various T
            for &t in &[128usize, 512, 2048, 8192] {
                let name = format!("fig4_scan_t{t}_logits");
                let Ok(art) = rt.load(&name) else {
                    println!("({name} not built — `make artifacts-full` \
                              for T=8192)");
                    continue;
                };
                let init = rt.load("fig4_kla_decode_b1_init").unwrap();
                let params = init.run(&[]).unwrap();
                let toks = kla::tensor::IntTensor::zeros(&[1, t]);
                let mut args: Vec<Value> = params.clone();
                args.push(Value::I32(toks));
                suite.bench(&format!("scan/xla T={t}"), || {
                    black_box(art.run(&args).unwrap());
                });
            }
            // pallas-kernel artifact
            if let Ok(art) = rt.load("fig4_pallas_t512_logits") {
                let init = rt.load("fig4_kla_decode_b1_init").unwrap();
                let params = init.run(&[]).unwrap();
                let toks = kla::tensor::IntTensor::zeros(&[1, 512]);
                let mut args: Vec<Value> = params;
                args.push(Value::I32(toks));
                suite.bench("scan/xla-pallas T=512", || {
                    black_box(art.run(&args).unwrap());
                });
            }
            // recurrent XLA: decode step driven T times
            let init = rt.load("fig4_kla_decode_b1_init").unwrap();
            let params = init.run(&[]).unwrap();
            let dec = kla::runtime::DecodeSession::new(
                &rt, "fig4_kla_decode_b1", params).unwrap();
            for &t in &[128usize, 512] {
                let state0 = dec.init_state().unwrap();
                suite.bench(&format!("recurrent/xla-step T={t}"), || {
                    let mut state = state0.clone();
                    let tok =
                        kla::tensor::IntTensor::new(&[1], vec![1]).unwrap();
                    for _ in 0..t {
                        let (lg, next) = dec.step(&tok, &state).unwrap();
                        black_box(lg);
                        state = next;
                    }
                });
            }
        }
    }

    suite.finish();
    // headline ratio (paper: ~350x CUDA vs recurrent at T=2048)
    let rec = suite.results().iter()
        .find(|r| r.name == "recurrent/native T=2048");
    let par = suite.results().iter()
        .find(|r| r.name.starts_with("scan/native-chunked")
            && r.name.ends_with("T=2048"));
    let headline = if let (Some(r), Some(p)) = (rec, par) {
        let ratio = r.mean_ms / p.mean_ms;
        println!("\nheadline: chunked scan is {ratio:.1}x faster than \
                  the recurrent update at T=2048 (paper: ~350x on A100 \
                  CUDA vs torch recurrent)");
        Json::num(ratio)
    } else {
        Json::Null
    };

    // machine-readable rows for BENCH_fig4.json (the CI scaling-curve
    // artifact; `t` is parsed from the point name so downstream plots
    // need no name grammar)
    let rows: Vec<Json> = suite.results().iter().map(|r| {
        let t = r.name.rsplit("T=").next()
            .and_then(|s| s.parse::<f64>().ok())
            .map_or(Json::Null, Json::num);
        Json::obj(vec![
            ("name", Json::str(&r.name)),
            ("t", t),
            ("iters", Json::num(r.iters as f64)),
            ("mean_ms", Json::num(r.mean_ms)),
            ("min_ms", Json::num(r.min_ms)),
            ("p50_ms", Json::num(r.p50_ms)),
        ])
    }).collect();
    let report = Json::obj(vec![
        ("bench", Json::str("fig4_scaling")),
        ("headline_speedup_t2048", headline),
        ("rows", Json::Arr(rows)),
    ]);
    if std::fs::write("BENCH_fig4.json", report.to_pretty()).is_ok() {
        println!("[bench] wrote BENCH_fig4.json");
    }
}

"""Time-parallel KLA filtering via `jax.lax.associative_scan`.

This is the differentiable "Torch associative scan" analogue of the paper
(Section 5.2, implementation (ii)): the mathematical reparameterisation with
no kernel fusion.  Training artifacts are built from this path because
`associative_scan` is composed of primitive ops and therefore supports
reverse-mode autodiff out of the box.

Two scans (paper Cor. 1.1 / Cor. 2.1):

1.  Precision scan.  Each token contributes a Moebius map represented by a
    2x2 matrix  M_t = [[1 + pbar*phi_t, abar^2*phi_t], [pbar, abar^2]]
    acting on lam via  M(lam) = (a*lam + b) / (c*lam + d).  Moebius maps
    compose by matrix multiplication, which is associative; the scan
    computes all prefix products M_{1:t} and applies them to lam0.
    Matrices are defined only up to scale, so each combine renormalises by
    the max-abs entry — this is what keeps T=8192 prefix products inside
    f32 range (the paper's kernel does the same implicitly by working with
    the ratio form).

2.  Mean scan.  Given the precision path, eta evolves affinely:
    eta_t = f_t * eta_{t-1} + b_t with f_t = abar * rho_t; affine maps
    (f, b) compose associatively as (f2*f1, f2*b1 + b2).

Shapes as in ref.py: k, q: (B, T, N); v, lam_v: (B, T, D);
abar, pbar, lam0, eta0: (N, D).  Returns lam, eta: (B, T, N, D), y: (B, T, D).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import LAM_MIN, LAM_MAX


def _mobius_combine(right, left):
    """Compose two batches of Moebius maps: result = right ∘ left.

    Each element is a 4-tuple (a, b, c, d) of identically-shaped arrays;
    composition is the 2x2 matrix product  M_r @ M_l, renormalised.
    NOTE on argument order: `lax.associative_scan` passes (earlier, later)
    as (first, second); we want prefix products applying the EARLIER map
    first, i.e. combined = later ∘ earlier, so the wrapper below flips.
    """
    ra, rb, rc, rd = right
    la, lb, lc, ld = left
    a = ra * la + rb * lc
    b = ra * lb + rb * ld
    c = rc * la + rd * lc
    d = rc * lb + rd * ld
    # Scale-invariance of Moebius maps: renormalise for f32 stability.
    s = jnp.maximum(jnp.maximum(jnp.abs(a), jnp.abs(b)),
                    jnp.maximum(jnp.abs(c), jnp.abs(d)))
    s = jnp.maximum(s, 1e-30)
    return a / s, b / s, c / s, d / s


def mobius_prefix_scan(phi, abar, pbar, lam0):
    """All posterior precisions via one associative scan.

    phi: (B, T, N, D) token precision contributions  k_t^2 * lam_v_t.
    abar, pbar, lam0: (N, D).
    Returns lam: (B, T, N, D).
    """
    abar2 = abar * abar                              # (N, D)
    ones = jnp.ones_like(phi)
    a = ones + pbar * phi                            # (B, T, N, D)
    b = abar2 * phi
    c = jnp.broadcast_to(pbar, phi.shape) * ones
    d = jnp.broadcast_to(abar2, phi.shape) * ones

    def combine(first, second):
        return _mobius_combine(second, first)        # later ∘ earlier

    pa, pb, pc, pd = jax.lax.associative_scan(combine, (a, b, c, d), axis=1)
    lam = (pa * lam0 + pb) / (pc * lam0 + pd)
    return jnp.clip(lam, LAM_MIN, LAM_MAX)


def affine_prefix_scan(f, b, init):
    """All information means via one associative scan.

    f, b: (B, T, N, D) per-step gate and additive evidence; init: (N, D).
    eta_t = (prod_{s<=t} f_s) * init + sum-with-gates(b)  — computed via the
    standard first-order-recurrence associative operator.
    """
    def combine(first, second):
        f1, b1 = first
        f2, b2 = second
        return f2 * f1, f2 * b1 + b2

    pf, pb = jax.lax.associative_scan(combine, (f, b), axis=1)
    return pf * init + pb


def kla_filter_scan(k, q, v, lam_v, abar, pbar, lam0, eta0):
    """Full two-pass scan-parallel KLA filter (batched).

    Pass 1 computes the precision path (Moebius scan); pass 2 reuses it to
    form the history-dependent forget gates and runs the affine scan for
    the information mean.  Cost: O(T) work, O(log T) depth, exactly the
    profile of Mamba/GLA-style mixers (paper C1/C2).
    """
    phi = (k[..., :, None] ** 2) * lam_v[..., None, :]        # (B, T, N, D)
    lam = mobius_prefix_scan(phi, abar, pbar, lam0)

    lam_prev = jnp.concatenate(
        [jnp.broadcast_to(lam0, lam[:, :1].shape), lam[:, :-1]], axis=1)
    rho = 1.0 / (abar * abar + pbar * lam_prev)               # (B, T, N, D)
    f = rho * abar
    evid = k[..., :, None] * (lam_v * v)[..., None, :]        # (B, T, N, D)
    eta = affine_prefix_scan(f, evid, eta0)

    mu = eta / lam
    y = jnp.einsum("btn,btnd->btd", q, mu)
    return lam, eta, y

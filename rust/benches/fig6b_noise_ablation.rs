//! Fig. 6b / Table 6: process-noise ablation.
//!
//! Fixing p_t = 0 collapses the Moebius precision recursion to a fixed-gate
//! linear update.  Paper: Selective Copy -14.9, Compression -12.1 points,
//! recall/memorisation unchanged.  We run full KLA vs kla_nonoise over the
//! MAD suite and print the delta per task.

use kla::bench::exp::{bench_seeds, bench_steps, train_mean_acc};
use kla::bench::Suite;
use kla::data::{task_by_name, MAD_TASKS};
use kla::runtime::Runtime;

fn main() {
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP fig6b: {e}");
            return;
        }
    };
    let steps = bench_steps(150);
    let seeds = bench_seeds(1);
    let mut suite = Suite::new("fig6b_noise_ablation");
    println!("{:18} {:>10} {:>10} {:>8}", "task", "full", "p=0", "delta");
    let mut deltas = Vec::new();
    for task_name in MAD_TASKS {
        let task = task_by_name(task_name).unwrap();
        let (full, _) = train_mean_acc(&rt, "mad_kla", task.as_ref(),
                                       steps, seeds).unwrap();
        let (zero, _) = train_mean_acc(&rt, "mad_kla_nonoise",
                                       task.as_ref(), steps, seeds).unwrap();
        let delta = zero - full;
        deltas.push(delta);
        println!("{task_name:18} {full:>10.4} {zero:>10.4} {delta:>+8.4}");
        suite.metric_row(task_name,
                         vec![("full".into(), full), ("p0".into(), zero),
                              ("delta".into(), delta)]);
    }
    let avg = deltas.iter().sum::<f64>() / deltas.len() as f64;
    println!("{:18} {:>10} {:>10} {avg:>+8.4}", "AVERAGE", "", "");
    suite.metric_row("average_delta", vec![("delta".into(), avg)]);
    suite.finish();
}

//! # kla — Kalman Linear Attention, reproduced as a Rust+JAX+Pallas stack
//!
//! Three layers (DESIGN.md):
//! - **L1/L2** live in `python/compile/` and are AOT-lowered to HLO text
//!   under `artifacts/` at build time (`make artifacts`);
//! - **L3** is this crate: runtime (PJRT), data pipeline, trainer,
//!   evaluation, serving, native KLA kernels, and the benchmark harness.
//!
//! Python never runs on the request path; after artifacts are built the
//! `repro` binary is self-contained.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod data;
pub mod eval;
pub mod kla;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;

pub use tensor::{IntTensor, Tensor};

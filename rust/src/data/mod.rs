//! Data pipeline: synthetic task generators (MAD suite, MQAR, A5), the
//! pretraining corpus + tokenizer, and batching.
//!
//! Every generator is seeded (`util::Pcg64`) and emits `Batch`es shaped for
//! a specific artifact (B, T fixed at AOT time).  Targets use `mask` to
//! select supervised positions; unsupervised positions carry target 0 with
//! mask 0.

pub mod a5;
pub mod corpus;
pub mod mad;
pub mod mqar;
pub mod tokenizer;

use crate::tensor::{IntTensor, Tensor};
use crate::util::Pcg64;

/// One training/eval batch: tokens, next-token targets, supervision mask.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: IntTensor,
    pub targets: IntTensor,
    pub mask: Tensor,
}

impl Batch {
    pub fn shape(&self) -> (usize, usize) {
        let s = self.tokens.shape();
        (s[0], s[1])
    }

    /// Fraction of supervised positions (sanity metric).
    pub fn mask_density(&self) -> f32 {
        let total = self.mask.data().len().max(1);
        self.mask.data().iter().sum::<f32>() / total as f32
    }
}

/// A single sequence with supervision; `TaskGen::batch` packs these.
#[derive(Clone, Debug, Default)]
pub struct Sample {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
}

impl Sample {
    pub fn with_capacity(t: usize) -> Self {
        Sample {
            tokens: Vec::with_capacity(t),
            targets: Vec::with_capacity(t),
            mask: Vec::with_capacity(t),
        }
    }

    pub fn push(&mut self, token: i32, target: i32, supervised: bool) {
        self.tokens.push(token);
        self.targets.push(target);
        self.mask.push(if supervised { 1.0 } else { 0.0 });
    }

    /// Pad (or truncate) to exactly `t` positions with PAD=0, mask 0.
    pub fn fit(&mut self, t: usize) {
        self.tokens.truncate(t);
        self.targets.truncate(t);
        self.mask.truncate(t);
        while self.tokens.len() < t {
            self.tokens.push(0);
            self.targets.push(0);
            self.mask.push(0.0);
        }
    }
}

/// Task generator interface: every synthetic benchmark implements this.
pub trait TaskGen {
    /// Human-readable task name (used in reports).
    fn name(&self) -> &str;
    /// One fresh sample of length exactly `t`.
    fn sample(&self, rng: &mut Pcg64, t: usize) -> Sample;

    /// Pack B samples into a Batch.
    fn batch(&self, rng: &mut Pcg64, b: usize, t: usize) -> Batch {
        let mut tokens = Vec::with_capacity(b * t);
        let mut targets = Vec::with_capacity(b * t);
        let mut mask = Vec::with_capacity(b * t);
        for _ in 0..b {
            let mut s = self.sample(rng, t);
            s.fit(t);
            tokens.extend_from_slice(&s.tokens);
            targets.extend_from_slice(&s.targets);
            mask.extend_from_slice(&s.mask);
        }
        Batch {
            tokens: IntTensor::new(&[b, t], tokens).unwrap(),
            targets: IntTensor::new(&[b, t], targets).unwrap(),
            mask: Tensor::new(&[b, t], mask).unwrap(),
        }
    }
}

/// Look up a task generator by name (the CLI/bench entry point).
pub fn task_by_name(name: &str) -> Option<Box<dyn TaskGen + Send + Sync>> {
    match name {
        "compression" => Some(Box::new(mad::Compression::default())),
        "memorization" => Some(Box::new(mad::Memorization::default())),
        "context_recall" => Some(Box::new(mad::ContextRecall::standard())),
        "noisy_recall" => Some(Box::new(mad::ContextRecall::noisy())),
        "fuzzy_recall" => Some(Box::new(mad::FuzzyRecall::default())),
        "selective_copy" => Some(Box::new(mad::SelectiveCopy::default())),
        "mqar" => Some(Box::new(mqar::Mqar::default())),
        "a5" => Some(Box::new(a5::A5Task::new())),
        _ => None,
    }
}

pub const MAD_TASKS: [&str; 6] = [
    "compression",
    "memorization",
    "context_recall",
    "noisy_recall",
    "fuzzy_recall",
    "selective_copy",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_resolvable() {
        for name in MAD_TASKS.iter().chain(["mqar", "a5"].iter()) {
            let t = task_by_name(name).unwrap_or_else(|| panic!("{name}"));
            let mut rng = Pcg64::seeded(0);
            let b = t.batch(&mut rng, 4, 64);
            assert_eq!(b.shape(), (4, 64));
            assert!(b.mask_density() > 0.0, "{name} has empty mask");
        }
        assert!(task_by_name("nope").is_none());
    }

    #[test]
    fn sample_fit_pads_and_truncates() {
        let mut s = Sample::default();
        s.push(5, 6, true);
        s.fit(3);
        assert_eq!(s.tokens, vec![5, 0, 0]);
        assert_eq!(s.mask, vec![1.0, 0.0, 0.0]);
        s.fit(1);
        assert_eq!(s.tokens, vec![5]);
    }
}

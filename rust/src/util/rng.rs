//! PCG64-style splittable RNG (the offline stand-in for the `rand` crate).
//!
//! Deterministic across platforms; used by every data generator so that
//! experiment seeds reproduce exactly. The core is the PCG-XSL-RR 128/64
//! generator (O'Neill 2014) with a fixed odd increment per stream.

/// Permuted congruential generator, 128-bit state / 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with an arbitrary (seed, stream) pair.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience single-seed constructor (stream 0xda3e39cb94b95bdb).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child stream (for per-task / per-seed splits).
    pub fn split(&mut self, label: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ label.rotate_left(17), label | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (cached second sample omitted for
    /// simplicity; generators here are not on any hot path).
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices out of 0..n (partial Fisher-Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample from unnormalised weights (Zipfian corpora etc.).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg64::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal_f32() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(5);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct_unique() {
        let mut rng = Pcg64::seeded(6);
        let picked = rng.choose_distinct(20, 10);
        let mut s = picked.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(picked.iter().all(|&i| i < 20));
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::seeded(11);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg64::seeded(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}

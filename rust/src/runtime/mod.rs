//! L3 runtime: load AOT artifacts (HLO text) and execute them on the PJRT
//! CPU client (xla crate: `PjRtClient::cpu()` -> `HloModuleProto::
//! from_text_file` -> `compile` -> `execute`).
//!
//! One process-wide client; compiled executables are cached per artifact
//! name.  All host values cross the boundary as `Value` (f32/i32 tensors),
//! converted to/from `xla::Literal`.

pub mod backend;
pub mod meta;
pub mod session;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::{IntTensor, Tensor};
use crate::util::json;
pub use backend::{DecodeBackend, DecodeState, NativeBackend};
pub use meta::{ArgMeta, ArtifactMeta, DType, ModelMeta};
pub use session::{DecodeSession, EvalResult, ScoreSession, TrainSession};

/// A host-side value crossing the XLA boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(Tensor::scalar(x))
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&IntTensor> {
        match self {
            Value::I32(t) => Ok(t),
            _ => bail!("expected i32 value"),
        }
    }

    pub fn item(&self) -> Result<f32> {
        self.as_f32()?.item()
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32(t) => {
                let dims: Vec<i64> =
                    t.shape().iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
            Value::I32(t) => {
                let dims: Vec<i64> =
                    t.shape().iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&x| x as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>()?;
                Ok(Value::F32(Tensor::new(&dims, data)?))
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>()?;
                Ok(Value::I32(IntTensor::new(&dims, data)?))
            }
            other => bail!("unsupported element type {other:?}"),
        }
    }
}

/// A compiled artifact: metadata + PJRT executable.
pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with host values; returns the flattened output tuple.
    pub fn run(&self, args: &[Value]) -> Result<Vec<Value>> {
        if args.len() != self.meta.inputs.len() {
            bail!("{}: expected {} inputs, got {}", self.meta.name,
                  self.meta.inputs.len(), args.len());
        }
        for (a, m) in args.iter().zip(&self.meta.inputs) {
            if a.shape() != m.shape.as_slice() {
                bail!("{}: input {:?} shape {:?} != expected {:?}",
                      self.meta.name, m.name, a.shape(), m.shape);
            }
        }
        let literals = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        let parts = out.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!("{}: got {} outputs, meta says {}", self.meta.name,
                  parts.len(), self.meta.outputs.len());
        }
        parts.iter().map(Value::from_literal).collect()
    }
}

/// Per-thread PJRT CPU client.  The `xla` crate's handles are Rc-based
/// (not Send/Sync), so every thread that touches PJRT gets its own client
/// and compiles its own executables; cross-thread traffic carries plain
/// `Value`s instead (see `crate::serve`).
fn client() -> Result<xla::PjRtClient> {
    thread_local! {
        static CLIENT: RefCell<Option<xla::PjRtClient>> =
            const { RefCell::new(None) };
    }
    CLIENT.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?);
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

/// Artifact registry over an `artifacts/` directory.
pub struct Runtime {
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
}

impl Runtime {
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.join("manifest.json").exists() {
            bail!(
                "no artifact manifest at {}/manifest.json — run `make \
                 artifacts` first",
                dir.display()
            );
        }
        Ok(Runtime { dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Locate the artifacts dir relative to the repo root (cwd or
    /// KLA_ARTIFACTS env override).
    pub fn discover() -> Result<Self> {
        if let Ok(dir) = std::env::var("KLA_ARTIFACTS") {
            return Runtime::new(dir);
        }
        for candidate in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(candidate).join("manifest.json").exists() {
                return Runtime::new(candidate);
            }
        }
        bail!("artifacts/ not found — run `make artifacts` (or set \
               KLA_ARTIFACTS)")
    }

    pub fn names(&self) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(self.dir.join("manifest.json"))?;
        let j = json::parse(&text)?;
        j.req("artifacts")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_str()?.to_string()))
            .collect()
    }

    pub fn meta(&self, name: &str) -> Result<ArtifactMeta> {
        let path = self.dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        ArtifactMeta::from_json(&json::parse(&text)?)
    }

    /// Load (compile) an artifact; cached per name.
    pub fn load(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let meta = self.meta(name)?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        if !hlo_path.exists() {
            bail!(
                "artifact {name} missing at {} — run `make artifacts` \
                 (or `make artifacts-full` for sweep configs)",
                hlo_path.display()
            );
        }
        let t = crate::util::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| anyhow!("parse {}: {e}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client()?
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        crate::log_debug!("compiled {name} in {:.1} ms", t.elapsed_ms());
        let artifact = Rc::new(Artifact { meta, exe });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

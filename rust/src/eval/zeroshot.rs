//! Zero-shot multiple-choice suite over the synthetic corpus — the
//! lm-eval-harness substitution (DESIGN.md §3, S13).
//!
//! Eight task families mirroring the paper's eight benchmarks in *protocol*
//! (choice scoring by summed / length-normalised logprob of the
//! continuation given a context), built from the corpus generator:
//!
//!   cap_ctx / riv_ctx / exp_ctx — in-context fact retrieval (held-out
//!       facts presented in the prompt, then queried; LAMBADA-ish);
//!   cap_mem / exp_mem — parametric recall of TRAIN facts with no context
//!       (OpenBookQA-ish closed-book);
//!   recency — copy/recency: which entity was mentioned last;
//!   agreement — grammatical template vs corrupted word order (HellaSwag-
//!       style acc_norm);
//!   distractor — retrieval with interleaved distractor facts.

use anyhow::Result;

use crate::data::corpus::{Corpus, Fact};
use crate::data::tokenizer::Tokenizer;
use crate::runtime::ScoreSession;
use crate::tensor::{IntTensor, Tensor};
use crate::util::Pcg64;

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct ZeroShotItem {
    pub task: &'static str,
    pub context: String,
    pub choices: Vec<String>,
    pub answer: usize,
    /// length-normalise the choice logprob (acc_norm)
    pub norm: bool,
}

/// Per-task accuracy report.
#[derive(Clone, Debug, Default)]
pub struct ZeroShotReport {
    pub per_task: Vec<(String, f64, usize)>, // (task, accuracy, n items)
}

impl ZeroShotReport {
    pub fn average(&self) -> f64 {
        if self.per_task.is_empty() {
            return f64::NAN;
        }
        self.per_task.iter().map(|&(_, a, _)| a).sum::<f64>()
            / self.per_task.len() as f64
    }
}

pub struct ZeroShotSuite {
    pub items: Vec<ZeroShotItem>,
}

impl ZeroShotSuite {
    /// Build the suite from a corpus (same seed as pretraining!).
    pub fn build(corpus: &Corpus, seed: u64, per_task: usize) -> Self {
        let mut rng = Pcg64::seeded(seed ^ 0x5EED);
        let mut items = Vec::new();
        let held = &corpus.heldout_facts;
        let train = &corpus.train_facts;

        let pick = |rng: &mut Pcg64, facts: &[Fact], rel| -> Vec<Fact> {
            let mut pool: Vec<Fact> = facts
                .iter()
                .filter(|f| f.relation == rel)
                .cloned()
                .collect();
            rng.shuffle(&mut pool);
            pool
        };

        use crate::data::corpus::Relation::*;
        // in-context retrieval families (held-out facts => answer must come
        // from the prompt, not the weights)
        for (task, rel) in [("cap_ctx", CapitalOf), ("riv_ctx", RiverOf),
                            ("exp_ctx", ExportOf)] {
            let pool = pick(&mut rng, held, rel);
            for i in 0..per_task.min(pool.len()) {
                let f = &pool[i];
                let mut wrong = Vec::new();
                let all = pick(&mut rng, train, rel);
                for w in all.iter().take(3) {
                    if w.answer() != f.answer() {
                        wrong.push(w.answer().to_string());
                    }
                }
                wrong.truncate(2);
                if wrong.len() < 2 {
                    continue;
                }
                let mut choices = vec![f.answer().to_string()];
                choices.extend(wrong);
                let answer = shuffle_answer(&mut rng, &mut choices, 0);
                items.push(ZeroShotItem {
                    task,
                    context: format!("{} {}", f.sentence(), f.prompt()),
                    choices,
                    answer,
                    norm: false,
                });
            }
        }

        // parametric memory families (train facts, closed book)
        for (task, rel) in [("cap_mem", CapitalOf), ("exp_mem", ExportOf)] {
            let pool = pick(&mut rng, train, rel);
            for i in 0..per_task.min(pool.len()) {
                let f = &pool[i];
                let mut choices = vec![f.answer().to_string()];
                for w in pool.iter().rev().take(2) {
                    if w.answer() != f.answer() {
                        choices.push(w.answer().to_string());
                    }
                }
                if choices.len() < 3 {
                    continue;
                }
                let answer = shuffle_answer(&mut rng, &mut choices, 0);
                items.push(ZeroShotItem {
                    task,
                    context: f.prompt(),
                    choices,
                    answer,
                    norm: false,
                });
            }
        }

        // recency: which place was mentioned most recently?
        for _ in 0..per_task {
            let pool = pick(&mut rng, train, CapitalOf);
            if pool.len() < 3 {
                break;
            }
            let ctx = format!(
                "{} {} the last place named above is",
                pool[0].sentence(),
                pool[1].sentence()
            );
            let mut choices = vec![pool[1].subject.to_string(),
                                   pool[0].subject.to_string(),
                                   pool[2].subject.to_string()];
            let answer = shuffle_answer(&mut rng, &mut choices, 0);
            items.push(ZeroShotItem {
                task: "recency",
                context: ctx,
                choices,
                answer,
                norm: false,
            });
        }

        // agreement: grammatical vs word-salad continuation (acc_norm)
        for _ in 0..per_task {
            let good = "the river carries fresh water .";
            let bad1 = "the carries river water fresh .";
            let bad2 = "water fresh the river carries .";
            let mut choices = vec![good.to_string(), bad1.to_string(),
                                   bad2.to_string()];
            let answer = shuffle_answer(&mut rng, &mut choices, 0);
            items.push(ZeroShotItem {
                task: "agreement",
                context: "according to the records ,".to_string(),
                choices,
                answer,
                norm: true,
            });
        }

        // distractor-heavy retrieval
        for _ in 0..per_task {
            let pool = pick(&mut rng, held, CapitalOf);
            let dis = pick(&mut rng, train, ExportOf);
            if pool.is_empty() || dis.len() < 2 {
                break;
            }
            let f = &pool[0];
            let ctx = format!(
                "{} {} {} {}",
                dis[0].sentence(),
                f.sentence(),
                dis[1].sentence(),
                f.prompt()
            );
            let mut choices = vec![f.answer().to_string(),
                                   dis[0].answer().to_string(),
                                   dis[1].answer().to_string()];
            let answer = shuffle_answer(&mut rng, &mut choices, 0);
            items.push(ZeroShotItem {
                task: "distractor",
                context: ctx,
                choices,
                answer,
                norm: false,
            });
        }

        ZeroShotSuite { items }
    }

    /// Score every item with a `ScoreSession`; returns per-task accuracy.
    pub fn evaluate(&self, session: &ScoreSession, tok: &Tokenizer)
                    -> Result<ZeroShotReport> {
        let (b, t) = session.batch_shape();
        // flatten all (item, choice) rows
        struct Row {
            item: usize,
            choice: usize,
            tokens: Vec<i32>,
            targets: Vec<i32>,
            mask: Vec<f32>,
            choice_len: usize,
        }
        let mut rows = Vec::new();
        for (ii, item) in self.items.iter().enumerate() {
            for (ci, choice) in item.choices.iter().enumerate() {
                let ctx_ids = tok.encode(&item.context);
                let full_ids =
                    tok.encode(&format!("{} {}", item.context, choice));
                // choice token span = suffix of full beyond context length
                // (re-tokenisation may shift the boundary by a token; use
                // longest common prefix to be safe)
                let mut boundary = 0;
                while boundary < ctx_ids.len()
                    && boundary < full_ids.len()
                    && ctx_ids[boundary] == full_ids[boundary]
                {
                    boundary += 1;
                }
                let mut tokens: Vec<i32> =
                    full_ids.iter().map(|&x| x as i32).collect();
                tokens.truncate(t);
                let mut targets = vec![0i32; tokens.len()];
                let mut mask = vec![0f32; tokens.len()];
                for p in 0..tokens.len().saturating_sub(1) {
                    targets[p] = tokens[p + 1];
                    // supervise positions predicting choice tokens
                    if p + 1 >= boundary {
                        mask[p] = 1.0;
                    }
                }
                let choice_len = tokens.len().saturating_sub(boundary).max(1);
                rows.push(Row {
                    item: ii,
                    choice: ci,
                    tokens,
                    targets,
                    mask,
                    choice_len,
                });
            }
        }

        // score rows in artifact-shaped batches
        let mut scores: Vec<Vec<f64>> = self
            .items
            .iter()
            .map(|it| vec![f64::NEG_INFINITY; it.choices.len()])
            .collect();
        for chunk in rows.chunks(b) {
            let mut tokens = vec![0i32; b * t];
            let mut targets = vec![0i32; b * t];
            let mut mask = vec![0f32; b * t];
            for (ri, row) in chunk.iter().enumerate() {
                tokens[ri * t..ri * t + row.tokens.len()]
                    .copy_from_slice(&row.tokens);
                targets[ri * t..ri * t + row.targets.len()]
                    .copy_from_slice(&row.targets);
                mask[ri * t..ri * t + row.mask.len()]
                    .copy_from_slice(&row.mask);
            }
            let lp = session.score(
                &IntTensor::new(&[b, t], tokens)?,
                &IntTensor::new(&[b, t], targets)?,
                &Tensor::new(&[b, t], mask)?,
            )?;
            for (ri, row) in chunk.iter().enumerate() {
                let norm = if self.items[row.item].norm {
                    row.choice_len as f64
                } else {
                    1.0
                };
                scores[row.item][row.choice] = lp[ri] as f64 / norm;
            }
        }

        // accuracy per task
        let mut agg: std::collections::BTreeMap<&'static str, (usize, usize)> =
            Default::default();
        for (ii, item) in self.items.iter().enumerate() {
            let pred = scores[ii]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            let e = agg.entry(item.task).or_insert((0, 0));
            e.1 += 1;
            if pred == item.answer {
                e.0 += 1;
            }
        }
        Ok(ZeroShotReport {
            per_task: agg
                .into_iter()
                .map(|(k, (c, n))| (k.to_string(), c as f64 / n as f64, n))
                .collect(),
        })
    }
}

fn shuffle_answer(rng: &mut Pcg64, choices: &mut Vec<String>,
                  answer: usize) -> usize {
    let correct = choices[answer].clone();
    rng.shuffle(choices);
    choices.iter().position(|c| c == &correct).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_builds_eight_families() {
        let corpus = Corpus::new(0);
        let suite = ZeroShotSuite::build(&corpus, 0, 4);
        let tasks: std::collections::BTreeSet<_> =
            suite.items.iter().map(|i| i.task).collect();
        assert!(tasks.len() >= 7, "only {tasks:?}");
        for item in &suite.items {
            assert!(item.answer < item.choices.len());
            assert!(item.choices.len() >= 3);
            // the correct choice appears exactly once
            let correct = &item.choices[item.answer];
            assert_eq!(
                item.choices.iter().filter(|c| c == &correct).count(),
                1, "{item:?}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let corpus = Corpus::new(0);
        let a = ZeroShotSuite::build(&corpus, 1, 4);
        let b = ZeroShotSuite::build(&corpus, 1, 4);
        assert_eq!(a.items.len(), b.items.len());
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.answer, y.answer);
        }
    }
}

//! Cross-validation between the three stacks:
//!   1. Python oracle (ref.py) vs native Rust filter — via PINNED vectors
//!      generated from `kla_filter_ref_python` (seed 1234, T=6, N=2, D=3).
//!   2. XLA decode artifact vs XLA logits artifact — the O(1) recurrent
//!      serving path must reproduce the scan-parallel forward token by
//!      token (requires `make artifacts`).

use kla::api::{Filter, KlaFilter, ScanPlan};
use kla::kla::{FilterInputs, FilterParams};

// ---- pinned vectors from python/compile/kernels/ref.py (seed 1234) ----
const T: usize = 6;
const N: usize = 2;
const D: usize = 3;
const K: &[f32] = &[-1.6038368, 0.0640999, 0.7408913, 0.1526192, 0.8637439,
    2.9130993, -1.4788233, 0.945473, -1.6661354, 0.3437446, -0.5124437,
    1.323759];
const Q: &[f32] = &[-0.8602802, 0.5194932, -1.2651438, -2.159139, 0.434734,
    1.7332894, 0.5201342, -1.0021658, 0.2683455, 0.7671747, 1.191272,
    -1.1574109];
const V: &[f32] = &[0.6962794, 0.3513837, -0.0324151, 0.0131816, -0.6792499,
    -0.620532, 1.3312142, 0.2588385, -0.4814839, -2.4917896, -0.8765638,
    -0.5055091, -1.2831292, -1.3303285, 0.8259926, -0.247215, -1.6997061,
    -1.3351529];
const LV: &[f32] = &[1.2942277, 0.7835357, 0.681661, 0.8274702, 0.319836,
    0.494688, 0.8975361, 1.1532011, 0.783584, 0.597151, 1.4315674, 1.176344,
    0.813663, 0.7944586, 1.1702391, 1.3120198, 0.581552, 1.1533089];
const ABAR: &[f32] = &[0.9213246, 0.933063, 0.803725, 0.8768824, 0.8919178,
    0.8420523];
const PBAR: &[f32] = &[0.0506919, 0.0578099, 0.0989771, 0.010538, 0.0281159,
    0.0331188];
const LAM_LAST: &[f32] = &[6.34579, 5.9023447, 5.9541326, 16.84819,
    10.597687, 11.463077];
const ETA_LAST: &[f32] = &[2.8117998, 2.6479254, 0.0803553, 1.283024,
    -1.9359281, -2.7757084];
const Y: &[f32] = &[0.3032758, 0.1303652, -0.0103928, 0.2535107, 0.2450583,
    0.1289559, 0.6313913, 0.1017377, -0.2731695, 0.0108777, 0.1735255,
    0.1939832, 0.231371, 0.0507699, -0.1130171, 0.4397097, 0.7458611,
    0.2963365];

fn pinned_case() -> (FilterParams, FilterInputs) {
    (
        FilterParams {
            n: N,
            d: D,
            abar: ABAR.to_vec(),
            pbar: PBAR.to_vec(),
            lam0: vec![1.0; N * D],
            eta0: vec![0.0; N * D],
        },
        FilterInputs {
            t: T,
            k: K.to_vec(),
            q: Q.to_vec(),
            v: V.to_vec(),
            lam_v: LV.to_vec(),
        },
    )
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what} length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}[{i}]: rust {x} vs python {y}"
        );
    }
}

#[test]
fn native_sequential_matches_python_oracle() {
    let (p, inp) = pinned_case();
    let (out, belief) = KlaFilter::prefix(&p, &inp, &KlaFilter::init(&p),
                                          &ScanPlan::sequential());
    assert_close(&out.lam[(T - 1) * N * D..], LAM_LAST, 1e-5, "lam[T-1]");
    assert_close(&out.eta[(T - 1) * N * D..], ETA_LAST, 1e-5, "eta[T-1]");
    assert_close(&out.y, Y, 1e-5, "y");
    // the carried belief IS the pinned posterior
    assert_close(&belief.lam, LAM_LAST, 1e-5, "belief.lam");
    assert_close(&belief.eta, ETA_LAST, 1e-5, "belief.eta");
}

#[test]
fn native_parallel_strategies_match_python_oracle() {
    let (p, inp) = pinned_case();
    let prior = KlaFilter::init(&p);
    let plans = [
        ScanPlan::blelloch(),
        ScanPlan::chunked(1),
        ScanPlan::chunked(2),
        ScanPlan::chunked(3),
        ScanPlan::chunked(6),
    ];
    for plan in plans {
        let (out, _) = KlaFilter::prefix(&p, &inp, &prior, &plan);
        assert_close(&out.y, Y, 1e-4, "y (parallel)");
        assert_close(&out.lam[(T - 1) * N * D..], LAM_LAST, 1e-4, "lam");
    }
}

#[test]
fn native_step_chain_matches_python_oracle() {
    // the decode-time face of the same primitive: step() over every token
    let (p, inp) = pinned_case();
    let mut belief = KlaFilter::init(&p);
    let mut y_all = Vec::new();
    for t in 0..T {
        y_all.extend(KlaFilter::step(&p, &inp, t, &mut belief));
    }
    assert_close(&y_all, Y, 1e-5, "y (stepped)");
    assert_close(&belief.lam, LAM_LAST, 1e-5, "lam (stepped)");
    assert_close(&belief.eta, ETA_LAST, 1e-5, "eta (stepped)");
}

// --------------------------------------------------------- XLA vs XLA ----

#[test]
fn decode_step_reproduces_parallel_forward() {
    let Ok(rt) = kla::runtime::Runtime::discover() else {
        eprintln!("SKIP: artifacts missing");
        return;
    };
    // fig4_kla_decode_b1 shares the mad model config; compare against
    // mad_kla_logits with the same (init) parameters.
    let init = rt.load("fig4_kla_decode_b1_init").unwrap();
    let params = init.run(&[]).unwrap();
    let decode = kla::runtime::DecodeSession::new(
        &rt, "fig4_kla_decode_b1", params.clone()).unwrap();

    // parallel forward at B=32 (mad artifact): put our sequence in row 0
    let mad = rt.load("mad_kla_logits").unwrap();
    let (b, t) = (mad.meta.batch, mad.meta.seq);
    let mut toks = kla::tensor::IntTensor::zeros(&[b, t]);
    let mut rng = kla::util::Pcg64::seeded(5);
    let seq: Vec<i32> = (0..t).map(|_| rng.below(60) as i32).collect();
    for (i, &x) in seq.iter().enumerate() {
        toks.set(&[0, i], x);
    }
    let mut args: Vec<kla::runtime::Value> = params.clone();
    args.push(kla::runtime::Value::I32(toks));
    let full = mad.run(&args).unwrap();
    let logits = full[0].as_f32().unwrap();

    // recurrent decode, token by token (first 16 steps suffice)
    let mut state = decode.init_state().unwrap();
    for (ti, &tok) in seq.iter().take(16).enumerate() {
        let t_in = kla::tensor::IntTensor::new(&[1], vec![tok]).unwrap();
        let (step_logits, next) = decode.step(&t_in, &state).unwrap();
        state = next;
        for vi in 0..mad.meta.model.vocab {
            let a = step_logits.get(&[0, vi]);
            let b_ = logits.get(&[0, ti, vi]);
            assert!(
                (a - b_).abs() < 2e-3 * (1.0 + b_.abs()),
                "t={ti} v={vi}: decode {a} vs parallel {b_}"
            );
        }
    }
}

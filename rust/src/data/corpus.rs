//! Synthetic pretraining corpus (the FineWeb-Edu substitution, DESIGN.md §3).
//!
//! A deterministic generator producing English-like text with:
//!   * a Zipfian content lexicon (frequent function words, long tail),
//!   * templated grammatical sentences (agreement, anaphora),
//!   * an embedded FACT TABLE ("the capital of X is Y", "a Z is a kind of
//!     W", ...) split into train facts and HELD-OUT facts.
//!
//! The zero-shot suite (`crate::eval::zeroshot`) builds its cloze /
//! multiple-choice items from the held-out facts, so "pretraining transfers
//! to downstream accuracy" is exercised end to end, at toy scale.

use crate::util::Pcg64;

const SUBJECTS: &[&str] = &[
    "the river", "the mountain", "a merchant", "the scholar", "a farmer",
    "the engine", "the garden", "a sailor", "the library", "the valley",
    "a painter", "the harbour", "the market", "a shepherd", "the castle",
];

const VERBS: &[&str] = &[
    "carries", "holds", "crosses", "feeds", "guards", "follows",
    "surrounds", "supplies", "shelters", "divides",
];

const OBJECTS: &[&str] = &[
    "the old town", "fresh water", "many travellers", "the northern road",
    "its quiet fields", "a long wall", "the grain stores", "bright lanterns",
    "the winter stock", "a narrow bridge",
];

const CONNECTORS: &[&str] =
    &["meanwhile", "later that year", "in the spring", "after the rains",
      "according to the records", "as the elders say"];

/// Entity names for the fact table (CVCV pattern keeps them tokenizable).
const PLACES: &[&str] = &[
    "mira", "tola", "vasu", "keno", "rila", "soma", "neva", "pilo",
    "gura", "zena", "lomi", "faru", "bena", "kiva", "dola", "runo",
];

const CAPITALS: &[&str] = &[
    "arbor", "colmo", "derin", "estia", "ferro", "galen", "helma", "istra",
    "jorvi", "kelda", "lumen", "morra", "norba", "ostia", "pravi", "quill",
];

/// One relation type in the fact table.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Relation {
    CapitalOf,
    RiverOf,
    ExportOf,
}

const EXPORTS: &[&str] = &[
    "copper", "salt", "timber", "wool", "amber", "olives", "iron", "silk",
    "grain", "honey", "marble", "tin", "dyes", "glass", "furs", "spice",
];

#[derive(Clone, Debug)]
pub struct Fact {
    pub relation: Relation,
    pub subject: &'static str,
    pub object: &'static str,
}

impl Fact {
    pub fn sentence(&self) -> String {
        match self.relation {
            Relation::CapitalOf => format!(
                "the capital of {} is {} .", self.subject, self.object),
            Relation::RiverOf => format!(
                "the great river of {} is called {} .", self.subject,
                self.object),
            Relation::ExportOf => format!(
                "the land of {} exports mostly {} .", self.subject,
                self.object),
        }
    }

    /// The sentence with the object removed (cloze prompt).
    pub fn prompt(&self) -> String {
        match self.relation {
            Relation::CapitalOf => {
                format!("the capital of {} is", self.subject)
            }
            Relation::RiverOf => {
                format!("the great river of {} is called", self.subject)
            }
            Relation::ExportOf => {
                format!("the land of {} exports mostly", self.subject)
            }
        }
    }

    pub fn answer(&self) -> &'static str {
        self.object
    }
}

/// Deterministic corpus generator.
pub struct Corpus {
    pub train_facts: Vec<Fact>,
    pub heldout_facts: Vec<Fact>,
    seed: u64,
}

impl Corpus {
    /// `seed` fixes the fact table split and all sampled text.
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64::seeded(seed ^ 0xFAC7);
        let mut facts = Vec::new();
        // deterministic pairing, shuffled by seed, of each relation
        let mut cap_idx: Vec<usize> = (0..PLACES.len()).collect();
        rng.shuffle(&mut cap_idx);
        for (i, &pi) in cap_idx.iter().enumerate() {
            facts.push(Fact {
                relation: Relation::CapitalOf,
                subject: PLACES[pi],
                object: CAPITALS[i],
            });
        }
        let mut riv_idx: Vec<usize> = (0..PLACES.len()).collect();
        rng.shuffle(&mut riv_idx);
        for (i, &pi) in riv_idx.iter().enumerate() {
            facts.push(Fact {
                relation: Relation::RiverOf,
                subject: PLACES[pi],
                object: CAPITALS[(i + 5) % CAPITALS.len()],
            });
        }
        let mut exp_idx: Vec<usize> = (0..PLACES.len()).collect();
        rng.shuffle(&mut exp_idx);
        for (i, &pi) in exp_idx.iter().enumerate() {
            facts.push(Fact {
                relation: Relation::ExportOf,
                subject: PLACES[pi],
                object: EXPORTS[i],
            });
        }
        rng.shuffle(&mut facts);
        // 75% train / 25% held out for the zero-shot suite.  NOTE: the
        // zero-shot eval measures *in-context generalisation of the fact
        // formats* plus memorised train facts; held-out facts are used as
        // distractor-controlled prompts with the answer present in-context.
        let split = facts.len() * 3 / 4;
        let heldout = facts.split_off(split);
        Corpus { train_facts: facts, heldout_facts: heldout, seed }
    }

    /// Generate ~`target_bytes` of training text.
    pub fn generate(&self, target_bytes: usize) -> String {
        let mut rng = Pcg64::seeded(self.seed ^ 0x7E47);
        let mut out = String::with_capacity(target_bytes + 128);
        // Zipf weights over subjects/verbs/objects
        let zipf = |n: usize| -> Vec<f64> {
            (1..=n).map(|k| 1.0 / k as f64).collect()
        };
        let ws = zipf(SUBJECTS.len());
        let wv = zipf(VERBS.len());
        let wo = zipf(OBJECTS.len());
        while out.len() < target_bytes {
            match rng.below(10) {
                // 30%: a fact sentence (training facts only)
                0..=2 => {
                    let f = &self.train_facts
                        [rng.usize_below(self.train_facts.len())];
                    out.push_str(&f.sentence());
                }
                // 10%: connector + fact (long-range context)
                3 => {
                    let c = CONNECTORS[rng.usize_below(CONNECTORS.len())];
                    let f = &self.train_facts
                        [rng.usize_below(self.train_facts.len())];
                    out.push_str(c);
                    out.push_str(" , ");
                    out.push_str(&f.sentence());
                }
                // 60%: templated grammatical sentence
                _ => {
                    let s = SUBJECTS[rng.weighted(&ws)];
                    let v = VERBS[rng.weighted(&wv)];
                    let o = OBJECTS[rng.weighted(&wo)];
                    out.push_str(&format!("{s} {v} {o} ."));
                }
            }
            out.push(' ');
        }
        out
    }
}

/// Tokenised corpus as a TaskGen: random (B, T) next-token windows with
/// full supervision — the pretraining data source for the Table 4 /
/// Fig. 1b runs and the `train_lm` end-to-end example.
pub struct CorpusLm {
    ids: Vec<i32>,
    vocab: usize,
}

impl CorpusLm {
    /// Generate a corpus, train the BPE tokenizer to `vocab`, tokenise.
    pub fn build(seed: u64, target_bytes: usize, vocab: usize)
                 -> anyhow::Result<(Self, super::tokenizer::Tokenizer, Corpus)> {
        let corpus = Corpus::new(seed);
        let text = corpus.generate(target_bytes);
        let tok = super::tokenizer::Tokenizer::train(&text, vocab)?;
        let ids: Vec<i32> =
            tok.encode(&text).iter().map(|&x| x as i32).collect();
        Ok((CorpusLm { ids, vocab }, tok, corpus))
    }

    pub fn tokens(&self) -> usize {
        self.ids.len()
    }
}

impl super::TaskGen for CorpusLm {
    fn name(&self) -> &str {
        "corpus_lm"
    }

    fn sample(&self, rng: &mut Pcg64, t: usize) -> super::Sample {
        let mut s = super::Sample::with_capacity(t);
        let start = rng.usize_below(self.ids.len().saturating_sub(t + 1).max(1));
        for i in 0..t {
            let tok = self.ids[(start + i) % self.ids.len()];
            let tgt = self.ids[(start + i + 1) % self.ids.len()];
            debug_assert!((tok as usize) < self.vocab);
            s.push(tok, tgt, true);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_lm_windows() {
        use crate::data::TaskGen;
        let (lm, tok, _) = CorpusLm::build(0, 20_000, 400).unwrap();
        assert!(lm.tokens() > 1000);
        assert!(tok.vocab_size() <= 400);
        let mut rng = Pcg64::seeded(0);
        let b = lm.batch(&mut rng, 4, 32);
        assert_eq!(b.shape(), (4, 32));
        assert_eq!(b.mask_density(), 1.0);
        // targets shift tokens by one within the stream
        let s = lm.sample(&mut rng, 16);
        for i in 0..15 {
            assert_eq!(s.targets[i], s.tokens[i + 1]);
        }
    }

    #[test]
    fn deterministic() {
        let a = Corpus::new(7).generate(4096);
        let b = Corpus::new(7).generate(4096);
        assert_eq!(a, b);
        let c = Corpus::new(8).generate(4096);
        assert_ne!(a, c);
    }

    #[test]
    fn fact_split_disjoint() {
        let c = Corpus::new(1);
        assert!(!c.train_facts.is_empty());
        assert!(!c.heldout_facts.is_empty());
        for h in &c.heldout_facts {
            assert!(!c
                .train_facts
                .iter()
                .any(|t| t.relation == h.relation
                    && t.subject == h.subject));
        }
    }

    #[test]
    fn heldout_sentences_absent_from_text() {
        let c = Corpus::new(2);
        let text = c.generate(200_000);
        for h in &c.heldout_facts {
            assert!(
                !text.contains(&h.sentence()),
                "held-out fact leaked: {}", h.sentence()
            );
        }
        // but train facts do appear
        let present = c
            .train_facts
            .iter()
            .filter(|f| text.contains(&f.sentence()))
            .count();
        assert!(present > c.train_facts.len() / 2);
    }

    #[test]
    fn prompts_are_prefixes() {
        let c = Corpus::new(3);
        for f in c.train_facts.iter().chain(&c.heldout_facts) {
            assert!(f.sentence().starts_with(&f.prompt()));
            assert!(f.sentence().contains(f.answer()));
        }
    }
}

//! Native baseline scan primitives (GLA-style gated linear scan), used by
//! the Fig. 4 bench to compare KLA's Moebius scan against the linear scan
//! it generalises — at identical state size and memory layout.

use crate::util::pool::parallel_ranges;

/// Sequential gated linear recurrence h_t = f_t ⊙ h_{t-1} + b_t over a
/// time-major (T, S) grid.  The GLA/Mamba-style first-order update.
pub fn linear_scan_sequential(t_len: usize, s: usize, f: &[f32], b: &[f32],
                              init: &[f32]) -> Vec<f32> {
    assert_eq!(f.len(), t_len * s);
    assert_eq!(b.len(), t_len * s);
    let mut out = vec![0.0f32; t_len * s];
    let mut cur = init.to_vec();
    for t in 0..t_len {
        for i in 0..s {
            cur[i] = f[t * s + i] * cur[i] + b[t * s + i];
            out[t * s + i] = cur[i];
        }
    }
    out
}

/// Chunked multi-threaded version: compose (F, B) per chunk, combine
/// carries, replay.  Exactly the affine half of the KLA chunked scan.
pub fn linear_scan_chunked(t_len: usize, s: usize, f: &[f32], b: &[f32],
                           init: &[f32], threads: usize) -> Vec<f32> {
    if t_len == 0 {
        return vec![];
    }
    let threads = threads.clamp(1, t_len);
    let chunk_len = t_len.div_ceil(threads);
    let n_chunks = t_len.div_ceil(chunk_len);

    // Pass 1: per-chunk (F, B) composition, in f64 so cross-chunk carries
    // stay accurate far below the strategy-conformance tolerance (the KLA
    // chunked scan does the same for its Moebius summaries).
    let mut summ: Vec<(Vec<f64>, Vec<f64>)> =
        vec![(vec![1.0; s], vec![0.0; s]); n_chunks];
    {
        let cells: Vec<_> = summ.iter_mut().collect();
        std::thread::scope(|scope| {
            for (c, slot) in cells.into_iter().enumerate() {
                scope.spawn(move || {
                    let start = c * chunk_len;
                    let end = ((c + 1) * chunk_len).min(t_len);
                    for t in start..end {
                        for i in 0..s {
                            let ft = f[t * s + i] as f64;
                            slot.0[i] *= ft;
                            slot.1[i] = ft * slot.1[i] + b[t * s + i] as f64;
                        }
                    }
                });
            }
        });
    }

    // Pass 2: carries (f64 chain).
    let init64: Vec<f64> = init.iter().map(|&x| x as f64).collect();
    let mut carries = vec![init64];
    for c in 0..n_chunks - 1 {
        let prev = carries.last().unwrap();
        let mut next = vec![0.0f64; s];
        for i in 0..s {
            next[i] = summ[c].0[i] * prev[i] + summ[c].1[i];
        }
        carries.push(next);
    }

    // Pass 3: replay.
    let mut out = vec![0.0f32; t_len * s];
    {
        let mut parts: Vec<&mut [f32]> = Vec::with_capacity(n_chunks);
        let mut rest = &mut out[..];
        for c in 0..n_chunks {
            let start = c * chunk_len;
            let end = ((c + 1) * chunk_len).min(t_len);
            let (head, tail) = rest.split_at_mut((end - start) * s);
            parts.push(head);
            rest = tail;
        }
        std::thread::scope(|scope| {
            for (c, part) in parts.into_iter().enumerate() {
                let carry: Vec<f32> =
                    carries[c].iter().map(|&x| x as f32).collect();
                scope.spawn(move || {
                    let start = c * chunk_len;
                    let end = ((c + 1) * chunk_len).min(t_len);
                    let mut cur = carry;
                    for (ti, t) in (start..end).enumerate() {
                        for i in 0..s {
                            cur[i] = f[t * s + i] * cur[i] + b[t * s + i];
                            part[ti * s + i] = cur[i];
                        }
                    }
                });
            }
        });
    }
    out
}

/// Blelloch tree scan over the affine (f, b) pairs: the work-efficient
/// O(log T)-depth reference strategy, per channel, with the tree composed
/// in f64 (matching the KLA side, `kla::scan::filter_blelloch_from`).
pub fn linear_scan_blelloch(t_len: usize, s: usize, f: &[f32], b: &[f32],
                            init: &[f32]) -> Vec<f32> {
    assert_eq!(f.len(), t_len * s);
    assert_eq!(b.len(), t_len * s);
    let mut out = vec![0.0f32; t_len * s];
    if t_len == 0 {
        return out;
    }
    let mut aff: Vec<(f64, f64)> = Vec::with_capacity(t_len);
    for i in 0..s {
        aff.clear();
        for t in 0..t_len {
            aff.push((f[t * s + i] as f64, b[t * s + i] as f64));
        }
        crate::util::prefix::blelloch_inclusive(&mut aff, |a, c| {
            (c.0 * a.0, c.0 * a.1 + c.1)
        });
        let h0 = init[i] as f64;
        for t in 0..t_len {
            out[t * s + i] = (aff[t].0 * h0 + aff[t].1) as f32;
        }
    }
    out
}

/// Blocked parallel-over-channels execution of the *sequential* recurrence
/// (how a GPU would parallelise the naive recurrent baseline: time stays
/// sequential, channels split across cores).
pub fn linear_scan_channel_parallel(t_len: usize, s: usize, f: &[f32],
                                    b: &[f32], init: &[f32],
                                    threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; t_len * s];
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_ranges(s, threads, |_, lo, hi| {
        let out_ptr = &out_ptr;
        for i in lo..hi {
            let mut cur = init[i];
            for t in 0..t_len {
                cur = f[t * s + i] * cur + b[t * s + i];
                // SAFETY: each (t, i) cell is written by exactly one thread
                // because channel ranges are disjoint.
                unsafe { *out_ptr.0.add(t * s + i) = cur };
            }
        }
    });
    out
}

struct SendPtr(*mut f32);
// SAFETY: the SendPtr raw pointer is only used inside
// linear_scan_channel_parallel, where every thread writes a disjoint set
// of (t, i) cells (channel ranges are split by parallel_ranges) and
// `out` outlives the parallel region.
unsafe impl Send for SendPtr {}
// SAFETY: shared access to a SendPtr is read-only on the pointer value
// itself; the pointed-to cells are partitioned per thread as above, so
// no two threads ever alias a write.
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn rand_case(t: usize, s: usize, seed: u64)
                 -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seeded(seed);
        let f: Vec<f32> = (0..t * s).map(|_| rng.range_f32(0.3, 0.99)).collect();
        let b: Vec<f32> = (0..t * s).map(|_| rng.normal_f32()).collect();
        let init: Vec<f32> = (0..s).map(|_| rng.normal_f32()).collect();
        (f, b, init)
    }

    #[test]
    fn chunked_matches_sequential() {
        for &(t, s) in &[(1, 1), (17, 3), (128, 16), (100, 7)] {
            let (f, b, init) = rand_case(t, s, t as u64);
            let seq = linear_scan_sequential(t, s, &f, &b, &init);
            for threads in [1, 2, 5, 8] {
                let par = linear_scan_chunked(t, s, &f, &b, &init, threads);
                for (i, (a, c)) in seq.iter().zip(&par).enumerate() {
                    assert!((a - c).abs() < 1e-4, "t={t} th={threads} i={i}");
                }
            }
        }
    }

    #[test]
    fn blelloch_matches_sequential() {
        for &(t, s) in &[(1, 1), (17, 3), (128, 16), (100, 7)] {
            let (f, b, init) = rand_case(t, s, 100 + t as u64);
            let seq = linear_scan_sequential(t, s, &f, &b, &init);
            let par = linear_scan_blelloch(t, s, &f, &b, &init);
            for (i, (a, c)) in seq.iter().zip(&par).enumerate() {
                assert!((a - c).abs() < 1e-4 * (1.0 + a.abs()),
                        "t={t} i={i}: {a} vs {c}");
            }
        }
    }

    #[test]
    fn channel_parallel_matches() {
        let (t, s) = (64, 32);
        let (f, b, init) = rand_case(t, s, 9);
        let seq = linear_scan_sequential(t, s, &f, &b, &init);
        let par = linear_scan_channel_parallel(t, s, &f, &b, &init, 4);
        for (a, c) in seq.iter().zip(&par) {
            assert!((a - c).abs() < 1e-5);
        }
    }
}

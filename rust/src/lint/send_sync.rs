//! Pass `send-sync-audit`: `unsafe impl Send`/`Sync` must argue its
//! soundness structurally, and raw pointers stay behind private types.
//!
//! The generic `unsafe` pass only demands that a `// SAFETY:` comment
//! EXISTS; for `Send`/`Sync` impls that is not enough — "this is
//! fine" passes that check while claiming, to every thread in the
//! program, that aliasing a raw pointer is sound.  This pass parses
//! the comment block directly above (and on) each `unsafe impl Send`
//! / `unsafe impl Sync` line and requires it to:
//!
//! - start from a `// SAFETY:` marker at all;
//! - name the **type** whose impl it justifies (so a copy-pasted
//!   comment cannot drift onto a different type);
//! - name a **guarded field** of that type — or, when the type is a
//!   tuple/unit struct or defined elsewhere, at least the word
//!   `pointer` — so the argument is about the data actually shared;
//! - use an **aliasing vocabulary** word ([`ALIAS_WORDS`]: disjoint,
//!   alias(ed/ing), read-only, exclusive, immutable, owned, unique)
//!   — the shape every sound Send/Sync argument reduces to.
//!
//! Separately, a `pub struct` exposing a raw-pointer field is a
//! finding regardless of impls: a public raw pointer lets any
//! downstream module smuggle the pointer across threads without the
//! SAFETY contract ever being restated (`pub(crate)` and private
//! structs are fine — the contract stays inside the audited tree).

use super::{Finding, LintInput, SourceFile};
use crate::lint::counter_sync::struct_fields;

const PASS: &str = "send-sync-audit";

/// Vocabulary one of which every sound aliasing argument uses.
pub const ALIAS_WORDS: [&str; 10] = [
    "disjoint",
    "alias",
    "aliased",
    "aliasing",
    "read-only",
    "readonly",
    "exclusive",
    "immutable",
    "owned",
    "unique",
];

pub fn run(input: &LintInput) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &input.files {
        check_impls(file, input, &mut out);
        check_pub_raw_ptr_structs(file, &mut out);
    }
    out
}

fn check_impls(file: &SourceFile, input: &LintInput, out: &mut Vec<Finding>) {
    let code = &file.code;
    for i in 0..code.len() {
        if code[i].ident() != Some("unsafe")
            || code.get(i + 1).and_then(|t| t.ident()) != Some("impl")
        {
            continue;
        }
        if file.is_test_line(code[i].line) {
            continue;
        }
        // Scan the impl header: `unsafe impl [<..>] Send|Sync for Ty`.
        let mut trait_name: Option<&str> = None;
        let mut ty: Option<&str> = None;
        let mut k = i + 2;
        while let Some(t) = code.get(k) {
            if t.is_punct('{') || t.is_punct(';') {
                break;
            }
            match t.ident() {
                Some(w @ ("Send" | "Sync")) if trait_name.is_none() => {
                    trait_name = Some(w);
                }
                Some("for") if trait_name.is_some() => {
                    ty = code.get(k + 1).and_then(|n| n.ident());
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        let (Some(trait_name), Some(ty)) = (trait_name, ty) else {
            continue;
        };
        audit_impl(file, input, code[i].line, trait_name, ty, out);
    }
}

fn audit_impl(
    file: &SourceFile,
    input: &LintInput,
    impl_line: usize,
    trait_name: &str,
    ty: &str,
    out: &mut Vec<Finding>,
) {
    let text = comment_block(file, impl_line);
    let mut push = |message: String| {
        out.push(Finding {
            pass: PASS,
            file: file.path.clone(),
            line: impl_line,
            message,
        });
    };
    if !text.contains("SAFETY:") {
        push(format!(
            "`unsafe impl {trait_name} for {ty}` without a \
             `// SAFETY:` comment block directly above; a thread-\
             safety claim needs its argument written down"
        ));
        return;
    }
    if !text.contains(ty) {
        push(format!(
            "the SAFETY comment for `unsafe impl {trait_name} for \
             {ty}` never names `{ty}` — a copy-pasted argument can \
             drift onto the wrong type; name the type it justifies"
        ));
    }
    // The guarded data: a named field of the type, or at least the
    // word `pointer` when the type has no named fields (tuple/unit
    // struct) or is defined outside the scanned set.
    let fields: Vec<String> = input
        .files
        .iter()
        .find_map(|f| struct_fields(&f.code, ty))
        .map(|fs| fs.into_iter().map(|f| f.name).collect())
        .unwrap_or_default();
    let names_field = fields.iter().any(|f| text.contains(f.as_str()));
    if fields.is_empty() {
        if !text.contains("pointer") {
            push(format!(
                "the SAFETY comment for `unsafe impl {trait_name} for \
                 {ty}` does not say what data is shared (expected at \
                 least the word `pointer` for a tuple/opaque type)"
            ));
        }
    } else if !names_field {
        push(format!(
            "the SAFETY comment for `unsafe impl {trait_name} for \
             {ty}` names none of its fields ({}); argue about the \
             data actually shared",
            fields.join(", ")
        ));
    }
    let lower = text.to_lowercase();
    if !ALIAS_WORDS.iter().any(|w| lower.contains(w)) {
        push(format!(
            "the SAFETY comment for `unsafe impl {trait_name} for \
             {ty}` makes no aliasing argument (none of: {}) — state \
             why concurrent access cannot alias a write",
            ALIAS_WORDS.join(", ")
        ));
    }
}

/// The contiguous plain-comment block ending directly above
/// `impl_line`, plus any comment on the line itself, joined.
fn comment_block(file: &SourceFile, impl_line: usize) -> String {
    // Gather candidate comment lines (doc comments excluded — a
    // `///` above an impl is API prose, not its SAFETY argument).
    let mut by_line: Vec<(usize, &str)> = Vec::new();
    for t in &file.toks {
        if let Some(c) = t.comment_text() {
            if !c.starts_with('/') && !c.starts_with('!') {
                by_line.push((t.line, c));
            }
        }
    }
    let mut lines: Vec<&str> = Vec::new();
    // Walk up from the line above the impl while comments are
    // contiguous.
    let mut want = impl_line.saturating_sub(1);
    while want > 0 {
        let found: Vec<&str> = by_line
            .iter()
            .filter(|(l, _)| *l == want)
            .map(|(_, c)| *c)
            .collect();
        if found.is_empty() {
            break;
        }
        for c in found.into_iter().rev() {
            lines.insert(0, c);
        }
        want -= 1;
    }
    for (l, c) in &by_line {
        if *l == impl_line {
            lines.push(c);
        }
    }
    lines.join("\n")
}

/// `pub struct` (not `pub(crate)`) whose body holds a `*mut` /
/// `*const` field.
fn check_pub_raw_ptr_structs(file: &SourceFile, out: &mut Vec<Finding>) {
    let code = &file.code;
    for i in 0..code.len() {
        if code[i].ident() != Some("struct") {
            continue;
        }
        // `pub struct`: the token before must be the ident `pub`
        // (for `pub(crate) struct` it is `)` — not flagged).
        if i == 0 || code[i - 1].ident() != Some("pub") {
            continue;
        }
        if file.is_test_line(code[i].line) {
            continue;
        }
        let Some(name) = code.get(i + 1).and_then(|t| t.ident()) else {
            continue;
        };
        // Scan the struct body: to the matching `}` of the first `{`,
        // or to the terminating `;` (tuple / unit struct).
        let mut depth = 0usize;
        let mut k = i + 2;
        let mut has_raw = false;
        while let Some(t) = code.get(k) {
            if t.is_punct('{') || t.is_punct('(') {
                depth += 1;
            } else if t.is_punct('}') || t.is_punct(')') {
                depth = depth.saturating_sub(1);
                if depth == 0 && t.is_punct('}') {
                    break;
                }
            } else if t.is_punct(';') && depth == 0 {
                break;
            } else if t.is_punct('*')
                && matches!(
                    code.get(k + 1).and_then(|n| n.ident()),
                    Some("mut" | "const")
                )
            {
                has_raw = true;
            }
            k += 1;
        }
        if has_raw {
            out.push(Finding {
                pass: PASS,
                file: file.path.clone(),
                line: code[i].line,
                message: format!(
                    "`pub struct {name}` exposes a raw-pointer field: \
                     any downstream module can move the pointer across \
                     threads without restating the SAFETY contract; \
                     make the struct or the field non-pub"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{run as run_all, LintInput, SourceFile};

    fn input(path: &str, src: &str) -> LintInput {
        LintInput {
            files: vec![SourceFile::from_source(path, src)],
            design_md: String::new(),
        }
    }

    #[test]
    fn fixture_fires_on_every_bad_shape() {
        let src = include_str!("fixtures/send_sync_bad.rs");
        let fs = run(&input("rust/src/baselines/mod.rs", src));
        let msgs: Vec<&str> =
            fs.iter().map(|f| f.message.as_str()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("exposes a raw-pointer")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter()
                .any(|m| m.contains("without a `// SAFETY:` comment")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("never names `Opaque`")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("names none of its fields")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("no aliasing argument")),
            "{msgs:?}"
        );
    }

    #[test]
    fn fixture_waivers_suppress_and_are_counted() {
        let src = include_str!("fixtures/send_sync_waived.rs");
        let report = run_all(&input("rust/src/baselines/mod.rs", src));
        assert!(
            report.findings.is_empty(),
            "waived fixture should be clean:\n{}",
            report.render()
        );
        let s = report
            .summaries
            .iter()
            .find(|s| s.pass == "send-sync-audit")
            .unwrap_or_else(|| panic!("no send-sync-audit summary"));
        assert!(s.waivers_used >= 4, "waivers used: {}", s.waivers_used);
    }

    #[test]
    fn structural_safety_comment_is_clean() {
        // mirrors the real `baselines::SendPtr` pattern
        let src = "\
struct SendPtr(*mut f32);\n\
// SAFETY: the SendPtr raw pointer is written through by threads\n\
// holding disjoint channel ranges, and the buffer outlives them.\n\
unsafe impl Send for SendPtr {}\n\
// SAFETY: shared access to a SendPtr is read-only on the pointer\n\
// itself; writes through it never alias across threads.\n\
unsafe impl Sync for SendPtr {}\n";
        let fs = run(&input("rust/src/baselines/mod.rs", src));
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn named_field_argument_is_required_and_sufficient() {
        let src = "\
struct Cell {\n\
    buf: *mut u8,\n\
    len: usize,\n\
}\n\
// SAFETY: Cell's `buf` region is owned by exactly one thread at a\n\
// time; `len` never changes after construction.\n\
unsafe impl Send for Cell {}\n";
        let fs = run(&input("rust/src/util/pool.rs", src));
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn pub_crate_and_private_raw_ptr_structs_are_fine() {
        let src = "\
pub(crate) struct A(*mut f32);\n\
struct B {\n\
    p: *const u8,\n\
}\n\
pub struct C {\n\
    n: usize,\n\
}\n";
        let fs = run(&input("rust/src/util/pool.rs", src));
        assert!(fs.is_empty(), "{fs:?}");
    }
}

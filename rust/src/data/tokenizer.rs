//! Greedy byte-pair-encoding tokenizer (the nanochat-BPE stand-in,
//! DESIGN.md §3/S12).  Trained on the synthetic corpus at build^W run time
//! (training is fast: one pass per merge over pair counts).
//!
//! Vocabulary layout: 0..255 = raw bytes, 256.. = merges, in merge order.
//! `Tokenizer::train(text, vocab)` learns `vocab - 256` merges;
//! encode/decode roundtrip exactly.

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// merges[i] = (left, right) token ids merged into id 256 + i.
    merges: Vec<(u32, u32)>,
    /// rank lookup for encoding.
    ranks: HashMap<(u32, u32), u32>,
}

impl Tokenizer {
    pub fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }

    /// Train BPE on `text` until `vocab` tokens exist.
    pub fn train(text: &str, vocab: usize) -> Result<Self> {
        if vocab < 256 {
            bail!("vocab must be >= 256, got {vocab}");
        }
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        let mut merges = Vec::with_capacity(vocab - 256);
        let mut ranks = HashMap::new();
        for next_id in 256..vocab as u32 {
            // count adjacent pairs
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // most frequent pair (ties broken by smallest pair for
            // determinism)
            let Some((&pair, &n)) = counts
                .iter()
                .max_by_key(|(&pair, &n)| (n, std::cmp::Reverse(pair)))
            else {
                break;
            };
            if n < 2 {
                break; // nothing worth merging
            }
            merges.push(pair);
            ranks.insert(pair, next_id);
            ids = merge_pair(&ids, pair, next_id);
        }
        Ok(Tokenizer { merges, ranks })
    }

    /// Encode text to token ids (greedy lowest-rank merging, GPT-2 style).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        loop {
            // find the lowest-rank applicable merge
            let mut best: Option<(u32, usize)> = None; // (merged_id, pos)
            for (i, w) in ids.windows(2).enumerate() {
                if let Some(&id) = self.ranks.get(&(w[0], w[1])) {
                    if best.is_none_or(|(b, _)| id < b) {
                        best = Some((id, i));
                    }
                }
            }
            match best {
                None => break,
                Some((id, _)) => {
                    let pair = self.merges[(id - 256) as usize];
                    ids = merge_pair(&ids, pair, id);
                }
            }
        }
        ids
    }

    /// Decode token ids back to text (lossless for valid UTF-8 inputs).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            self.push_bytes(id, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn push_bytes(&self, id: u32, out: &mut Vec<u8>) {
        if id < 256 {
            out.push(id as u8);
        } else {
            let (l, r) = self.merges[(id - 256) as usize];
            self.push_bytes(l, out);
            self.push_bytes(r, out);
        }
    }

    /// Serialise to a compact text format (one merge per line).
    pub fn save(&self) -> String {
        let mut s = String::from("kla-bpe-v1\n");
        for (l, r) in &self.merges {
            s.push_str(&format!("{l} {r}\n"));
        }
        s
    }

    pub fn load(src: &str) -> Result<Self> {
        let mut lines = src.lines();
        if lines.next() != Some("kla-bpe-v1") {
            bail!("bad tokenizer header");
        }
        let mut merges = Vec::new();
        let mut ranks = HashMap::new();
        for (i, line) in lines.enumerate() {
            let (l, r) = line
                .split_once(' ')
                .ok_or_else(|| anyhow::anyhow!("bad merge line {i}"))?;
            let pair = (l.parse()?, r.parse()?);
            ranks.insert(pair, 256 + i as u32);
            merges.push(pair);
        }
        Ok(Tokenizer { merges, ranks })
    }
}

fn merge_pair(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const TEXT: &str = "the cat sat on the mat. the cat ate the rat. \
                        the mat was flat. a cat and a rat and a mat.";

    #[test]
    fn roundtrip() {
        let tok = Tokenizer::train(TEXT, 300).unwrap();
        for probe in [TEXT, "the cat", "unseen words zxq!", ""] {
            let ids = tok.encode(probe);
            assert_eq!(tok.decode(&ids), probe);
        }
    }

    #[test]
    fn compresses_common_patterns() {
        let tok = Tokenizer::train(TEXT, 320).unwrap();
        let ids = tok.encode("the cat sat on the mat.");
        assert!(ids.len() < "the cat sat on the mat.".len(),
                "no compression: {} ids", ids.len());
    }

    #[test]
    fn ids_below_vocab() {
        let tok = Tokenizer::train(TEXT, 280).unwrap();
        for &id in &tok.encode(TEXT) {
            assert!((id as usize) < tok.vocab_size());
        }
    }

    #[test]
    fn save_load_identical() {
        let tok = Tokenizer::train(TEXT, 300).unwrap();
        let tok2 = Tokenizer::load(&tok.save()).unwrap();
        assert_eq!(tok.encode(TEXT), tok2.encode(TEXT));
    }

    #[test]
    fn deterministic_training() {
        let a = Tokenizer::train(TEXT, 300).unwrap();
        let b = Tokenizer::train(TEXT, 300).unwrap();
        assert_eq!(a.merges, b.merges);
    }

    #[test]
    fn rejects_tiny_vocab() {
        assert!(Tokenizer::train(TEXT, 100).is_err());
    }
}

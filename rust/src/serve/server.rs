//! TCP front-end: newline-delimited JSON over a plain socket (std::net —
//! no tokio offline).  One reader thread + one writer thread per
//! connection; all generation funnels into the single engine thread
//! (continuous batching).  The writer thread serialises every line the
//! connection emits, so any number of requests may be in flight per
//! connection and their event streams interleave safely (protocol v2
//! multiplexing).
//!
//! # Protocol v2 (one JSON object per line)
//!
//! ## Generation requests
//!
//! Every generation request carries a client-chosen `id` — an integer in
//! [0, 2^53] (the exact-integer f64 range), scoped to the connection.
//! An id may not collide with a request still in flight on the same
//! connection (`duplicate-id`); once the terminal event for it arrives
//! it may be reused.  Everything after `prompt` is optional and
//! overrides the server default from [`ServeConfig`]:
//!
//!   -> {"id": 3,
//!       "prompt": [1,2,3],
//!       "max_new_tokens": 8,       0 = prefill only (no token events;
//!                                  uncertainty still reported); values
//!                                  above the server's max_new_limit are
//!                                  REJECTED, never clamped
//!       "temperature": 0.8,        0 = greedy argmax (the default)
//!       "top_k": 40,               0 = off, 1 = greedy
//!       "top_p": 0.95,             >= 1 = off
//!       "seed": 7,                 explicit sampling seed, an integer
//!                                  in [0, 2^53] (see below)
//!       "stop_tokens": [0, 31],    sampling one of these ends the
//!                                  request (stop token included in the
//!                                  output; prompt occurrences ignored)
//!       "eos": 0,                  shorthand: one extra stop token
//!       "uncertainty_temp": 0.5,   c in tau_eff = tau*(1 + c*u), u =
//!                                  slot mean posterior variance
//!       "cache": false}            opt out of the belief-state prefix
//!                                  cache for this request (no snapshot
//!                                  lookup OR insertion); a no-op when
//!                                  the server runs without a cache
//!
//! The reply is a STREAM of typed event lines, all tagged with the
//! request's `id`.  Events of one request arrive in order; events of
//! different requests interleave arbitrarily:
//!
//!   <- {"id": 3, "event": "start", "queue_ms": 0.1}
//!   <- {"id": 3, "event": "token", "index": 0, "token": 17,
//!       "uncertainty": 0.42}        one per sampled token, the moment
//!                                   it is sampled; `uncertainty` is the
//!                                   slot's POST-STEP mean posterior
//!                                   variance — the paper's per-step
//!                                   belief trajectory
//!   <- {"id": 3, "event": "done", "tokens": [...], "queue_ms": 0.1,
//!       "total_ms": 12.3, "uncertainty": 0.42, "cancelled": false,
//!       "cached_tokens": 0}
//!
//! `done` is the terminal event and carries the complete legacy reply
//! shape: its `tokens` array is always exactly the concatenation of the
//! `token` events (pinned by tests + the `stream-parity` CI step), so
//! collecting only `done` reproduces the v1 one-shot behaviour.
//! `cached_tokens` is how many prompt tokens the request skipped by
//! restoring a belief-state prefix-cache snapshot at admit (0 when the
//! cache is off, missed, or the request opted out with
//! `"cache": false`).  Cache hits change timings only — the generated
//! tokens are identical to a cold prefill (pinned by the
//! `prefix-cache-parity` CI step; see DESIGN.md §S15).
//!
//! ## Cancellation
//!
//!   -> {"cmd": "cancel", "id": 3}   <- {"ok": true, "id": 3}
//!
//! Cancels an in-flight request on THIS connection: the engine retires
//! its slot at the next iteration's sweep (before `admit()`, so a queued
//! request takes the freed slot within the same engine iteration) and
//! the request's stream ends with `"event": "done", "cancelled": true`
//! carrying whatever was generated.  Cancelling an unknown or finished
//! id is a no-op answered `{"ok": false, "id": N}`.  Closing the
//! connection cancels every request still in flight on it implicitly —
//! dead clients stop burning batch lanes.
//!
//! ## Commands
//!
//!   -> {"cmd": "ping"}     <- {"ok": true}
//!   -> {"cmd": "stats"}    <- {"requests": N, "steps": N,
//!       "tokens_out": N, "prefill_tokens": N, "cancelled": N,
//!       "failed": N, "wasted_tokens": N, "prefix_hits": N,
//!       "prefix_partial_hits": N, "prefix_misses": N,
//!       "prefix_evictions": N, "prefix_cached_tokens": N,
//!       "prefix_bytes": N, "prefix_entries": N}
//!       (live counters; `cancelled` counts requests retired early,
//!       `failed` counts requests whose prefill round errored
//!       server-side, `wasted_tokens` counts tokens decoded for
//!       requests that never completed; the `prefix_*` counters mirror
//!       the belief-state prefix cache and stay 0 when it is disabled)
//!   -> {"cmd": "shutdown"} <- {"ok": true}    (stops the listener —
//!       the handler pokes the accept loop itself, no external
//!       connection needed for the server to quiesce)
//!
//! Command replies are single untagged lines; they may interleave with
//! event lines of in-flight requests (the typed [`Client`] buffers
//! events while waiting for a command reply).
//!
//! ## Errors
//!
//! Every malformed or rejected line gets a structured error EVENT and
//! the connection stays usable; the request `id` is echoed when it was
//! parseable:
//!   <- {"event": "err", "id": 3, "err": {"code": "<kebab-case-code>",
//!       "msg": "<human detail>"}}
//! Codes: `bad-json`, `unknown-cmd`, `bad-cmd`, `missing-id`, `bad-id`,
//! `duplicate-id`, `too-many-inflight`, `missing-prompt`, `bad-prompt`,
//! `bad-prompt-token` (a prompt entry is not an integer in i32 range —
//! previously truncated silently), `bad-max-new`, `max-new-too-large`
//! (over the server's max_new_limit — previously clamped silently),
//! `bad-temperature`, `bad-top-k`, `bad-top-p`, `bad-seed`,
//! `bad-stop-tokens`, `bad-eos`, `bad-uncertainty-temp`, `bad-cache`,
//! `prefill-failed` (this request's lane of a fused prefill round
//! errored — terminal for the request only; the engine releases the
//! slot and keeps serving every other lane), `unavailable` (the engine
//! is gone — also the terminal event of any ACCEPTED request the engine
//! dropped without answering, e.g. when its thread errors out
//! mid-serve, so a stream never just goes silent; and the terminal
//! reply when a connection's bookkeeping is poisoned and can accept no
//! further work).  This list is pinned against the code by the
//! protocol-sync pass of repro-lint: every code the server emits must
//! appear backticked above, and vice versa.
//!
//! Event kinds: `start`, `token`, `done`, `err` — the complete set of
//! `"event"` values a connection can emit, also pinned by
//! protocol-sync.
//!
//! ## Configuration notes
//!
//! A `--prefix-cache-block` that is not a multiple of
//! `--prefill-chunk` would make snapshot boundaries unreachable by the
//! fused prefill rounds (cursors only ever land on chunk multiples),
//! so the server rounds the block UP to the next chunk multiple at
//! boot and logs a warning instead of silently caching nothing.
//!
//! ## Determinism contract (unchanged from v1)
//!
//! Sampling draws are counter-based (`serve::sampling`) — token `t` of a
//! request depends only on its RNG key and `t`.  With an explicit
//! `seed`, the key is `(engine seed, seed)`, so the same request
//! reproduces token-for-token across server restarts, batch widths, and
//! slot assignments (for a fixed prefill-chunk setting; across different
//! chunk sizes logits agree only to the 1e-5 scan tolerance — see
//! `serve::sampling`); without one it falls back to
//! `(engine seed, request id)`, stable for a fixed arrival order.
//! Greedy requests (temperature 0) are deterministic with no seed at
//! all.  Streaming changes none of this: the `token` events and the
//! `done.tokens` array are the same samples, emitted incrementally.

use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

// The connection-side sync state — shutdown latch, per-request cancel
// flags, the active-request map and each connection's writer channel —
// comes from the model-checker shims (std re-exports in normal
// builds), so the ConnSink terminal-delivery protocol is
// model-checked under `--features mc-shim` (DESIGN.md §S19).  The
// engine request channel stays on std mpsc (see `serve_with`).
use crate::mc::sync::{channel, AtomicBool, Mutex, Sender};

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::engine::{run_engine_opts, EngineEvent, EngineOptions,
                    EngineRequest, EngineStats, EventSink, LiveStats,
                    SinkClosed};
use super::sampling::SamplerConfig;
use crate::config::ServeConfig;
use crate::runtime::backend::NativeBackend;
use crate::runtime::{Runtime, Value};
use crate::util::Json;

/// Largest integer JSON (f64) represents exactly — the bound for request
/// ids and sampling seeds alike.
const MAX_ID: f64 = (1u64 << 53) as f64;

/// Server-side request defaults + limits, shared by the router threads.
#[derive(Clone, Debug)]
struct ProtocolDefaults {
    max_new: usize,
    max_new_limit: usize,
    max_inflight: usize,
    sampler: SamplerConfig,
}

impl ProtocolDefaults {
    fn from_serve(cfg: &ServeConfig) -> Self {
        ProtocolDefaults {
            max_new: cfg.max_new_tokens,
            max_new_limit: cfg.max_new_limit,
            max_inflight: cfg.max_inflight,
            sampler: SamplerConfig::from_serve(cfg),
        }
    }
}

/// The documented structured error event:
/// `{"event": "err", "id": N?, "err": {"code": ..., "msg": ...}}`.
fn err_reply(id: Option<u64>, code: &str, msg: &str) -> Json {
    let mut pairs = vec![
        ("event", Json::str("err")),
        ("err",
         Json::obj(vec![("code", Json::str(code)),
                        ("msg", Json::str(msg))])),
    ];
    if let Some(id) = id {
        pairs.push(("id", Json::num(id as f64)));
    }
    Json::obj(pairs)
}

pub struct ServerHandle {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<Result<EngineStats>>>,
    listener_join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and collect engine stats.
    pub fn stop(mut self) -> Result<EngineStats> {
        // ord: SeqCst — process-wide shutdown latch; set once here,
        // polled by the engine and every router thread.
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(&self.addr);
        if let Some(j) = self.listener_join.take() {
            let _ = j.join();
        }
        match self.join.take() {
            Some(j) => match j.join() {
                Ok(stats) => stats,
                Err(_) => bail!("engine thread panicked"),
            },
            None => Ok(EngineStats::default()),
        }
    }
}

/// Which decode backend the engine thread should build.
///
/// PJRT handles are not Send, so the XLA variant carries plain data
/// (artifact dir + base + params) and the engine thread builds its own
/// Runtime and DecodeSession; the native variant is plain data already
/// and moves straight into the engine thread.
pub enum EngineSpec {
    /// XLA/PJRT over a `{base}_decode` artifact (needs `make artifacts`).
    Xla {
        artifacts_dir: PathBuf,
        artifact: String,
        params: Vec<Value>,
    },
    /// Pure-Rust KLA model — no artifacts required.
    Native(NativeBackend),
}

impl EngineSpec {
    fn kind(&self) -> &'static str {
        match self {
            EngineSpec::Xla { .. } => "xla",
            EngineSpec::Native(_) => "native",
        }
    }
}

/// Start the server on the XLA artifact backend; returns once the socket
/// is listening.  (Kept as the historical entry point — thin wrapper
/// over [`serve_with`].)
pub fn serve(artifacts_dir: PathBuf, artifact_base: String,
             params: Vec<Value>, cfg: &ServeConfig) -> Result<ServerHandle> {
    serve_with(EngineSpec::Xla {
        artifacts_dir,
        artifact: artifact_base,
        params,
    }, cfg)
}

/// Start the server on the pure-Rust native backend — the offline path:
/// no artifacts, no PJRT, same engine/batcher/cache stack.
pub fn serve_native(backend: NativeBackend, cfg: &ServeConfig)
                    -> Result<ServerHandle> {
    serve_with(EngineSpec::Native(backend), cfg)
}

/// Start the server over any [`EngineSpec`]; returns once the socket is
/// listening.
pub fn serve_with(spec: EngineSpec, cfg: &ServeConfig)
                  -> Result<ServerHandle> {
    // boot-time validation of the server-wide sampling defaults (per-
    // request fields are validated protocol-side with {"err": ...})
    SamplerConfig::from_serve(cfg)
        .validate()
        .context("serve config sampling defaults")?;
    // a default max_new above the limit would reject every request that
    // OMITS max_new_tokens with an error about a value the client never
    // sent — refuse to boot instead
    if cfg.max_new_tokens > cfg.max_new_limit {
        bail!(
            "serve config: max_new_tokens default {} exceeds \
             max_new_limit {}",
            cfg.max_new_tokens, cfg.max_new_limit);
    }
    if cfg.max_inflight == 0 {
        bail!("serve config: max_inflight must be >= 1 (a connection \
               that can hold no requests in flight serves nothing)");
    }
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?.to_string();
    // the engine request queue stays on std mpsc: intake is polled
    // with recv_timeout, which the model checker does not shim
    let (tx, rx) = std::sync::mpsc::channel::<EngineRequest>();
    let opts = EngineOptions::from_serve(cfg);
    let shutdown = Arc::new(AtomicBool::new(false));
    let live = Arc::new(LiveStats::default());
    let shutdown_engine = shutdown.clone();
    let live_engine = live.clone();
    let backend_kind = spec.kind();
    let engine_join = std::thread::spawn(move || match spec {
        EngineSpec::Xla { artifacts_dir, artifact, params } => {
            let rt = Runtime::new(&artifacts_dir)?;
            let session = crate::runtime::DecodeSession::new(
                &rt, &artifact, params)?;
            run_engine_opts(&session, rx, &opts, shutdown_engine,
                            &live_engine)
        }
        EngineSpec::Native(backend) => {
            run_engine_opts(&backend, rx, &opts, shutdown_engine,
                            &live_engine)
        }
    });

    let shutdown2 = shutdown.clone();
    let defaults = Arc::new(ProtocolDefaults::from_serve(cfg));
    let self_addr = addr.clone();
    let listener_join = std::thread::spawn(move || {
        for stream in listener.incoming() {
            // ord: SeqCst — control edge: any thread's shutdown store
            // (handle_line, ServerHandle::stop) must be seen here
            if shutdown2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let shutdown3 = shutdown2.clone();
            let live3 = live.clone();
            let addr3 = self_addr.clone();
            let defaults3 = defaults.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx, defaults3, shutdown3,
                                    live3, addr3);
            });
        }
        // tx (and all clones in finished handlers) dropping closes the
        // engine's queue, letting run_engine drain and exit.
    });

    crate::log_info!("serving on {addr} ({backend_kind} backend)");
    Ok(ServerHandle {
        addr,
        shutdown,
        join: Some(engine_join),
        listener_join: Some(listener_join),
    })
}

/// In-flight requests of one connection: wire id -> engine cancel flag.
/// Shared by the reader thread (registration, `{"cmd":"cancel"}`,
/// disconnect sweep) and the per-request sinks (a `done` event retires
/// its entry).
pub(crate) type ActiveMap = Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>>;

/// The engine-side event sink for one request on one connection:
/// serialises events to protocol lines tagged with the wire id and hands
/// them to the connection's writer thread.  Reports [`SinkClosed`] once
/// the connection is known dead (reader saw EOF or the writer hit a
/// write error), which the engine treats as an implicit cancel.
pub(crate) struct ConnSink {
    id: u64,
    writer: Sender<String>,
    closed: Arc<AtomicBool>,
    active: ActiveMap,
    /// Latched when the terminal `done` event is produced.  If the sink
    /// is dropped WITHOUT it — the engine thread errored out or drained
    /// the request without answering — `Drop` emits a terminal
    /// `unavailable` error event instead, so a blocking client's stream
    /// always ends (v1 replied "engine dropped the request" from the
    /// response channel's disconnect; a v2 stream must not just go
    /// silent).
    terminal_sent: AtomicBool,
}

#[cfg(test)]
impl ConnSink {
    /// Build a sink on caller-supplied plumbing.  Used by this file's
    /// unit tests and by the model-checked terminal-delivery invariant
    /// in `crate::mc` (which explores engine-drop vs. disconnect
    /// interleavings against the REAL sink, not a model of it).
    pub(crate) fn for_test(id: u64, writer: Sender<String>,
                           closed: Arc<AtomicBool>, active: ActiveMap)
                           -> Self {
        ConnSink {
            id,
            writer,
            closed,
            active,
            terminal_sent: AtomicBool::new(false),
        }
    }
}

impl Drop for ConnSink {
    fn drop(&mut self) {
        // ord: SeqCst — pairs with the store in `send`; both run on the
        // engine thread today, but the latch must stay correct if a
        // sink ever outlives its request on another thread
        if self.terminal_sent.load(Ordering::SeqCst) {
            return;
        }
        // no unwrap: never panic in drop on a poisoned map
        if let Ok(mut map) = self.active.lock() {
            map.remove(&self.id);
        }
        let reply = err_reply(Some(self.id), "unavailable",
                              "engine dropped the request");
        let _ = self.writer.send(reply.to_string());
    }
}

impl EventSink for ConnSink {
    fn send(&self, ev: EngineEvent) -> std::result::Result<(), SinkClosed> {
        // ord: SeqCst — reader/writer threads store `closed`; the
        // engine thread must observe it to stop decoding for the peer
        if self.closed.load(Ordering::SeqCst) {
            return Err(SinkClosed);
        }
        let idp = ("id", Json::num(self.id as f64));
        let (line, terminal) = match ev {
            EngineEvent::Started { queue_ms } => (
                Json::obj(vec![
                    idp,
                    ("event", Json::str("start")),
                    ("queue_ms", Json::num(queue_ms)),
                ]),
                false,
            ),
            EngineEvent::Token { index, token, uncertainty } => (
                Json::obj(vec![
                    idp,
                    ("event", Json::str("token")),
                    ("index", Json::num(index as f64)),
                    ("token", Json::num(token as f64)),
                    ("uncertainty", Json::num(uncertainty as f64)),
                ]),
                false,
            ),
            EngineEvent::Done(r) => (
                Json::obj(vec![
                    idp,
                    ("event", Json::str("done")),
                    ("tokens",
                     Json::Arr(r.tokens.iter()
                         .map(|&t| Json::num(t as f64))
                         .collect())),
                    ("queue_ms", Json::num(r.queue_ms)),
                    ("total_ms", Json::num(r.total_ms)),
                    ("uncertainty", Json::num(r.uncertainty as f64)),
                    ("cancelled", Json::Bool(r.cancelled)),
                    ("cached_tokens", Json::num(r.cached_tokens as f64)),
                ]),
                true,
            ),
            // the request's lane of a fused prefill round errored; the
            // engine has already released the slot — terminal for THIS
            // request only, the connection and every other stream stay
            // usable
            EngineEvent::Failed { message } => (
                err_reply(Some(self.id), "prefill-failed", &message),
                true,
            ),
        };
        if terminal {
            // the id becomes reusable the moment its terminal event is
            // enqueued — BEFORE the send, so a reader that saw `done`
            // can immediately resubmit the id without racing this map
            // ord: SeqCst — latch store must be visible to Drop (which
            // may run on the engine thread after an error path)
            self.terminal_sent.store(true, Ordering::SeqCst);
            // a poisoned map must not panic the engine thread (it is
            // the thread calling send): the id stays registered, which
            // only costs a failed reuse on an already-dying connection
            if let Ok(mut map) = self.active.lock() {
                map.remove(&self.id);
            }
        }
        self.writer.send(line.to_string()).map_err(|_| SinkClosed)
    }
}

fn handle_conn(stream: TcpStream,
               tx: std::sync::mpsc::Sender<EngineRequest>,
               defaults: Arc<ProtocolDefaults>, shutdown: Arc<AtomicBool>,
               live: Arc<LiveStats>, self_addr: String)
               -> Result<()> {
    let peer = stream.peer_addr().ok();
    let writer_stream = stream.try_clone()?;
    // the writer thread owns the write half: every line this connection
    // emits (command replies AND event streams of any number of in-
    // flight requests) funnels through one channel, so concurrent
    // requests multiplex without interleaving bytes mid-line
    let (wtx, wrx) = channel::<String>();
    let closed = Arc::new(AtomicBool::new(false));
    let closed_writer = closed.clone();
    let writer_join = std::thread::spawn(move || {
        let mut w = writer_stream;
        for line in wrx {
            if w.write_all(line.as_bytes())
                .and_then(|_| w.write_all(b"\n"))
                .and_then(|_| w.flush())
                .is_err()
            {
                // peer gone: flag it so sinks stop producing, and stop
                // consuming — remaining senders see a dropped receiver
                // ord: SeqCst — must reach the engine thread's load in
                // `ConnSink::send` so it retires the request
                closed_writer.store(true, Ordering::SeqCst);
                break;
            }
        }
    });
    let active: ActiveMap = Arc::new(Mutex::new(HashMap::new()));
    let ctx = ConnCtx {
        tx: &tx,
        defaults: &defaults,
        shutdown: &shutdown,
        live: &live,
        self_addr: &self_addr,
        wtx: &wtx,
        closed: &closed,
        active: &active,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        if let Some(reply) = handle_line(&line, &ctx) {
            if wtx.send(reply.to_string()).is_err() {
                break;
            }
        }
        // ord: SeqCst — see the flag our own handle_line (or any other
        // connection's) just stored, before blocking on the next line
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    // reader gone (client closed, or server shutting down): the sink is
    // marked closed and every request still in flight on this connection
    // is implicitly cancelled, so the engine stops burning batch lanes
    // on a dead connection instead of decoding to max_new into the void
    // ord: SeqCst — both stores are cross-thread control edges read by
    // the engine (`closed` in ConnSink::send, cancel flags in the
    // sweep); the per-request flags below ride the same rationale
    closed.store(true, Ordering::SeqCst);
    // poisoned map: the panicking thread already flagged nothing, but
    // the sinks' `closed` check above still retires every in-flight
    // request on the next engine event, so skip rather than panic
    if let Ok(mut map) = active.lock() {
        for (_, flag) in map.drain() {
            flag.store(true, Ordering::SeqCst);
        }
    }
    drop(wtx);
    let _ = writer_join.join();
    crate::log_debug!("connection {peer:?} closed");
    Ok(())
}

/// Everything a protocol line may need, bundled so `handle_line` stays
/// testable and the reader loop readable.
struct ConnCtx<'a> {
    // std channel on purpose: must match the engine thread's Receiver
    // (see `serve_with`); the engine polls it with `recv_timeout`.
    tx: &'a std::sync::mpsc::Sender<EngineRequest>,
    defaults: &'a ProtocolDefaults,
    shutdown: &'a AtomicBool,
    live: &'a LiveStats,
    self_addr: &'a str,
    wtx: &'a Sender<String>,
    closed: &'a Arc<AtomicBool>,
    active: &'a ActiveMap,
}

/// One protocol line in; `Some(reply)` for commands and errors, `None`
/// for an accepted generation request (its reply is the event stream the
/// engine pushes through the writer thread).  Every failure mode is a
/// structured `{"event": "err", ...}` reply (documented atop this file)
/// — the connection always stays usable.
fn handle_line(line: &str, ctx: &ConnCtx) -> Option<Json> {
    let req = match crate::util::json::parse(line) {
        Ok(v) => v,
        Err(e) => return Some(err_reply(None, "bad-json", &e.to_string())),
    };
    if let Some(cmd) = req.get("cmd") {
        let Ok(cmd) = cmd.as_str() else {
            return Some(err_reply(None, "bad-cmd", "cmd must be a string"));
        };
        match cmd {
            "shutdown" => {
                // ord: SeqCst — read by the listener loop, every
                // reader loop, and the engine's per-step check
                ctx.shutdown.store(true, Ordering::SeqCst);
                // poke our own accept() so the listener observes the
                // flag and exits — without this, a client-issued
                // shutdown left the listener thread blocked until some
                // EXTERNAL connection happened to arrive
                let _ = TcpStream::connect(ctx.self_addr);
                return Some(Json::obj(vec![("ok", Json::Bool(true))]));
            }
            "ping" => {
                return Some(Json::obj(vec![("ok", Json::Bool(true))]));
            }
            "stats" => {
                let live = ctx.live;
                let n = |v: usize| Json::num(v as f64);
                return Some(Json::obj(vec![
                    ("requests", n(live.requests.load(Ordering::Relaxed))),
                    ("steps", n(live.steps.load(Ordering::Relaxed))),
                    ("tokens_out",
                     n(live.tokens_out.load(Ordering::Relaxed))),
                    ("prefill_tokens",
                     n(live.prefill_tokens.load(Ordering::Relaxed))),
                    ("cancelled",
                     n(live.cancelled.load(Ordering::Relaxed))),
                    ("failed",
                     n(live.failed.load(Ordering::Relaxed))),
                    ("wasted_tokens",
                     n(live.wasted_tokens.load(Ordering::Relaxed))),
                    ("prefix_hits",
                     n(live.prefix_hits.load(Ordering::Relaxed))),
                    ("prefix_partial_hits",
                     n(live.prefix_partial_hits.load(Ordering::Relaxed))),
                    ("prefix_misses",
                     n(live.prefix_misses.load(Ordering::Relaxed))),
                    ("prefix_evictions",
                     n(live.prefix_evictions.load(Ordering::Relaxed))),
                    ("prefix_cached_tokens",
                     n(live.prefix_cached_tokens
                         .load(Ordering::Relaxed))),
                    ("prefix_bytes",
                     n(live.prefix_bytes.load(Ordering::Relaxed))),
                    ("prefix_entries",
                     n(live.prefix_entries.load(Ordering::Relaxed))),
                ]));
            }
            "cancel" => {
                let id = match req.get("id").and_then(|x| {
                    int_in_range(x, 0.0, MAX_ID)
                }) {
                    Some(n) => n as u64,
                    None => {
                        return Some(err_reply(None, "bad-id",
                            "cancel needs an integer \"id\" in [0, 2^53]"));
                    }
                };
                // set the engine cancel flag; the entry itself is
                // removed when the request's terminal (cancelled) done
                // event goes out, keeping double-cancel a clean no-op
                let found = match ctx.active.lock() {
                    Ok(map) => match map.get(&id) {
                        Some(flag) => {
                            // ord: SeqCst — engine sweeps this flag
                            // from its own thread between steps
                            flag.store(true, Ordering::SeqCst);
                            true
                        }
                        None => false,
                    },
                    // poisoned map: nothing can be cancelled any more,
                    // which is exactly what `ok: false` reports
                    Err(_) => false,
                };
                return Some(Json::obj(vec![
                    ("ok", Json::Bool(found)),
                    ("id", Json::num(id as f64)),
                ]));
            }
            other => {
                return Some(err_reply(None, "unknown-cmd",
                                      &format!("unknown cmd {other:?}")));
            }
        }
    }
    let (id, prompt, max_new, sampler, cache) =
        match parse_request(&req, ctx.defaults) {
            Ok(parts) => parts,
            Err(reply) => return Some(reply),
        };
    let cancel = Arc::new(AtomicBool::new(false));
    {
        let Ok(mut map) = ctx.active.lock() else {
            // a poisoned connection map cannot accept new requests;
            // `unavailable` is the documented terminal for that state
            return Some(err_reply(Some(id), "unavailable",
                                  "connection state poisoned"));
        };
        if map.len() >= ctx.defaults.max_inflight {
            return Some(err_reply(Some(id), "too-many-inflight", &format!(
                "connection already has {} requests in flight (limit {})",
                map.len(), ctx.defaults.max_inflight)));
        }
        match map.entry(id) {
            Entry::Occupied(_) => {
                return Some(err_reply(Some(id), "duplicate-id", &format!(
                    "request id {id} is already in flight on this \
                     connection (ids are reusable after their done/err \
                     event)")));
            }
            Entry::Vacant(v) => {
                v.insert(cancel.clone());
            }
        }
    }
    let sink = ConnSink {
        id,
        writer: ctx.wtx.clone(),
        closed: ctx.closed.clone(),
        active: ctx.active.clone(),
        terminal_sent: AtomicBool::new(false),
    };
    // If the engine is gone this send fails and the SendError drops the
    // request — including its sink, whose Drop emits the terminal
    // `unavailable` error event and deregisters the id.  That is the
    // same single-terminal-line contract as every other path, so no
    // explicit reply here either way.
    let _ = ctx.tx.send(EngineRequest {
        prompt,
        max_new,
        sampler,
        submitted: Instant::now(),
        cancel,
        sink: Box::new(sink),
        cache,
    });
    None
}

/// A JSON number that is an exact integer within [lo, hi].
fn int_in_range(x: &Json, lo: f64, hi: f64) -> Option<f64> {
    let n = x.as_f64().ok()?;
    if n.fract() == 0.0 && n >= lo && n <= hi {
        Some(n)
    } else {
        None
    }
}

/// Parse one i32 token id, rejecting non-integers and out-of-range
/// values (the old `x.as_i64()? as i32` silently truncated both).
fn token_id(x: &Json) -> Option<i32> {
    let n = int_in_range(x, i32::MIN as f64, i32::MAX as f64)?;
    i32::try_from(n as i64).ok()
}

/// Validate a generation request against the server defaults; any
/// violation is the structured error reply to send back.  The `id` is
/// parsed FIRST so every later error can echo it.
#[allow(clippy::result_large_err)]
fn parse_request(req: &Json, d: &ProtocolDefaults)
                 -> std::result::Result<(u64, Vec<i32>, usize,
                                         SamplerConfig, bool),
                                        Json> {
    let Some(id_val) = req.get("id") else {
        return Err(err_reply(None, "missing-id",
            "generation requests carry a client-chosen integer \"id\" \
             in [0, 2^53] (protocol v2); its event stream is tagged \
             with it"));
    };
    let Some(id) = int_in_range(id_val, 0.0, MAX_ID) else {
        return Err(err_reply(None, "bad-id", &format!(
            "id = {} must be an integer in [0, 2^53] (JSON numbers are \
             exact only up to 2^53)",
            id_val.to_string())));
    };
    let id = id as u64;
    let fail = |code: &str, msg: String| Err(err_reply(Some(id), code,
                                                       &msg));
    let Some(prompt_val) = req.get("prompt") else {
        return fail("missing-prompt", "request has no \"prompt\"".into());
    };
    let Ok(arr) = prompt_val.as_arr() else {
        return fail("bad-prompt", "\"prompt\" must be an array".into());
    };
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, x) in arr.iter().enumerate() {
        match token_id(x) {
            Some(t) => prompt.push(t),
            None => {
                return fail("bad-prompt-token", format!(
                    "prompt[{i}] = {} is not a token id (want an \
                     integer in [{}, {}])",
                    x.to_string(), i32::MIN, i32::MAX));
            }
        }
    }
    let max_new = match req.get("max_new_tokens") {
        None => d.max_new,
        Some(x) => match int_in_range(x, 0.0, usize::MAX as f64) {
            Some(n) => n as usize,
            None => {
                return fail("bad-max-new", format!(
                    "max_new_tokens = {} must be a non-negative integer",
                    x.to_string()));
            }
        },
    };
    if max_new > d.max_new_limit {
        return fail("max-new-too-large", format!(
            "max_new_tokens {max_new} exceeds the server limit {} (the \
             server never clamps silently — ask for less)",
            d.max_new_limit));
    }
    let mut s = d.sampler.clone();
    if let Some(x) = req.get("temperature") {
        // finiteness is checked AFTER the f32 cast: an f64 like 1e39 is
        // finite but saturates to f32::INFINITY, which would silently
        // turn the softmax uniform
        match x.as_f64() {
            Ok(t) if (t as f32).is_finite() && t >= 0.0 => {
                s.temperature = t as f32;
            }
            _ => {
                return fail("bad-temperature", format!(
                    "temperature = {} must be a finite number >= 0",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("top_k") {
        match int_in_range(x, 0.0, usize::MAX as f64) {
            Some(k) => s.top_k = k as usize,
            None => {
                return fail("bad-top-k", format!(
                    "top_k = {} must be a non-negative integer",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("top_p") {
        match x.as_f64() {
            Ok(p) if p.is_finite() && p > 0.0 => s.top_p = p.min(1.0) as f32,
            _ => {
                return fail("bad-top-p", format!(
                    "top_p = {} must be a finite number in (0, 1] \
                     (>= 1 disables)",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("uncertainty_temp") {
        match x.as_f64() {
            Ok(c) if (c as f32).is_finite() && c >= 0.0 => {
                s.uncertainty_temp = c as f32;
            }
            _ => {
                return fail("bad-uncertainty-temp", format!(
                    "uncertainty_temp = {} must be a finite number >= 0",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("seed") {
        // bounded by 2^53, the largest integer range f64 (and therefore
        // JSON) represents exactly — beyond it distinct seeds would
        // silently collapse to the same key, the very class of silent
        // coercion this protocol rejects elsewhere
        match int_in_range(x, 0.0, MAX_ID) {
            Some(n) => s.seed = Some(n as u64),
            None => {
                return fail("bad-seed", format!(
                    "seed = {} must be an integer in [0, 2^53] (JSON \
                     numbers are exact only up to 2^53)",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("stop_tokens") {
        let Ok(arr) = x.as_arr() else {
            return fail("bad-stop-tokens",
                        "\"stop_tokens\" must be an array".into());
        };
        let mut stops = Vec::with_capacity(arr.len());
        for (i, t) in arr.iter().enumerate() {
            match token_id(t) {
                Some(id) => stops.push(id),
                None => {
                    return fail("bad-stop-tokens", format!(
                        "stop_tokens[{i}] = {} is not a token id",
                        t.to_string()));
                }
            }
        }
        s.stop_tokens = stops; // REPLACES the server default list
    }
    if let Some(x) = req.get("eos") {
        match token_id(x) {
            Some(id) => s.stop_tokens.push(id),
            None => {
                return fail("bad-eos", format!(
                    "eos = {} is not a token id", x.to_string()));
            }
        }
    }
    let cache = match req.get("cache") {
        None => true,
        Some(x) => match x.as_bool() {
            Ok(b) => b,
            Err(_) => {
                return fail("bad-cache", format!(
                    "cache = {} must be a boolean", x.to_string()));
            }
        },
    };
    Ok((id, prompt, max_new, s, cache))
}

/// Optional per-request sampling & termination fields for
/// [`Client::request_opts`] / [`Client::stream`].  `None` fields are
/// omitted from the wire request, so the server default applies.
#[derive(Clone, Debug, Default)]
pub struct RequestOpts {
    pub temperature: Option<f64>,
    pub top_k: Option<usize>,
    pub top_p: Option<f64>,
    /// Sampling seed; the protocol carries it as a JSON number, so the
    /// server only accepts values up to 2^53 (exact-integer f64 range).
    pub seed: Option<u64>,
    pub stop_tokens: Option<Vec<i32>>,
    pub eos: Option<i32>,
    pub uncertainty_temp: Option<f64>,
    /// `Some(false)` opts this request out of the belief-state prefix
    /// cache (no snapshot lookup or insertion); `None`/`Some(true)`
    /// participate (the default).
    pub cache: Option<bool>,
}

/// One parsed protocol-v2 event line, as surfaced by
/// [`Client::next_event`] / [`Client::stream`].
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// The request entered a batch slot; queue time is final.
    Start { id: u64, queue_ms: f64 },
    /// One sampled token with its post-step posterior uncertainty.
    Token { id: u64, index: usize, token: i32, uncertainty: f64 },
    /// Terminal: the full legacy reply shape.  `tokens` is always the
    /// concatenation of the `Token` events.
    Done {
        id: u64,
        tokens: Vec<i32>,
        queue_ms: f64,
        total_ms: f64,
        uncertainty: f64,
        cancelled: bool,
        /// Prompt tokens skipped via a restored prefix-cache snapshot.
        cached_tokens: usize,
    },
    /// Terminal: the request (or, with `id: None`, the protocol line)
    /// was rejected.
    Err { id: Option<u64>, code: String, msg: String },
}

impl StreamEvent {
    /// The request this event belongs to (None only for errors on lines
    /// whose id was unparseable).
    pub fn id(&self) -> Option<u64> {
        match self {
            StreamEvent::Start { id, .. }
            | StreamEvent::Token { id, .. }
            | StreamEvent::Done { id, .. } => Some(*id),
            StreamEvent::Err { id, .. } => *id,
        }
    }

    /// Terminal events end a request's stream.
    pub fn is_terminal(&self) -> bool {
        matches!(self, StreamEvent::Done { .. } | StreamEvent::Err { .. })
    }

    fn from_json(j: &Json) -> Result<StreamEvent> {
        let id_of = |j: &Json| -> Result<u64> {
            Ok(j.req("id")?.as_f64()? as u64)
        };
        match j.req("event")?.as_str()? {
            "start" => Ok(StreamEvent::Start {
                id: id_of(j)?,
                queue_ms: j.req("queue_ms")?.as_f64()?,
            }),
            "token" => Ok(StreamEvent::Token {
                id: id_of(j)?,
                index: j.req("index")?.as_usize()?,
                token: i32::try_from(j.req("token")?.as_i64()?)?,
                uncertainty: j.req("uncertainty")?.as_f64()?,
            }),
            "done" => Ok(StreamEvent::Done {
                id: id_of(j)?,
                tokens: j
                    .req("tokens")?
                    .as_arr()?
                    .iter()
                    .map(|t| Ok(i32::try_from(t.as_i64()?)?))
                    .collect::<Result<_>>()?,
                queue_ms: j.req("queue_ms")?.as_f64()?,
                total_ms: j.req("total_ms")?.as_f64()?,
                uncertainty: j.req("uncertainty")?.as_f64()?,
                cancelled: j.req("cancelled")?.as_bool()?,
                cached_tokens: j.req("cached_tokens")?.as_usize()?,
            }),
            "err" => {
                let e = j.req("err")?;
                Ok(StreamEvent::Err {
                    id: j.get("id").and_then(|x| x.as_f64().ok())
                        .map(|n| n as u64),
                    code: e.req("code")?.as_str()?.to_string(),
                    msg: e.req("msg")?.as_str()?.to_string(),
                })
            }
            other => bail!("unknown event kind {other:?}"),
        }
    }
}

/// Typed protocol-v2 client (used by tests, the serve_demo example and
/// the throughput bench).  Supports any number of multiplexed in-flight
/// requests on one connection: [`Client::submit`] fires one off,
/// [`Client::next_event`] reads whatever arrives next,
/// [`Client::stream`] iterates one request's events, and
/// [`Client::cancel`] aborts one mid-generation.  The legacy blocking
/// [`Client::request`] / [`Client::request_opts`] survive as thin
/// stream-and-collect wrappers returning the v1 one-shot reply shape.
pub struct Client {
    stream: BufReader<TcpStream>,
    /// Events read while looking for something else (a command reply, or
    /// another request's events) — drained before the socket is touched
    /// again.
    pending: VecDeque<StreamEvent>,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            stream: BufReader::new(stream),
            pending: VecDeque::new(),
            next_id: 0,
        })
    }

    /// Blocking one-shot request (legacy v1 shape): stream-and-collect
    /// over the v2 protocol, returning
    /// `{"tokens", "queue_ms", "total_ms", "uncertainty"}` (or the
    /// legacy `{"err": {...}}` object if the request was rejected).
    pub fn request(&mut self, prompt: &[i32], max_new: usize)
                   -> Result<Json> {
        self.request_opts(prompt, max_new, &RequestOpts::default())
    }

    /// [`Client::request`] with explicit sampling & termination fields.
    pub fn request_opts(&mut self, prompt: &[i32], max_new: usize,
                        opts: &RequestOpts) -> Result<Json> {
        let id = self.submit(prompt, max_new, opts)?;
        loop {
            match self.next_event_for(id)? {
                StreamEvent::Done {
                    tokens, queue_ms, total_ms, uncertainty,
                    cached_tokens, ..
                } => {
                    return Ok(Json::obj(vec![
                        ("tokens",
                         Json::Arr(tokens.iter()
                             .map(|&t| Json::num(t as f64))
                             .collect())),
                        ("queue_ms", Json::num(queue_ms)),
                        ("total_ms", Json::num(total_ms)),
                        ("uncertainty", Json::num(uncertainty)),
                        ("cached_tokens",
                         Json::num(cached_tokens as f64)),
                    ]));
                }
                StreamEvent::Err { code, msg, .. } => {
                    return Ok(err_reply(None, &code, &msg));
                }
                StreamEvent::Start { .. } | StreamEvent::Token { .. } => {}
            }
        }
    }

    /// Fire off a generation request without waiting for anything;
    /// returns its connection-scoped id.  Events arrive via
    /// [`Client::next_event`] / [`Client::stream`].
    pub fn submit(&mut self, prompt: &[i32], max_new: usize,
                  opts: &RequestOpts) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let mut pairs = vec![
            ("id", Json::num(id as f64)),
            ("prompt",
             Json::Arr(prompt.iter().map(|&t| Json::num(t as f64))
                 .collect())),
            ("max_new_tokens", Json::num(max_new as f64)),
        ];
        if let Some(t) = opts.temperature {
            pairs.push(("temperature", Json::num(t)));
        }
        if let Some(k) = opts.top_k {
            pairs.push(("top_k", Json::num(k as f64)));
        }
        if let Some(p) = opts.top_p {
            pairs.push(("top_p", Json::num(p)));
        }
        if let Some(s) = opts.seed {
            pairs.push(("seed", Json::num(s as f64)));
        }
        if let Some(stops) = &opts.stop_tokens {
            pairs.push(("stop_tokens",
                        Json::Arr(stops.iter()
                            .map(|&t| Json::num(t as f64))
                            .collect())));
        }
        if let Some(e) = opts.eos {
            pairs.push(("eos", Json::num(e as f64)));
        }
        if let Some(c) = opts.uncertainty_temp {
            pairs.push(("uncertainty_temp", Json::num(c)));
        }
        if let Some(c) = opts.cache {
            pairs.push(("cache", Json::Bool(c)));
        }
        self.write_line(&Json::obj(pairs).to_string())?;
        Ok(id)
    }

    /// Submit and iterate the request's event stream; the iterator ends
    /// after the terminal `Done`/`Err` event.  Events of OTHER in-flight
    /// requests encountered along the way are buffered, not lost.
    pub fn stream(&mut self, prompt: &[i32], max_new: usize,
                  opts: &RequestOpts) -> Result<ClientStream<'_>> {
        let id = self.submit(prompt, max_new, opts)?;
        Ok(ClientStream { client: self, id, finished: false })
    }

    /// The next event from ANY in-flight request (buffered events
    /// first).
    pub fn next_event(&mut self) -> Result<StreamEvent> {
        if let Some(ev) = self.pending.pop_front() {
            return Ok(ev);
        }
        self.read_event()
    }

    /// Cancel an in-flight request: `{"ok": true}` if it was still
    /// active (its stream then ends with a `cancelled: true` done
    /// event), `{"ok": false}` if the id was unknown or already
    /// finished.
    pub fn cancel(&mut self, id: u64) -> Result<Json> {
        self.send_cmd(&format!("{{\"cmd\":\"cancel\",\"id\":{id}}}"))
    }

    pub fn ping(&mut self) -> Result<Json> {
        self.send_cmd(r#"{"cmd":"ping"}"#)
    }

    /// Live engine counters: requests, steps, tokens_out,
    /// prefill_tokens, cancelled, failed, wasted_tokens, plus the prefix-cache
    /// mirrors (prefix_hits, prefix_partial_hits, prefix_misses,
    /// prefix_evictions, prefix_cached_tokens, prefix_bytes,
    /// prefix_entries) — answered mid-serve, not only after shutdown.
    pub fn stats(&mut self) -> Result<Json> {
        self.send_cmd(r#"{"cmd":"stats"}"#)
    }

    pub fn shutdown(&mut self) -> Result<Json> {
        self.send_cmd(r#"{"cmd":"shutdown"}"#)
    }

    // ------------------------------------------------------ plumbing --

    fn write_line(&mut self, line: &str) -> Result<()> {
        let stream = self.stream.get_mut();
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
        Ok(())
    }

    fn read_json(&mut self) -> Result<Json> {
        let mut reply = String::new();
        if self.stream.read_line(&mut reply)? == 0 {
            bail!("connection closed by server");
        }
        crate::util::json::parse(reply.trim())
    }

    fn read_event(&mut self) -> Result<StreamEvent> {
        let j = self.read_json()?;
        StreamEvent::from_json(&j)
    }

    /// The next event belonging to request `id` (or a global error with
    /// no id — the reply to a line the server could not attribute);
    /// events of other requests are buffered in arrival order.
    fn next_event_for(&mut self, id: u64) -> Result<StreamEvent> {
        if let Some(pos) = self
            .pending
            .iter()
            .position(|e| e.id() == Some(id) || e.id().is_none())
        {
            if let Some(ev) = self.pending.remove(pos) {
                return Ok(ev);
            }
        }
        loop {
            let ev = self.read_event()?;
            if ev.id() == Some(id) || ev.id().is_none() {
                return Ok(ev);
            }
            self.pending.push_back(ev);
        }
    }

    /// Send a command line and return its (untagged) reply, buffering
    /// any event lines that arrive first — in-flight streams interleave
    /// freely with command replies.
    fn send_cmd(&mut self, line: &str) -> Result<Json> {
        self.write_line(line)?;
        loop {
            let j = self.read_json()?;
            if j.get("event").is_none() {
                return Ok(j);
            }
            let ev = StreamEvent::from_json(&j)?;
            if matches!(ev, StreamEvent::Err { id: None, .. }) {
                // an error the server could not attribute to a request
                // is the reply to the line we just sent
                return Ok(j);
            }
            self.pending.push_back(ev);
        }
    }
}

/// Iterator over one request's event stream (see [`Client::stream`]);
/// ends after the terminal event.  A transport error also ends the
/// stream (check [`ClientStream::finished`] semantics via the terminal
/// event if you need to distinguish).
pub struct ClientStream<'a> {
    client: &'a mut Client,
    id: u64,
    finished: bool,
}

impl ClientStream<'_> {
    /// The connection-scoped id of the request this stream follows.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Cancel this request mid-stream; its terminal event will be a
    /// `cancelled: true` done (keep iterating to observe it).
    pub fn cancel(&mut self) -> Result<Json> {
        let id = self.id;
        self.client.cancel(id)
    }
}

impl Iterator for ClientStream<'_> {
    type Item = StreamEvent;

    fn next(&mut self) -> Option<StreamEvent> {
        if self.finished {
            return None;
        }
        match self.client.next_event_for(self.id) {
            Ok(ev) => {
                if ev.is_terminal() {
                    self.finished = true;
                }
                Some(ev)
            }
            Err(_) => {
                self.finished = true;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // `channel` here is the mc::sync one from the parent's imports: a
    // std passthrough normally, a model-aware shim under `mc-shim`
    // (where it degrades to std outside a model execution, so these
    // tests behave identically under both builds).
    fn sink(id: u64, writer: Sender<String>, active: &ActiveMap)
            -> ConnSink {
        active.lock().unwrap()
            .insert(id, Arc::new(AtomicBool::new(false)));
        ConnSink::for_test(id, writer,
                           Arc::new(AtomicBool::new(false)),
                           active.clone())
    }

    #[test]
    fn conn_sink_drop_without_terminal_emits_unavailable() {
        // the v1 "engine dropped the request" contract: an accepted
        // request whose sink dies without a done event must still end
        // its stream with a terminal error line, and free its id
        let (wtx, wrx) = channel::<String>();
        let active: ActiveMap = Arc::new(Mutex::new(HashMap::new()));
        let s = sink(9, wtx, &active);
        s.send(EngineEvent::Started { queue_ms: 0.5 }).unwrap();
        drop(s); // engine discarded the request (error / shutdown drain)
        let lines: Vec<String> = wrx.iter().collect();
        assert_eq!(lines.len(), 2, "start + terminal err: {lines:?}");
        let err = crate::util::json::parse(&lines[1]).unwrap();
        assert_eq!(err.req("event").unwrap().as_str().unwrap(), "err");
        assert_eq!(err.req("id").unwrap().as_i64().unwrap(), 9);
        assert_eq!(
            err.req("err").unwrap().req("code").unwrap()
                .as_str().unwrap(),
            "unavailable");
        assert!(active.lock().unwrap().is_empty(),
                "drop must deregister the id");
    }

    #[test]
    fn conn_sink_done_is_terminal_and_suppresses_the_drop_event() {
        let (wtx, wrx) = channel::<String>();
        let active: ActiveMap = Arc::new(Mutex::new(HashMap::new()));
        let s = sink(3, wtx, &active);
        s.send(EngineEvent::Token { index: 0, token: 7,
                                    uncertainty: 0.25 })
            .unwrap();
        s.send(EngineEvent::Done(crate::serve::EngineResponse {
            tokens: vec![7],
            queue_ms: 0.0,
            total_ms: 1.0,
            uncertainty: 0.25,
            cancelled: false,
            cached_tokens: 0,
        }))
        .unwrap();
        // done already freed the id for reuse
        assert!(active.lock().unwrap().is_empty());
        drop(s);
        let lines: Vec<String> = wrx.iter().collect();
        assert_eq!(lines.len(), 2, "token + done, NO drop event: {lines:?}");
        let done = crate::util::json::parse(&lines[1]).unwrap();
        assert_eq!(done.req("event").unwrap().as_str().unwrap(), "done");
        assert!(!done.req("cancelled").unwrap().as_bool().unwrap());
        let tok = crate::util::json::parse(&lines[0]).unwrap();
        assert_eq!(tok.req("event").unwrap().as_str().unwrap(), "token");
        assert_eq!(tok.req("token").unwrap().as_i64().unwrap(), 7);
        assert_eq!(tok.req("index").unwrap().as_i64().unwrap(), 0);
    }

    #[test]
    fn conn_sink_failed_is_a_terminal_prefill_failed_err() {
        // a fused-prefill fault retires ONE request: the sink turns
        // EngineEvent::Failed into a terminal err line, frees the id,
        // and suppresses the drop-time unavailable event
        let (wtx, wrx) = channel::<String>();
        let active: ActiveMap = Arc::new(Mutex::new(HashMap::new()));
        let s = sink(5, wtx, &active);
        s.send(EngineEvent::Started { queue_ms: 0.0 }).unwrap();
        s.send(EngineEvent::Failed {
            message: "prefill failed: injected".into(),
        })
        .unwrap();
        assert!(active.lock().unwrap().is_empty(),
                "failed must free the id like done does");
        drop(s);
        let lines: Vec<String> = wrx.iter().collect();
        assert_eq!(lines.len(), 2,
                   "start + terminal err, NO drop event: {lines:?}");
        let err = crate::util::json::parse(&lines[1]).unwrap();
        assert_eq!(err.req("event").unwrap().as_str().unwrap(), "err");
        assert_eq!(err.req("id").unwrap().as_i64().unwrap(), 5);
        let body = err.req("err").unwrap();
        assert_eq!(body.req("code").unwrap().as_str().unwrap(),
                   "prefill-failed");
        assert!(body.req("msg").unwrap().as_str().unwrap()
                    .contains("injected"));
    }

    #[test]
    fn closed_conn_sink_refuses_events() {
        let (wtx, wrx) = channel::<String>();
        let active: ActiveMap = Arc::new(Mutex::new(HashMap::new()));
        let s = sink(1, wtx, &active);
        s.closed.store(true, Ordering::SeqCst);
        assert!(s.send(EngineEvent::Started { queue_ms: 0.0 }).is_err(),
                "a closed connection must report SinkClosed");
        drop(s);
        // the drop-event goes to the (dead) writer; nothing else did
        let lines: Vec<String> = wrx.iter().collect();
        assert_eq!(lines.len(), 1, "{lines:?}");
    }
}

//! Waiver fixture for the `atomic-ordering` pass: both waivable
//! finding classes suppressed by reasoned waivers.  Never compiled —
//! `include_str!`-ed by unit tests only.

use std::sync::atomic::{AtomicUsize, Ordering};

pub struct Flags {
    pub ready: AtomicUsize,
}

pub fn waived_relaxed(f: &Flags) -> usize {
    // lint: allow(atomic-ordering, advisory flag; stale reads are safe)
    f.ready.load(Ordering::Relaxed)
}

pub fn waived_missing(f: &Flags) {
    // lint: allow(atomic-ordering, rationale lives on the paired load)
    f.ready.store(1, Ordering::Release);
}

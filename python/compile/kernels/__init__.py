"""L1 kernels: the KLA information filter in three interchangeable forms.

- `kla_filter_ref*`  — sequential oracle (ref.py), the correctness signal;
- `kla_filter_scan`  — time-parallel associative scans (scan.py),
                       differentiable, used by training artifacts;
- `kla_filter_pallas`— chunked Pallas kernel (pallas_kla.py), interpret-mode
                       on CPU, custom-VJP'd through the scan form.

`kla_filter(..., impl=...)` dispatches between them so L2 model code is
implementation-agnostic.
"""

from .ref import (kla_filter_ref, kla_filter_ref_batched,
                  kla_filter_ref_python, kla_posterior_moments,
                  LAM_MIN, LAM_MAX)
from .scan import kla_filter_scan, mobius_prefix_scan, affine_prefix_scan
from .pallas_kla import kla_filter_pallas
from .ou import constrain, discretise, discretise_raw

_IMPLS = {
    "ref": kla_filter_ref_batched,
    "scan": kla_filter_scan,
    "pallas": kla_filter_pallas,
}


def kla_filter(k, q, v, lam_v, abar, pbar, lam0, eta0, *, impl: str = "scan"):
    """Batched KLA filter.  k, q: (B,T,N); v, lam_v: (B,T,D);
    abar/pbar/lam0/eta0: (N,D).  Returns lam, eta: (B,T,N,D), y: (B,T,D)."""
    return _IMPLS[impl](k, q, v, lam_v, abar, pbar, lam0, eta0)

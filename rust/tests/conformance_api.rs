//! Conformance suite for the unified `kla::api` surface (the laws in the
//! `Filter` trait docs):
//!
//! 1. **Strategy conformance** — every `ScanPlan` strategy (Sequential,
//!    Blelloch, Chunked at thread counts 1/2/8) produces the same
//!    trajectories within 1e-5 (relative), for both the KLA information
//!    filter and the GLA baseline.
//! 2. **Carry-split equivalence** — splitting a sequence at arbitrary
//!    points and chaining `prefix()` through the carried belief (or
//!    chaining `step()` token by token) reproduces the one-shot
//!    `prefix()`; on the sequential strategy this is exact (bit-for-bit).
//! 3. **Batched entry** — `prefix_batch` equals per-row `prefix`.

use kla::api::{prefix_batch, Filter, GlaFilter, GlaInputs, GlaParams,
               KlaFilter, ScanPlan};
use kla::kla::{FilterInputs, FilterParams};
use kla::util::Pcg64;

const TOL: f32 = 1e-5;

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(),
               b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= TOL * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

/// Well-conditioned random params: gates bounded away from 1 so f32
/// round-off cannot amplify past the 1e-5 conformance tolerance.
fn tame_params(rng: &mut Pcg64, n: usize, d: usize) -> FilterParams {
    FilterParams {
        n,
        d,
        abar: (0..n * d).map(|_| rng.range_f32(0.7, 0.95)).collect(),
        pbar: (0..n * d).map(|_| rng.range_f32(0.02, 0.2)).collect(),
        lam0: (0..n * d).map(|_| rng.range_f32(0.5, 2.0)).collect(),
        eta0: (0..n * d).map(|_| rng.range_f32(-0.1, 0.1)).collect(),
    }
}

fn tame_inputs(rng: &mut Pcg64, t: usize, n: usize, d: usize)
               -> FilterInputs {
    FilterInputs {
        t,
        k: (0..t * n).map(|_| rng.normal_f32().clamp(-2.0, 2.0)).collect(),
        q: (0..t * n).map(|_| rng.normal_f32()).collect(),
        v: (0..t * d).map(|_| rng.normal_f32()).collect(),
        lam_v: (0..t * d).map(|_| rng.range_f32(0.1, 1.5)).collect(),
    }
}

fn gla_case(rng: &mut Pcg64, t: usize, s: usize) -> (GlaParams, GlaInputs) {
    (
        GlaParams {
            s,
            h0: (0..s).map(|_| rng.normal_f32()).collect(),
        },
        GlaInputs {
            t,
            f: (0..t * s).map(|_| rng.range_f32(0.3, 0.95)).collect(),
            b: (0..t * s).map(|_| rng.normal_f32()).collect(),
        },
    )
}

/// Every non-sequential plan the suite must reconcile with the
/// sequential reference (thread counts 1/2/8 per the issue).
fn all_plans() -> [ScanPlan; 4] {
    [
        ScanPlan::blelloch(),
        ScanPlan::chunked(1),
        ScanPlan::chunked(2),
        ScanPlan::chunked(8),
    ]
}

// ------------------------------------------------ strategy conformance ---

#[test]
fn kla_strategies_agree_within_tolerance() {
    let mut rng = Pcg64::seeded(0xC0FF);
    for &(t, n, d) in
        &[(1usize, 1usize, 1usize), (7, 2, 3), (64, 4, 8), (129, 3, 5),
          (300, 2, 4)]
    {
        let p = tame_params(&mut rng, n, d);
        let inp = tame_inputs(&mut rng, t, n, d);
        let prior = KlaFilter::init(&p);
        let (seq, seq_belief) =
            KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        for plan in all_plans() {
            let (par, par_belief) =
                KlaFilter::prefix(&p, &inp, &prior, &plan);
            let tag = format!("kla t={t} n={n} d={d} plan={plan:?}");
            assert_close(&seq.lam, &par.lam, &format!("{tag} lam"));
            assert_close(&seq.eta, &par.eta, &format!("{tag} eta"));
            assert_close(&seq.y, &par.y, &format!("{tag} y"));
            assert_close(&seq_belief.lam, &par_belief.lam,
                         &format!("{tag} belief.lam"));
            assert_close(&seq_belief.eta, &par_belief.eta,
                         &format!("{tag} belief.eta"));
        }
    }
}

#[test]
fn gla_strategies_agree_within_tolerance() {
    let mut rng = Pcg64::seeded(0x61A);
    for &(t, s) in &[(1usize, 1usize), (7, 3), (64, 16), (129, 5),
                     (300, 8)]
    {
        let (p, inp) = gla_case(&mut rng, t, s);
        let prior = GlaFilter::init(&p);
        let (seq, seq_belief) =
            GlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        for plan in all_plans() {
            let (par, par_belief) =
                GlaFilter::prefix(&p, &inp, &prior, &plan);
            let tag = format!("gla t={t} s={s} plan={plan:?}");
            assert_close(&seq, &par, &format!("{tag} h"));
            assert_close(&seq_belief.h, &par_belief.h,
                         &format!("{tag} belief"));
        }
    }
}

// --------------------------------------------- carry-split equivalence ---

/// Split `[0, t)` into random contiguous segments.
fn random_splits(rng: &mut Pcg64, t: usize) -> Vec<(usize, usize)> {
    let mut cuts = vec![0usize, t];
    for _ in 0..3 {
        cuts.push(rng.usize_below(t + 1));
    }
    cuts.sort_unstable();
    cuts.dedup();
    cuts.windows(2).map(|w| (w[0], w[1])).collect()
}

#[test]
fn kla_prefix_chaining_is_exact_on_sequential() {
    let mut rng = Pcg64::seeded(0x5E9);
    for &(t, n, d) in &[(5usize, 1usize, 1usize), (37, 2, 3), (128, 3, 4)]
    {
        let p = tame_params(&mut rng, n, d);
        let inp = tame_inputs(&mut rng, t, n, d);
        let prior = KlaFilter::init(&p);
        let plan = ScanPlan::sequential();
        let (full, full_belief) = KlaFilter::prefix(&p, &inp, &prior, &plan);
        for _ in 0..4 {
            let mut belief = prior.clone();
            let mut lam = Vec::new();
            let mut eta = Vec::new();
            let mut y = Vec::new();
            for (lo, hi) in random_splits(&mut rng, t) {
                let part = KlaFilter::slice(&inp, lo, hi);
                let (out, next) =
                    KlaFilter::prefix(&p, &part, &belief, &plan);
                lam.extend(out.lam);
                eta.extend(out.eta);
                y.extend(out.y);
                belief = next;
            }
            // sequential chaining runs the identical op sequence: exact
            assert_eq!(full.lam, lam, "lam t={t}");
            assert_eq!(full.eta, eta, "eta t={t}");
            assert_eq!(full.y, y, "y t={t}");
            assert_eq!(full_belief, belief, "belief t={t}");
        }
    }
}

#[test]
fn kla_prefix_chaining_conforms_on_parallel_plans() {
    let mut rng = Pcg64::seeded(0xCAFE);
    let (t, n, d) = (200usize, 2usize, 4usize);
    let p = tame_params(&mut rng, n, d);
    let inp = tame_inputs(&mut rng, t, n, d);
    let prior = KlaFilter::init(&p);
    let (full, _) =
        KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
    for plan in all_plans() {
        let mut belief = prior.clone();
        let mut y = Vec::new();
        for (lo, hi) in random_splits(&mut rng, t) {
            let part = KlaFilter::slice(&inp, lo, hi);
            let (out, next) = KlaFilter::prefix(&p, &part, &belief, &plan);
            y.extend(out.y);
            belief = next;
        }
        assert_close(&full.y, &y, &format!("chained y plan={plan:?}"));
    }
}

#[test]
fn kla_step_chain_reproduces_prefix_exactly() {
    let mut rng = Pcg64::seeded(0x57E9);
    for &(t, n, d) in &[(1usize, 1usize, 1usize), (23, 3, 4), (100, 2, 6)]
    {
        let p = tame_params(&mut rng, n, d);
        let inp = tame_inputs(&mut rng, t, n, d);
        let prior = KlaFilter::init(&p);
        let (full, full_belief) =
            KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        let s = p.state();
        let mut belief = prior.clone();
        for ti in 0..t {
            let y = KlaFilter::step(&p, &inp, ti, &mut belief);
            assert_eq!(&full.lam[ti * s..(ti + 1) * s], &belief.lam[..],
                       "lam t={t} ti={ti}");
            assert_eq!(&full.eta[ti * s..(ti + 1) * s], &belief.eta[..],
                       "eta t={t} ti={ti}");
            assert_eq!(&full.y[ti * d..(ti + 1) * d], &y[..],
                       "y t={t} ti={ti}");
        }
        assert_eq!(full_belief, belief);
    }
}

#[test]
fn gla_carry_split_equivalence() {
    let mut rng = Pcg64::seeded(0x61A2);
    for &(t, s) in &[(5usize, 2usize), (37, 4), (128, 8)] {
        let (p, inp) = gla_case(&mut rng, t, s);
        let prior = GlaFilter::init(&p);
        let plan = ScanPlan::sequential();
        let (full, full_belief) = GlaFilter::prefix(&p, &inp, &prior, &plan);
        // prefix() chaining over random splits: exact on sequential
        let mut belief = prior.clone();
        let mut h = Vec::new();
        for (lo, hi) in random_splits(&mut rng, t) {
            let part = GlaFilter::slice(&inp, lo, hi);
            let (out, next) = GlaFilter::prefix(&p, &part, &belief, &plan);
            h.extend(out);
            belief = next;
        }
        assert_eq!(full, h, "h t={t}");
        assert_eq!(full_belief, belief);
        // step() chaining: exact
        let mut belief = prior.clone();
        for ti in 0..t {
            let row = GlaFilter::step(&p, &inp, ti, &mut belief);
            assert_eq!(&full[ti * s..(ti + 1) * s], &row[..], "ti={ti}");
        }
        assert_eq!(full_belief, belief);
    }
}

// -------------------------------------------------------- batched entry ---

#[test]
fn batched_entry_point_matches_per_row_prefix() {
    let mut rng = Pcg64::seeded(0xBA7C);
    let (n, d) = (2usize, 4usize);
    let p = tame_params(&mut rng, n, d);
    let rows: Vec<FilterInputs> = (0..6)
        .map(|i| tame_inputs(&mut rng, 20 + 13 * i, n, d))
        .collect();
    let beliefs: Vec<_> = (0..rows.len())
        .map(|_| KlaFilter::init(&p))
        .collect();
    let solo: Vec<_> = rows
        .iter()
        .zip(&beliefs)
        .map(|(r, b)| KlaFilter::prefix(&p, r, b, &ScanPlan::sequential()))
        .collect();
    for workers in [1usize, 2, 8] {
        let plan = ScanPlan::chunked(workers).with_batch(rows.len());
        let batched =
            prefix_batch::<KlaFilter>(&p, &rows, &beliefs, &plan);
        assert_eq!(batched.len(), solo.len());
        for (i, ((bo, bb), (so, sb))) in
            batched.iter().zip(&solo).enumerate()
        {
            // batched rows run the sequential op order: exact agreement
            assert_eq!(bo, so, "row {i} output (workers={workers})");
            assert_eq!(bb, sb, "row {i} belief (workers={workers})");
        }
    }
}

// ---------------------------------------------- native model-level law ---
// The filter-level laws above lift to the full language model: the native
// LM's O(1) `step()` chain must reproduce its batched `prefix()` forward,
// because every per-position op is shared and the filter carry obeys the
// step-chain law.  This is the model-level parity the serve stack rests
// on (the engine only ever calls `step()`).

use kla::kla::model::{NativeLm, NativeLmConfig};
use kla::tensor::IntTensor;

#[test]
fn native_model_step_chain_matches_prefix() {
    let cfg = NativeLmConfig {
        vocab: 24,
        d_model: 12,
        n_layers: 2,
        n_state: 3,
        conv_kernel: 4,
        process_noise: true,
        ou_exact: true,
    };
    let lm = NativeLm::seeded(&cfg, 0xD0D0);
    let (b, t) = (3usize, 17usize);
    let mut rng = Pcg64::seeded(99);
    let toks: Vec<i32> = (0..b * t)
        .map(|_| rng.below(cfg.vocab as u64) as i32)
        .collect();
    let full = lm
        .prefix(&IntTensor::new(&[b, t], toks.clone()).unwrap())
        .unwrap();
    let mut state = lm.init_state(b);
    for ti in 0..t {
        let col: Vec<i32> = (0..b).map(|bi| toks[bi * t + ti]).collect();
        let (logits, next) = lm
            .step(&IntTensor::new(&[b], col).unwrap(), &state)
            .unwrap();
        state = next;
        for bi in 0..b {
            for vi in 0..cfg.vocab {
                let a = logits.get(&[bi, vi]);
                let e = full.get(&[bi, ti, vi]);
                assert!(
                    (a - e).abs() <= TOL * (1.0 + a.abs().max(e.abs())),
                    "model parity bi={bi} ti={ti} vi={vi}: step {a} vs \
                     prefix {e}"
                );
            }
        }
    }
}

#[test]
fn native_model_ablation_variants_keep_parity() {
    // the two paper ablation switches change the dynamics, not the
    // carry-split structure — parity must hold for every variant
    for (process_noise, ou_exact) in
        [(false, true), (true, false), (false, false)]
    {
        let cfg = NativeLmConfig {
            vocab: 12,
            d_model: 8,
            n_layers: 1,
            n_state: 2,
            conv_kernel: 3,
            process_noise,
            ou_exact,
        };
        let lm = NativeLm::seeded(&cfg, 5);
        let t = 9usize;
        let toks: Vec<i32> = (0..t).map(|i| (i * 7 % 12) as i32).collect();
        let full = lm
            .prefix(&IntTensor::new(&[1, t], toks.clone()).unwrap())
            .unwrap();
        let mut state = lm.init_state(1);
        for (ti, &tok) in toks.iter().enumerate() {
            let (logits, next) = lm
                .step(&IntTensor::new(&[1], vec![tok]).unwrap(), &state)
                .unwrap();
            state = next;
            for vi in 0..cfg.vocab {
                let a = logits.get(&[0, vi]);
                let e = full.get(&[0, ti, vi]);
                assert!(
                    (a - e).abs() <= TOL * (1.0 + a.abs().max(e.abs())),
                    "pn={process_noise} oe={ou_exact} ti={ti} vi={vi}: \
                     {a} vs {e}"
                );
            }
        }
    }
}

#[test]
fn native_model_prefill_slot_conforms_across_plans() {
    // the serving-side lift of the strategy-conformance law: prefilling
    // one lane with a whole token chunk under ANY ScanPlan agrees with
    // chaining step() over the same tokens — exactly on the sequential
    // plan, within 1e-5 on Blelloch/Chunked (the scan strategies the
    // engine uses for chunked prompt prefill)
    let cfg = NativeLmConfig {
        vocab: 24,
        d_model: 12,
        n_layers: 2,
        n_state: 3,
        conv_kernel: 4,
        process_noise: true,
        ou_exact: true,
    };
    let lm = NativeLm::seeded(&cfg, 0xBEEF);
    let b = 2usize;
    let slot = 1usize;
    let mut rng = Pcg64::seeded(7);
    for t in [1usize, 3, 64, 129] {
        let toks: Vec<i32> = (0..t)
            .map(|_| rng.below(cfg.vocab as u64) as i32)
            .collect();
        // reference: chained step() over the whole batch
        let mut state = lm.init_state(b);
        let mut last = None;
        for &tok in &toks {
            let (lg, next) = lm
                .step(&IntTensor::new(&[b], vec![tok; b]).unwrap(), &state)
                .unwrap();
            state = next;
            last = Some(lg);
        }
        let ref_logits = last.unwrap();
        let lane_ref = state.slot(slot).unwrap();
        for plan in [ScanPlan::sequential(), ScanPlan::blelloch(),
                     ScanPlan::chunked(2), ScanPlan::chunked(8)]
        {
            let (lg, lane) = lm
                .prefill_slot(&IntTensor::new(&[t], toks.clone()).unwrap(),
                              slot, &lm.init_state(b), &plan)
                .unwrap();
            let tag = format!("t={t} plan={plan:?}");
            for vi in 0..cfg.vocab {
                let a = lg.get(&[vi]);
                let e = ref_logits.get(&[slot, vi]);
                assert!(
                    (a - e).abs() <= TOL * (1.0 + a.abs().max(e.abs())),
                    "{tag} logits[{vi}]: {a} vs {e}"
                );
            }
            assert_close(lane.lam.data(), lane_ref.lam.data(),
                         &format!("{tag} lane.lam"));
            assert_close(lane.eta.data(), lane_ref.eta.data(),
                         &format!("{tag} lane.eta"));
            assert_close(lane.conv.data(), lane_ref.conv.data(),
                         &format!("{tag} lane.conv"));
        }
    }
}

#[test]
fn native_model_checkpoint_roundtrip_preserves_logits() {
    let cfg = NativeLmConfig {
        vocab: 16,
        d_model: 8,
        n_layers: 2,
        n_state: 2,
        conv_kernel: 4,
        process_noise: true,
        ou_exact: true,
    };
    let lm = NativeLm::seeded(&cfg, 77);
    // per-process dir: concurrent test runs must not race on the file
    let dir = std::env::temp_dir()
        .join(format!("kla_native_ckpt_test_{}", std::process::id()));
    let dir_s = dir.to_str().unwrap();
    let path =
        kla::train::checkpoint::save(dir_s, "native_lm", &lm.to_values())
            .unwrap();
    let loaded = kla::train::checkpoint::load(&path).unwrap();
    let lm2 = NativeLm::from_values(&loaded, true, true).unwrap();
    let toks =
        IntTensor::new(&[2, 6], (0..12).map(|i| i % 16).collect()).unwrap();
    // the checkpoint format is lossless: logits identical bit-for-bit
    assert_eq!(lm.prefix(&toks).unwrap().data(),
               lm2.prefix(&toks).unwrap().data());
    std::fs::remove_file(path).ok();
}

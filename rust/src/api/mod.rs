//! # `kla::api` — one `Filter` abstraction for every native scan
//!
//! The paper's core observation is that the information-form Kalman
//! filter, GLA's gated linear recurrence, and decode-time stepping are the
//! *same associative-scan primitive* at different granularities.  This
//! module is that observation as an API:
//!
//! - [`Filter`] — a filter family with associated `Params`, `Inputs`, a
//!   carryable `Belief` state and per-step `Output`s.  `prefix()` runs a
//!   full-sequence scan from any belief; `step()` advances a belief by one
//!   token.  Chaining `step()` (or `prefix()` over slices, carrying the
//!   belief) reproduces the full scan — the carry-split property the
//!   conformance suite (`rust/tests/conformance_api.rs`) pins down.
//! - [`ScanPlan`] — a builder selecting the execution [`Strategy`]
//!   (`Sequential` | `Blelloch` | `Chunked { threads }` |
//!   `Chained { threads }` | `Auto`) and the batch dimension `B`, over
//!   the time-major layout every implementation shares.  `Auto` resolves
//!   by (B, T, cores): multi-lane work goes lane-chained across the
//!   shared pool, long single sequences go time-chunked, short ones stay
//!   sequential.
//! - [`prefix_batch`] — the batched `(B, T, …)` entry point: B independent
//!   sequences scanned under one plan, trading time-parallelism for
//!   batch-parallelism when B is large.
//!
//! Two families implement the trait today: [`KlaFilter`] (the information
//! filter from `kla::scan`) and [`GlaFilter`] (the gated linear baseline
//! from `baselines`).  Future backends (SIMD, PJRT-native, sharded) plug
//! in at this seam.
//!
//! ## Migration from the old free functions
//!
//! | old (pre-`kla::api`)                  | new                                              |
//! |---------------------------------------|--------------------------------------------------|
//! | `filter_sequential(&p, &inp)`         | `KlaFilter::prefix(&p, &inp, &b, &ScanPlan::sequential())` |
//! | `filter_scan(&p, &inp)`               | `KlaFilter::prefix(&p, &inp, &b, &ScanPlan::chunked(1))` |
//! | `filter_chunked(&p, &inp, threads)`   | `KlaFilter::prefix(&p, &inp, &b, &ScanPlan::chunked(threads))` |
//! | (no equivalent)                       | `KlaFilter::prefix(&p, &inp, &b, &ScanPlan::blelloch())` |
//! | (no equivalent, B=1 only)             | `prefix_batch::<KlaFilter>(&p, &rows, &beliefs, &plan)` |
//! | `linear_scan_sequential(t, s, …)`     | `GlaFilter::prefix(&p, &inp, &b, &ScanPlan::sequential())` |
//! | `linear_scan_chunked(t, s, …, th)`    | `GlaFilter::prefix(&p, &inp, &b, &ScanPlan::chunked(th))` |
//! | manual per-token loops at decode time | `Filter::step(&p, &inp, t, &mut belief)`         |
//!
//! where `b = KlaFilter::init(&p)` (resp. `GlaFilter::init`) is the prior
//! belief.  The free functions remain as the strategy internals.

use crate::baselines::{linear_scan_blelloch, linear_scan_chunked,
                       linear_scan_sequential};
use crate::kla::scan::{self, FilterInputs, FilterOutputs, FilterParams};

// --------------------------------------------------------------- plans ---

/// Execution strategy for a prefix scan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Naive time-stepped recurrence (the Fig. 4 recurrent baseline).
    Sequential,
    /// Work-efficient up-sweep/down-sweep tree scan, single-threaded —
    /// the O(log T)-depth reference shape of the L1 kernels.
    Blelloch,
    /// Two-level chunked scan across `threads` cores (compose chunk
    /// summaries in parallel, carry serially, replay in parallel).
    Chunked { threads: usize },
    /// Lane-chained multi-dimensional layout (slots × time): B lanes are
    /// distributed over `threads` pool workers and each lane is scanned
    /// sequentially, its carry chained through time in one pass.  For
    /// B >= threads this is the work-optimal shape (no cross-thread
    /// carry traffic), and each lane is bit-exact against `Sequential`.
    /// On a single sequence the chain degenerates to one sequential
    /// lane.
    Chained { threads: usize },
    /// Pick a strategy from (T, B) at run time; never reaches the
    /// implementations (resolved by [`ScanPlan::resolve`]).
    Auto,
}

/// Below this sequence length the chunked scan's thread launch overhead
/// beats its parallel win, so `Auto` stays sequential.
const AUTO_SEQUENTIAL_MAX_T: usize = 2048;

/// A scan execution plan: strategy + batch dimension, over the shared
/// time-major layout.  Builder idiom:
///
/// ```ignore
/// let plan = ScanPlan::new()
///     .with_strategy(Strategy::Chunked { threads: 8 })
///     .with_batch(4);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScanPlan {
    strategy: Strategy,
    batch: usize,
}

impl Default for ScanPlan {
    fn default() -> Self {
        ScanPlan { strategy: Strategy::Auto, batch: 1 }
    }
}

impl ScanPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Shorthand: a sequential plan.
    pub fn sequential() -> Self {
        Self::new().with_strategy(Strategy::Sequential)
    }

    /// Shorthand: a Blelloch tree-scan plan.
    pub fn blelloch() -> Self {
        Self::new().with_strategy(Strategy::Blelloch)
    }

    /// Shorthand: a chunked multi-threaded plan.
    pub fn chunked(threads: usize) -> Self {
        Self::new().with_strategy(Strategy::Chunked { threads })
    }

    /// Shorthand: a lane-chained (slots × time) plan.
    pub fn chained(threads: usize) -> Self {
        Self::new().with_strategy(Strategy::Chained { threads })
    }

    /// Shorthand: let the plan pick per sequence length.
    pub fn auto() -> Self {
        Self::new()
    }

    pub fn with_strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Declare the batch dimension B (rows handed to [`prefix_batch`]).
    pub fn with_batch(mut self, batch: usize) -> Self {
        assert!(batch >= 1, "batch dimension must be >= 1");
        self.batch = batch;
        self
    }

    pub fn strategy(&self) -> Strategy {
        self.strategy
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Resolve `Auto` for a sequence of length `t_len` (and sanitise
    /// thread counts).  Never returns [`Strategy::Auto`].
    pub fn resolve(&self, t_len: usize) -> Strategy {
        match self.strategy {
            Strategy::Auto => {
                if self.batch > 1 {
                    // batched work parallelises across rows, each row
                    // sequential — the lane-chained layout (see
                    // prefix_batch / NativeLm::prefill_ragged).
                    Strategy::Chained {
                        threads: self
                            .batch
                            .min(crate::util::pool::default_threads()),
                    }
                } else if t_len <= AUTO_SEQUENTIAL_MAX_T {
                    // short sequences aren't worth the thread launch.
                    Strategy::Sequential
                } else {
                    Strategy::Chunked {
                        threads: crate::util::pool::default_threads(),
                    }
                }
            }
            Strategy::Chunked { threads } => {
                Strategy::Chunked { threads: threads.max(1) }
            }
            Strategy::Chained { threads } => {
                Strategy::Chained { threads: threads.max(1) }
            }
            s => s,
        }
    }

    /// Resolve for a multi-lane round: `lanes` ragged sequences, longest
    /// `max_t`, scanned together (the serving engine's fused prefill).
    /// `Auto` picks by (B, T, cores): two or more lanes go lane-chained
    /// across the pool; a single lane falls back to [`Self::resolve`]'s
    /// time-axis choice.  Never returns [`Strategy::Auto`].
    pub fn resolve_lanes(&self, lanes: usize, max_t: usize) -> Strategy {
        match self.strategy {
            Strategy::Auto if lanes > 1 => Strategy::Chained {
                threads: lanes
                    .min(crate::util::pool::default_threads()),
            },
            _ => self.resolve(max_t),
        }
    }
}

// --------------------------------------------------------------- trait ---

/// A Bayesian/linear filter family: one associative-scan primitive viewed
/// as a full-sequence `prefix()` (train time) or an incremental `step()`
/// (decode time), with an explicit carryable belief state tying the two
/// together.
///
/// Laws (pinned by `rust/tests/conformance_api.rs`):
/// - **Strategy conformance:** for any plan, `prefix()` agrees with the
///   sequential strategy within 1e-5 (relative), provided the precision
///   trajectory stays strictly inside the `[LAM_MIN, LAM_MAX]` guard
///   rails.  The clamp is a numerical safety net, not part of the
///   associative algebra: when it binds mid-sequence (degenerate
///   parameters — e.g. `pbar = 0` with unbounded evidence), the
///   reparameterised strategies can deviate from the clamped sequential
///   recursion, as in the L1 kernels.
/// - **Carry-split equivalence:** splitting `inputs` at any point, running
///   `prefix()` on the head, and resuming on the tail from the returned
///   belief reproduces the full scan; on the sequential strategy this is
///   exact (bit-for-bit), and chaining `step()` over every t is likewise
///   exact.
pub trait Filter {
    /// Learned parameters (per-channel priors included).
    type Params;
    /// One sequence of inputs, time-major.
    type Inputs;
    /// The carryable posterior state at a single time step.
    type Belief: Clone;
    /// Full-sequence outputs (per-step trajectories / readouts).
    type Output;

    /// The prior belief (state before any observation).
    fn init(params: &Self::Params) -> Self::Belief;

    /// Number of time steps in `inputs`.
    fn len(inputs: &Self::Inputs) -> usize;

    /// True when `inputs` holds no time steps.
    fn is_empty(inputs: &Self::Inputs) -> bool {
        Self::len(inputs) == 0
    }

    /// Time-slice `[lo, hi)` of `inputs` (carry-split execution).
    fn slice(inputs: &Self::Inputs, lo: usize, hi: usize) -> Self::Inputs;

    /// Full-sequence scan from `belief` under `plan`; returns the per-step
    /// outputs and the posterior belief after the final step.
    fn prefix(params: &Self::Params, inputs: &Self::Inputs,
              belief: &Self::Belief, plan: &ScanPlan)
              -> (Self::Output, Self::Belief);

    /// One incremental update: advance `belief` through step `t` of
    /// `inputs` in place, returning that step's readout row.
    fn step(params: &Self::Params, inputs: &Self::Inputs, t: usize,
            belief: &mut Self::Belief) -> Vec<f32>;
}

// ------------------------------------------------------- batched entry ---

/// Batched `(B, T, …)` prefix scan: `rows[i]` scanned from `beliefs[i]`,
/// all under one plan.  When the plan's strategy carries a thread count
/// (or resolves to one), rows are distributed across that many workers
/// and each row runs sequentially — for B ≥ threads this is the
/// work-optimal layout (no cross-thread carry traffic at all); otherwise
/// rows run in submission order with the per-row strategy.
pub fn prefix_batch<F: Filter>(params: &F::Params, rows: &[F::Inputs],
                               beliefs: &[F::Belief], plan: &ScanPlan)
                               -> Vec<(F::Output, F::Belief)>
where
    F::Params: Sync,
    F::Inputs: Sync,
    F::Belief: Send + Sync,
    F::Output: Send,
{
    assert_eq!(rows.len(), beliefs.len(),
               "prefix_batch: {} rows vs {} beliefs", rows.len(),
               beliefs.len());
    assert!(plan.batch() == 1 || plan.batch() == rows.len(),
            "prefix_batch: plan declares B={} but got {} rows",
            plan.batch(), rows.len());
    let b = rows.len();
    if b == 0 {
        return Vec::new();
    }
    let max_t = rows.iter().map(|r| F::len(r)).max().unwrap_or(0);
    let workers = match plan.resolve_lanes(b, max_t) {
        Strategy::Chunked { threads } | Strategy::Chained { threads } => {
            threads.min(b)
        }
        _ => 1,
    };
    if b == 1 || workers <= 1 {
        return rows
            .iter()
            .zip(beliefs)
            .map(|(row, bel)| F::prefix(params, row, bel, plan))
            .collect();
    }
    // Parallelise across rows on the shared persistent pool; per-row
    // work stays sequential so the machine is not oversubscribed
    // (B-parallelism replaces T-parallelism).
    let row_plan = ScanPlan::sequential().with_batch(plan.batch());
    let mut out: Vec<Option<(F::Output, F::Belief)>> = Vec::new();
    out.resize_with(b, || None);
    let chunk = b.div_ceil(workers);
    crate::util::thread_pool::ThreadPool::global().scope(|scope| {
        let mut rest = &mut out[..];
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            scope.spawn(move || {
                for (off, slot) in head.iter_mut().enumerate() {
                    let r = base + off;
                    *slot = Some(F::prefix(params, &rows[r], &beliefs[r],
                                           &row_plan));
                }
            });
            base += take;
        }
    });
    out.into_iter()
        .map(|slot| slot.expect("every row scanned"))
        .collect()
}

// ---------------------------------------------------------- KLA filter ---

/// The posterior belief of the KLA information filter: per-channel
/// precision `lam` and information mean `eta` over the (N, D) state grid —
/// the same carry the decode artifact threads through serving
/// (`crate::serve::state_cache`).
#[derive(Clone, Debug, PartialEq)]
pub struct KlaBelief {
    pub lam: Vec<f32>,
    pub eta: Vec<f32>,
}

impl KlaBelief {
    /// The learned prior belief of `params`.
    pub fn prior(params: &FilterParams) -> Self {
        KlaBelief { lam: params.lam0.clone(), eta: params.eta0.clone() }
    }

    pub fn from_parts(lam: Vec<f32>, eta: Vec<f32>) -> Self {
        assert_eq!(lam.len(), eta.len(), "lam/eta length mismatch");
        KlaBelief { lam, eta }
    }

    /// Number of state channels (N*D).
    pub fn state(&self) -> usize {
        self.lam.len()
    }

    /// Mean posterior variance (1/lam) — the serving-side uncertainty
    /// signal (paper §7: epistemic uncertainty applications).
    pub fn mean_variance(&self) -> f32 {
        mean_variance(&self.lam)
    }
}

/// Mean posterior variance (1/lam, floored at 1e-9) over a borrowed
/// precision slice — THE uncertainty formula, shared by [`KlaBelief`],
/// the serving belief cache, and the native variance trace so the three
/// can never drift apart.
pub fn mean_variance(lam: &[f32]) -> f32 {
    if lam.is_empty() {
        return 0.0;
    }
    let acc: f64 = lam.iter().map(|&l| 1.0 / l.max(1e-9) as f64).sum();
    (acc / lam.len() as f64) as f32
}

/// The KLA information filter (Theorem 1 / `kla::scan`) as a [`Filter`].
pub struct KlaFilter;

impl Filter for KlaFilter {
    type Params = FilterParams;
    type Inputs = FilterInputs;
    type Belief = KlaBelief;
    type Output = FilterOutputs;

    fn init(params: &FilterParams) -> KlaBelief {
        KlaBelief::prior(params)
    }

    fn len(inputs: &FilterInputs) -> usize {
        inputs.t
    }

    fn slice(inputs: &FilterInputs, lo: usize, hi: usize) -> FilterInputs {
        inputs.slice(lo, hi)
    }

    fn prefix(params: &FilterParams, inputs: &FilterInputs,
              belief: &KlaBelief, plan: &ScanPlan)
              -> (FilterOutputs, KlaBelief) {
        let out = match plan.resolve(inputs.t) {
            // a single sequence is one lane of the chain: sequential,
            // bit-exact (lane-parallelism lives in prefix_batch /
            // NativeLm::prefill_ragged)
            Strategy::Sequential | Strategy::Chained { .. } => {
                scan::filter_sequential_from(
                    params, inputs, &belief.lam, &belief.eta)
            }
            Strategy::Blelloch => scan::filter_blelloch_from(
                params, inputs, &belief.lam, &belief.eta),
            Strategy::Chunked { threads } => scan::filter_chunked_from(
                params, inputs, threads, &belief.lam, &belief.eta),
            Strategy::Auto => unreachable!("resolve() never returns Auto"),
        };
        let next = if inputs.t == 0 {
            belief.clone()
        } else {
            let s = params.state();
            KlaBelief {
                lam: out.lam[(inputs.t - 1) * s..].to_vec(),
                eta: out.eta[(inputs.t - 1) * s..].to_vec(),
            }
        };
        (out, next)
    }

    fn step(params: &FilterParams, inputs: &FilterInputs, t: usize,
            belief: &mut KlaBelief) -> Vec<f32> {
        scan::step_once(params, inputs, t, &mut belief.lam,
                        &mut belief.eta)
    }
}

// ---------------------------------------------------------- GLA filter ---

/// Parameters of the gated linear (GLA/Mamba-style) baseline: the state
/// width and the prior state.  The gates and drives arrive as inputs.
#[derive(Clone, Debug)]
pub struct GlaParams {
    pub s: usize,
    pub h0: Vec<f32>,
}

impl GlaParams {
    pub fn zeros(s: usize) -> Self {
        GlaParams { s, h0: vec![0.0; s] }
    }
}

/// One sequence of gated-linear inputs: forget gates f (T, S) and drives
/// b (T, S), time-major.
#[derive(Clone, Debug)]
pub struct GlaInputs {
    pub t: usize,
    pub f: Vec<f32>,
    pub b: Vec<f32>,
}

impl GlaInputs {
    pub fn slice(&self, lo: usize, hi: usize) -> GlaInputs {
        assert!(lo <= hi && hi <= self.t);
        if self.t == 0 {
            return GlaInputs { t: 0, f: Vec::new(), b: Vec::new() };
        }
        let s = self.f.len() / self.t;
        GlaInputs {
            t: hi - lo,
            f: self.f[lo * s..hi * s].to_vec(),
            b: self.b[lo * s..hi * s].to_vec(),
        }
    }
}

/// The gated-linear hidden state h (S values).
#[derive(Clone, Debug, PartialEq)]
pub struct GlaBelief {
    pub h: Vec<f32>,
}

/// The GLA baseline recurrence h_t = f_t ⊙ h_{t-1} + b_t (`baselines`) as
/// a [`Filter`] — the affine half of the KLA scan at identical state size
/// and layout, which is what makes the Fig. 4 comparison apples-to-apples.
pub struct GlaFilter;

impl Filter for GlaFilter {
    type Params = GlaParams;
    type Inputs = GlaInputs;
    type Belief = GlaBelief;
    /// The full hidden-state trajectory, (T, S) time-major.
    type Output = Vec<f32>;

    fn init(params: &GlaParams) -> GlaBelief {
        GlaBelief { h: params.h0.clone() }
    }

    fn len(inputs: &GlaInputs) -> usize {
        inputs.t
    }

    fn slice(inputs: &GlaInputs, lo: usize, hi: usize) -> GlaInputs {
        inputs.slice(lo, hi)
    }

    fn prefix(params: &GlaParams, inputs: &GlaInputs, belief: &GlaBelief,
              plan: &ScanPlan) -> (Vec<f32>, GlaBelief) {
        let (t, s) = (inputs.t, params.s);
        let out = match plan.resolve(t) {
            Strategy::Sequential | Strategy::Chained { .. } => {
                linear_scan_sequential(
                    t, s, &inputs.f, &inputs.b, &belief.h)
            }
            Strategy::Blelloch => linear_scan_blelloch(
                t, s, &inputs.f, &inputs.b, &belief.h),
            Strategy::Chunked { threads } => linear_scan_chunked(
                t, s, &inputs.f, &inputs.b, &belief.h, threads),
            Strategy::Auto => unreachable!("resolve() never returns Auto"),
        };
        let next = if t == 0 {
            belief.clone()
        } else {
            GlaBelief { h: out[(t - 1) * s..].to_vec() }
        };
        (out, next)
    }

    fn step(params: &GlaParams, inputs: &GlaInputs, t: usize,
            belief: &mut GlaBelief) -> Vec<f32> {
        let s = params.s;
        debug_assert!(t < inputs.t);
        for i in 0..s {
            belief.h[i] =
                inputs.f[t * s + i] * belief.h[i] + inputs.b[t * s + i];
        }
        belief.h.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kla::scan::{random_inputs, random_params};
    use crate::util::Pcg64;

    #[test]
    fn plan_builder_round_trips() {
        let plan = ScanPlan::new()
            .with_strategy(Strategy::Chunked { threads: 8 })
            .with_batch(4);
        assert_eq!(plan.strategy(), Strategy::Chunked { threads: 8 });
        assert_eq!(plan.batch(), 4);
        assert_eq!(ScanPlan::sequential().strategy(), Strategy::Sequential);
        assert_eq!(ScanPlan::blelloch().strategy(), Strategy::Blelloch);
        assert_eq!(ScanPlan::chunked(3).strategy(),
                   Strategy::Chunked { threads: 3 });
        assert_eq!(ScanPlan::auto().strategy(), Strategy::Auto);
    }

    #[test]
    fn auto_resolves_by_length_and_batch() {
        assert_eq!(ScanPlan::auto().resolve(64), Strategy::Sequential);
        match ScanPlan::auto().resolve(1 << 16) {
            Strategy::Chunked { threads } => assert!(threads >= 1),
            other => panic!("expected chunked, got {other:?}"),
        }
        // batched plans go lane-chained: rows distributed across the
        // pool, each row sequential
        match ScanPlan::auto().with_batch(8).resolve(1 << 16) {
            Strategy::Chained { threads } => {
                assert!(threads >= 1 && threads <= 8)
            }
            other => panic!("expected chained, got {other:?}"),
        }
        // explicit strategies resolve to themselves
        assert_eq!(ScanPlan::blelloch().resolve(10), Strategy::Blelloch);
        assert_eq!(ScanPlan::chunked(0).resolve(10),
                   Strategy::Chunked { threads: 1 });
        assert_eq!(ScanPlan::chained(0).resolve(10),
                   Strategy::Chained { threads: 1 });
    }

    #[test]
    fn resolve_lanes_picks_by_lane_count() {
        // multi-lane Auto goes lane-chained regardless of T
        match ScanPlan::auto().resolve_lanes(4, 8) {
            Strategy::Chained { threads } => {
                assert!(threads >= 1 && threads <= 4)
            }
            other => panic!("expected chained, got {other:?}"),
        }
        // one lane falls back to the time-axis choice
        assert_eq!(ScanPlan::auto().resolve_lanes(1, 64),
                   Strategy::Sequential);
        match ScanPlan::auto().resolve_lanes(1, 1 << 16) {
            Strategy::Chunked { threads } => assert!(threads >= 1),
            other => panic!("expected chunked, got {other:?}"),
        }
        // explicit strategies pass through (sanitised)
        assert_eq!(ScanPlan::blelloch().resolve_lanes(4, 8),
                   Strategy::Blelloch);
        assert_eq!(ScanPlan::chained(0).resolve_lanes(4, 8),
                   Strategy::Chained { threads: 1 });
    }

    #[test]
    fn chained_prefix_is_bit_exact_vs_sequential() {
        let mut rng = Pcg64::seeded(14);
        let (t, n, d) = (23, 2, 3);
        let p = random_params(&mut rng, n, d);
        let inp = random_inputs(&mut rng, t, n, d);
        let prior = KlaFilter::init(&p);
        let (seq, seq_b) =
            KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        let (cha, cha_b) =
            KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::chained(4));
        assert_eq!(seq.y, cha.y);
        assert_eq!(seq.lam, cha.lam);
        assert_eq!(seq.eta, cha.eta);
        assert_eq!(seq_b, cha_b);
    }

    #[test]
    fn kla_prefix_carries_final_belief() {
        let mut rng = Pcg64::seeded(11);
        let (t, n, d) = (19, 2, 3);
        let s = n * d;
        let p = random_params(&mut rng, n, d);
        let inp = random_inputs(&mut rng, t, n, d);
        let prior = KlaFilter::init(&p);
        let (out, belief) =
            KlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        assert_eq!(&belief.lam[..], &out.lam[(t - 1) * s..]);
        assert_eq!(&belief.eta[..], &out.eta[(t - 1) * s..]);
        // empty scan: belief unchanged
        let empty = KlaFilter::slice(&inp, 0, 0);
        let (out0, belief0) =
            KlaFilter::prefix(&p, &empty, &belief, &ScanPlan::sequential());
        assert!(out0.lam.is_empty());
        assert_eq!(belief0, belief);
    }

    #[test]
    fn prefix_batch_matches_per_row() {
        let mut rng = Pcg64::seeded(12);
        let (n, d) = (2, 4);
        let p = random_params(&mut rng, n, d);
        let rows: Vec<_> = (0..5)
            .map(|i| random_inputs(&mut rng, 10 + i, n, d))
            .collect();
        let beliefs: Vec<_> =
            (0..5).map(|_| KlaFilter::init(&p)).collect();
        let solo: Vec<_> = rows
            .iter()
            .zip(&beliefs)
            .map(|(r, b)| {
                KlaFilter::prefix(&p, r, b, &ScanPlan::sequential())
            })
            .collect();
        let batched = prefix_batch::<KlaFilter>(
            &p, &rows, &beliefs, &ScanPlan::chunked(3));
        assert_eq!(batched.len(), solo.len());
        for ((a, ab), (b, bb)) in batched.iter().zip(&solo) {
            // rows run sequentially inside the batch ⇒ exact agreement
            assert_eq!(a, b);
            assert_eq!(ab, bb);
        }
    }

    #[test]
    fn gla_step_chain_matches_prefix_exactly() {
        let mut rng = Pcg64::seeded(13);
        let (t, s) = (29, 7);
        let p = GlaParams::zeros(s);
        let inp = GlaInputs {
            t,
            f: (0..t * s).map(|_| rng.range_f32(0.3, 0.95)).collect(),
            b: (0..t * s).map(|_| rng.normal_f32()).collect(),
        };
        let prior = GlaFilter::init(&p);
        let (out, last) =
            GlaFilter::prefix(&p, &inp, &prior, &ScanPlan::sequential());
        let mut belief = GlaFilter::init(&p);
        for ti in 0..t {
            let h = GlaFilter::step(&p, &inp, ti, &mut belief);
            assert_eq!(&out[ti * s..(ti + 1) * s], &h[..], "t={ti}");
        }
        assert_eq!(belief, last);
    }

    #[test]
    fn belief_mean_variance_tracks_precision() {
        let lo = KlaBelief::from_parts(vec![1.0; 4], vec![0.0; 4]);
        let hi = KlaBelief::from_parts(vec![100.0; 4], vec![0.0; 4]);
        assert!(hi.mean_variance() < lo.mean_variance());
        assert!((lo.mean_variance() - 1.0).abs() < 1e-6);
        let empty = KlaBelief::from_parts(vec![], vec![]);
        assert_eq!(empty.mean_variance(), 0.0);
    }
}

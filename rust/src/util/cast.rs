//! Checked/clamping integer conversions for token and vocab values.
//!
//! Token ids are `i32` end to end (`IntTensor`, the wire protocol's
//! `bad-prompt-token` validation, the samplers), while row indices and
//! vocab sizes are `usize`.  A bare `as i32` on that boundary silently
//! truncates — the bug class PR 4 fixed on the protocol side and the
//! `determinism` lint pass now bans in the serve modules (DESIGN.md
//! §S18).  These helpers make the conversion explicit: values in range
//! convert exactly; values out of range (impossible for real vocabs,
//! which are far below `i32::MAX`) debug-assert and saturate instead
//! of wrapping.

/// A sampled row index as a token id.  Exact for `i < 2^31`; saturates
/// (with a debug assertion) beyond, rather than wrapping negative.
pub fn token_from_index(i: usize) -> i32 {
    debug_assert!(
        i32::try_from(i).is_ok(),
        "token index {i} exceeds i32 range"
    );
    i32::try_from(i).unwrap_or(i32::MAX)
}

/// The largest valid token id of a `vocab`-sized model, never negative
/// (an empty vocab yields 0 so clamping stays well-defined).
pub fn vocab_max_token(vocab: usize) -> i32 {
    debug_assert!(
        i32::try_from(vocab).is_ok(),
        "vocab size {vocab} exceeds i32 range"
    );
    let v = i32::try_from(vocab).unwrap_or(i32::MAX);
    (v - 1).max(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_range_values_convert_exactly() {
        assert_eq!(token_from_index(0), 0);
        assert_eq!(token_from_index(65_535), 65_535);
        assert_eq!(vocab_max_token(50_000), 49_999);
    }

    #[test]
    fn degenerate_vocabs_clamp_to_zero() {
        assert_eq!(vocab_max_token(0), 0);
        assert_eq!(vocab_max_token(1), 0);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn release_mode_saturates_instead_of_wrapping() {
        assert_eq!(token_from_index(usize::MAX), i32::MAX);
        assert_eq!(vocab_max_token(usize::MAX), i32::MAX - 1);
    }
}

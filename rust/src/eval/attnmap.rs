//! Equivalent Kalman attention matrix (paper Appendix E.4/E.5, Fig. 10-13).
//!
//! Unrolling the information-mean recurrence eta_t = f_t eta_{t-1} +
//! k_t lam_v_t v_t gives a lower-triangular matrix
//!     W[t, s] = (prod_{u=s+1..t} f_u) * k_s * lam_v_s      (s <= t)
//! and the full per-channel sequence map is
//!     M_seq = diag(q ⊙ lam^{-1}) W.
//! Entries are computed from the native filter's gate path, so this is a
//! pure L3 diagnostic needing no extra artifact.

use crate::api::{Filter, KlaFilter, ScanPlan};
use crate::kla::{FilterInputs, FilterParams};

/// Per-channel attention matrix for channel (n, d): T x T lower-triangular.
pub fn kalman_attention(p: &FilterParams, inp: &FilterInputs, n_idx: usize,
                        d_idx: usize) -> Vec<f32> {
    let (n, d, t_len) = (p.n, p.d, inp.t);
    assert!(n_idx < n && d_idx < d);
    let idx = n_idx * d + d_idx;
    // forward pass for lam (needed for gates and the final scaling)
    let (out, _) = KlaFilter::prefix(p, inp, &KlaFilter::init(p),
                                     &ScanPlan::sequential());
    let s = n * d;
    // gates f_t = rho_t * abar
    let mut gates = vec![0.0f32; t_len];
    for t in 0..t_len {
        let lam_prev = if t == 0 {
            p.lam0[idx]
        } else {
            out.lam[(t - 1) * s + idx]
        };
        let abar = p.abar[idx];
        gates[t] = abar / (abar * abar + p.pbar[idx] * lam_prev);
    }
    let mut w = vec![0.0f32; t_len * t_len];
    for t in 0..t_len {
        // W[t, s] = (prod_{u=s+1..t} f_u) * k_s * lam_v_s; scaled by
        // q_t / lam_t to give M_seq.
        let scale = inp.q[t * n + n_idx] / out.lam[t * s + idx];
        let mut gate_prod = 1.0f32;
        for src in (0..=t).rev() {
            if src < t {
                gate_prod *= gates[src + 1];
            }
            let contrib =
                inp.k[src * n + n_idx] * inp.lam_v[src * d + d_idx];
            w[t * t_len + src] = scale * gate_prod * contrib;
        }
    }
    w
}

/// ASCII render (rows = targets, cols = sources) for quick inspection.
pub fn render_ascii(w: &[f32], t: usize, width: usize) -> String {
    let step = (t / width.max(1)).max(1);
    let maxabs = w.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-12);
    let chars = [' ', '.', ':', '+', '*', '#'];
    let mut out = String::new();
    for r in (0..t).step_by(step) {
        for c in (0..t).step_by(step) {
            let x = (w[r * t + c].abs() / maxabs * 5.0).round() as usize;
            out.push(chars[x.min(5)]);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kla::{random_inputs, random_params};
    use crate::util::Pcg64;

    #[test]
    fn attention_matrix_reproduces_filter_output() {
        // y[t, d] over channel (n0, d0) contributions: sum_s W[t,s] v[s,d0]
        // must equal q_t * mu_t for a single-slot model (N=1).
        let mut rng = Pcg64::seeded(0);
        let (t, n, d) = (12, 1, 1);
        let p = random_params(&mut rng, n, d);
        let mut inp = random_inputs(&mut rng, t, n, d);
        // make eta0 zero so the matrix form has no init term
        let mut p = p;
        p.eta0.iter_mut().for_each(|x| *x = 0.0);
        let (out, _) = KlaFilter::prefix(&p, &inp, &KlaFilter::init(&p),
                                         &ScanPlan::sequential());
        let w = kalman_attention(&p, &inp, 0, 0);
        for ti in 0..t {
            let mut acc = 0.0f32;
            for s in 0..=ti {
                acc += w[ti * t + s] * inp.v[s];
            }
            let expect = out.y[ti];
            assert!(
                (acc - expect).abs() < 1e-4 * (1.0 + expect.abs()),
                "t={ti}: {acc} vs {expect}"
            );
        }
        // strictly causal: upper triangle zero
        for r in 0..t {
            for c in r + 1..t {
                assert_eq!(w[r * t + c], 0.0);
            }
        }
        inp.t = t; // silence unused-mut lint paths
    }

    #[test]
    fn ascii_render_shapes() {
        let w = vec![0.5f32; 16 * 16];
        let s = render_ascii(&w, 16, 8);
        assert_eq!(s.lines().count(), 8);
    }
}

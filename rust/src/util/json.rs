//! Minimal JSON parser/serialiser (offline stand-in for serde_json).
//!
//! Parses the artifact meta.json files and writes metrics/benchmark
//! reports. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (not needed for our ASCII metadata).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----------------------------------------------------------- access --
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 {
            bail!("expected non-negative number, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ------------------------------------------------------ construction --
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // -------------------------------------------------------- serialise --
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                for _ in 0..n {
                    o.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------- parsing ---

pub fn parse(src: &str) -> Result<Json> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of unescaped bytes (UTF-8 passes through)
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(
                        &self.bytes[start..self.pos],
                    )?);
                }
                None => bail!("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": -2.5e3}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.req("c").unwrap().as_f64().unwrap(), -2500.0);
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_meta_style() {
        let src = r#"{"name":"x","inputs":[{"name":"tokens","shape":[4,8],
                      "dtype":"int32"}],"batch":4}"#;
        let v = parse(src).unwrap();
        let inp = &v.req("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.req("shape").unwrap().usize_vec().unwrap(), vec![4, 8]);
        assert_eq!(inp.req("dtype").unwrap().as_str().unwrap(), "int32");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote \" slash \\ nl \n tab \t".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.5])),
            ("name", Json::str("hi")),
        ]);
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }
}

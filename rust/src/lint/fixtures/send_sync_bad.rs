//! Known-bad fixture for the `send-sync-audit` pass: a leaked raw
//! pointer and the SAFETY-comment shapes the pass rejects.  Never
//! compiled — `include_str!`-ed by the pass's unit tests only.

// A public struct exposing a raw pointer: the SAFETY contract leaks
// past the audited tree.
pub struct LeakyPtr(*mut f32);

struct Opaque {
    data: *const u8,
}

unsafe impl Send for Opaque {}

// SAFETY: this is fine.
unsafe impl Sync for Opaque {}

//! MAD synthetic LM suite (paper Appendix F.1, Poli et al. 2024), scaled
//! to the shared vocab-64 / T-128 artifact family (DESIGN.md §3).
//!
//! Shared vocabulary layout (all six tasks use the same artifacts):
//!   0  PAD    1  SEP ([c] / query marker)   2  BLANK   3  INSERT
//!   KEYS   = 8..24    (16 keys)
//!   VALUES = 24..40   (16 values)
//!   CONTENT= 8..40    (copy/compression content)
//!   NOISE  = 40..56   (separate noise vocabulary)
//!
//! Each task probes a distinct capability (paper Table 7): associative
//! recall, span compositionality, noise robustness, ordered copying,
//! aggregation/bottlenecking, parametric memory.

use super::{Sample, TaskGen};
use crate::util::Pcg64;

pub const PAD: i32 = 0;
pub const SEP: i32 = 1;
pub const BLANK: i32 = 2;
pub const INSERT: i32 = 3;
pub const KEY_BASE: i32 = 8;
pub const N_KEYS: i32 = 16;
pub const VAL_BASE: i32 = 24;
pub const N_VALS: i32 = 16;
pub const CONTENT_BASE: i32 = 8;
pub const N_CONTENT: i32 = 32;
pub const NOISE_BASE: i32 = 40;
pub const N_NOISE: i32 = 16;

fn rand_key(rng: &mut Pcg64) -> i32 {
    KEY_BASE + rng.below(N_KEYS as u64) as i32
}

fn rand_val(rng: &mut Pcg64) -> i32 {
    VAL_BASE + rng.below(N_VALS as u64) as i32
}

fn rand_content(rng: &mut Pcg64) -> i32 {
    CONTENT_BASE + rng.below(N_CONTENT as u64) as i32
}

fn rand_noise(rng: &mut Pcg64) -> i32 {
    NOISE_BASE + rng.below(N_NOISE as u64) as i32
}

// ------------------------------------------------------- Context recall ---

/// In-context recall (+ optional noise): key-value pairs with fresh random
/// bindings per sequence; every re-occurrence of a bound key is supervised
/// with its value.  `noise_frac > 0` interleaves tokens from the separate
/// noise vocabulary (Noisy Recall, paper: 20%).
pub struct ContextRecall {
    pub noise_frac: f64,
    name: &'static str,
}

impl ContextRecall {
    pub fn standard() -> Self {
        ContextRecall { noise_frac: 0.0, name: "context_recall" }
    }

    pub fn noisy() -> Self {
        ContextRecall { noise_frac: 0.2, name: "noisy_recall" }
    }
}

impl TaskGen for ContextRecall {
    fn name(&self) -> &str {
        self.name
    }

    fn sample(&self, rng: &mut Pcg64, t: usize) -> Sample {
        let mut s = Sample::with_capacity(t);
        // fresh random binding for each key this sequence
        let mut binding = [0i32; 16];
        for slot in binding.iter_mut() {
            *slot = rand_val(rng);
        }
        let mut seen = [false; 16];
        while s.tokens.len() + 2 <= t {
            if self.noise_frac > 0.0 && rng.bool(self.noise_frac) {
                s.push(rand_noise(rng), PAD, false);
                continue;
            }
            let k = rand_key(rng);
            let ki = (k - KEY_BASE) as usize;
            let v = binding[ki];
            // key token (never supervised), then value token (supervised
            // iff this key was already bound earlier in the sequence).
            s.push(k, PAD, false);
            s.push(v, PAD, false);
            // supervise the *prediction* of v at the key position:
            // targets are next-token style, so position of k predicts v.
            let idx = s.tokens.len() - 2;
            s.targets[idx] = v;
            s.mask[idx] = if seen[ki] { 1.0 } else { 0.0 };
            seen[ki] = true;
        }
        s.fit(t);
        s
    }
}

// --------------------------------------------------------- Fuzzy recall ---

/// Fuzzy in-context recall: keys and values are multi-token spans
/// (1-2 tokens here; paper uses up to 3), testing compositional keys.
#[derive(Default)]
pub struct FuzzyRecall;

impl TaskGen for FuzzyRecall {
    fn name(&self) -> &str {
        "fuzzy_recall"
    }

    fn sample(&self, rng: &mut Pcg64, t: usize) -> Sample {
        let mut s = Sample::with_capacity(t);
        // bindings: key span (2 tokens) -> value span (2 tokens)
        const N_PAIRS: usize = 8;
        let mut keys = Vec::with_capacity(N_PAIRS);
        let mut vals = Vec::with_capacity(N_PAIRS);
        for _ in 0..N_PAIRS {
            keys.push([rand_key(rng), rand_key(rng)]);
            vals.push([rand_val(rng), rand_val(rng)]);
        }
        let mut seen = [false; N_PAIRS];
        while s.tokens.len() + 4 <= t {
            let pi = rng.usize_below(N_PAIRS);
            let (k, v) = (keys[pi], vals[pi]);
            s.push(k[0], PAD, false);
            s.push(k[1], v[0], seen[pi]); // end of key span predicts v[0]
            s.push(v[0], v[1], seen[pi]); // then v[1]
            s.push(v[1], PAD, false);
            seen[pi] = true;
        }
        s.fit(t);
        s
    }
}

// ------------------------------------------------------- Selective copy ---

/// Selective copying: content tokens interspersed with BLANKs; after a SEP,
/// INSERT markers must be filled with the content tokens in order.
pub struct SelectiveCopy {
    pub n_copy: usize,
}

impl Default for SelectiveCopy {
    fn default() -> Self {
        SelectiveCopy { n_copy: 16 }
    }
}

impl TaskGen for SelectiveCopy {
    fn name(&self) -> &str {
        "selective_copy"
    }

    fn sample(&self, rng: &mut Pcg64, t: usize) -> Sample {
        let n_copy = self.n_copy.min((t - 2) / 2);
        let body = t - 2 * n_copy - 1; // content+blank region
        let mut s = Sample::with_capacity(t);
        // place n_copy content tokens at random distinct positions
        let mut pos = rng.choose_distinct(body, n_copy);
        pos.sort_unstable();
        let content: Vec<i32> =
            (0..n_copy).map(|_| rand_content(rng)).collect();
        let mut ci = 0;
        for p in 0..body {
            if ci < n_copy && pos[ci] == p {
                s.push(content[ci], PAD, false);
                ci += 1;
            } else {
                s.push(BLANK, PAD, false);
            }
        }
        s.push(SEP, content[0], true); // SEP predicts first copied token
        for i in 0..n_copy {
            // INSERT positions: each predicts the NEXT content token
            let target = if i + 1 < n_copy { content[i + 1] } else { PAD };
            let supervised = i + 1 < n_copy;
            s.push(content[i], PAD, false);
            s.push(INSERT, target, supervised);
        }
        s.fit(t);
        s
    }
}

// ---------------------------------------------------------- Compression ---

/// Compression: random content, a SEP ([c]) boundary, then the model must
/// reproduce the full prefix from its recurrent state alone (autoregressive
/// analogue of MAD's MLP-decoded compression probe; the fixed-size state is
/// the bottleneck either way).
pub struct Compression {
    pub content_len: usize,
}

impl Default for Compression {
    fn default() -> Self {
        Compression { content_len: 24 }
    }
}

impl TaskGen for Compression {
    fn name(&self) -> &str {
        "compression"
    }

    fn sample(&self, rng: &mut Pcg64, t: usize) -> Sample {
        let m = self.content_len.min((t - 1) / 2);
        let content: Vec<i32> = (0..m).map(|_| rand_content(rng)).collect();
        let mut s = Sample::with_capacity(t);
        for &c in &content {
            s.push(c, PAD, false);
        }
        s.push(SEP, content[0], true);
        for i in 0..m - 1 {
            s.push(content[i], content[i + 1], true);
        }
        s.fit(t);
        s
    }
}

// --------------------------------------------------------- Memorization ---

/// Memorization: a FIXED key->value dictionary shared across all sequences
/// (parametric memory: values never appear in the input; they must be
/// learned into the weights).
pub struct Memorization {
    dict: Vec<i32>,
}

impl Default for Memorization {
    fn default() -> Self {
        // fixed dictionary drawn from a fixed seed — same for train & eval
        let mut rng = Pcg64::seeded(0xD1C7);
        let dict = (0..N_KEYS).map(|_| rand_val(&mut rng)).collect();
        Memorization { dict }
    }
}

impl TaskGen for Memorization {
    fn name(&self) -> &str {
        "memorization"
    }

    fn sample(&self, rng: &mut Pcg64, t: usize) -> Sample {
        let mut s = Sample::with_capacity(t);
        while s.tokens.len() + 2 <= t {
            let k = rand_key(rng);
            let v = self.dict[(k - KEY_BASE) as usize];
            // key predicts its dictionary value at the INSERT position
            s.push(k, v, true);
            s.push(INSERT, PAD, false);
        }
        s.fit(t);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TaskGen;

    fn gen_one(task: &dyn TaskGen, seed: u64, t: usize) -> Sample {
        let mut rng = Pcg64::seeded(seed);
        task.sample(&mut rng, t)
    }

    #[test]
    fn context_recall_supervises_repeats_consistently() {
        let task = ContextRecall::standard();
        let s = gen_one(&task, 1, 128);
        // every supervised position: target equals the value bound to that
        // key at its first occurrence
        let mut first: std::collections::HashMap<i32, i32> = Default::default();
        for i in 0..s.tokens.len() - 1 {
            let tok = s.tokens[i];
            if (KEY_BASE..KEY_BASE + N_KEYS).contains(&tok) {
                let val = s.tokens[i + 1];
                if let Some(&v0) = first.get(&tok) {
                    if s.mask[i] > 0.0 {
                        assert_eq!(s.targets[i], v0, "binding changed");
                    }
                } else {
                    first.insert(tok, val);
                    assert_eq!(s.mask[i], 0.0, "first occurrence supervised");
                }
            }
        }
        assert!(s.mask.iter().sum::<f32>() > 0.0);
    }

    #[test]
    fn noisy_recall_contains_noise() {
        let task = ContextRecall::noisy();
        let s = gen_one(&task, 2, 128);
        let noise = s
            .tokens
            .iter()
            .filter(|&&x| (NOISE_BASE..NOISE_BASE + N_NOISE).contains(&x))
            .count();
        assert!(noise > 5, "only {noise} noise tokens");
        // noise positions are never supervised
        for (i, &tok) in s.tokens.iter().enumerate() {
            if (NOISE_BASE..NOISE_BASE + N_NOISE).contains(&tok) {
                assert_eq!(s.mask[i], 0.0);
            }
        }
    }

    #[test]
    fn selective_copy_targets_in_order() {
        let task = SelectiveCopy::default();
        let s = gen_one(&task, 3, 128);
        // content = non-blank non-special tokens before SEP
        let sep = s.tokens.iter().position(|&x| x == SEP).unwrap();
        let content: Vec<i32> = s.tokens[..sep]
            .iter()
            .copied()
            .filter(|&x| x >= CONTENT_BASE && x < CONTENT_BASE + N_CONTENT)
            .collect();
        assert_eq!(content.len(), 16);
        // supervised targets spell the content in order
        let sup: Vec<i32> = (0..s.tokens.len())
            .filter(|&i| s.mask[i] > 0.0)
            .map(|i| s.targets[i])
            .collect();
        assert_eq!(sup, content);
    }

    #[test]
    fn compression_reproduces_prefix() {
        let task = Compression::default();
        let s = gen_one(&task, 4, 128);
        let m = task.content_len;
        let content: Vec<i32> = s.tokens[..m].to_vec();
        let sup: Vec<i32> = (0..s.tokens.len())
            .filter(|&i| s.mask[i] > 0.0)
            .map(|i| s.targets[i])
            .collect();
        assert_eq!(sup, content);
    }

    #[test]
    fn memorization_dict_is_fixed() {
        let t1 = Memorization::default();
        let t2 = Memorization::default();
        let s1 = gen_one(&t1, 5, 64);
        let s2 = gen_one(&t2, 6, 64);
        // same key always maps to same value across instances & sequences
        let mut map: std::collections::HashMap<i32, i32> = Default::default();
        for s in [&s1, &s2] {
            for i in 0..s.tokens.len() {
                if s.mask[i] > 0.0 {
                    let (k, v) = (s.tokens[i], s.targets[i]);
                    assert_eq!(*map.entry(k).or_insert(v), v);
                }
            }
        }
        // values never appear as input tokens
        for s in [&s1, &s2] {
            for &tok in &s.tokens {
                assert!(!(VAL_BASE..VAL_BASE + N_VALS).contains(&tok));
            }
        }
    }

    #[test]
    fn fuzzy_recall_spans_consistent() {
        let task = FuzzyRecall;
        let s = gen_one(&task, 7, 128);
        assert!(s.mask.iter().sum::<f32>() > 0.0);
        // every supervised target is a value token
        for i in 0..s.tokens.len() {
            if s.mask[i] > 0.0 {
                assert!(
                    (VAL_BASE..VAL_BASE + N_VALS).contains(&s.targets[i]),
                    "target {} not a value", s.targets[i]
                );
            }
        }
    }

    #[test]
    fn all_tokens_in_vocab() {
        for (task, seed) in [
            (&ContextRecall::standard() as &dyn TaskGen, 10u64),
            (&ContextRecall::noisy(), 11),
            (&FuzzyRecall, 12),
            (&SelectiveCopy::default(), 13),
            (&Compression::default(), 14),
            (&Memorization::default(), 15),
        ] {
            let s = gen_one(task, seed, 128);
            for &x in s.tokens.iter().chain(s.targets.iter()) {
                assert!((0..64).contains(&x), "{}: token {x}", task.name());
            }
        }
    }
}

// Waived fixture for the `determinism` pass: the same clock / spawn /
// cast shapes as determinism_bad.rs, each suppressed by a
// waiver comment.  Never compiled —
// only `include_str!`-ed by rust/src/lint/determinism.rs tests.

fn drifty(vocab: usize) -> i32 {
    // lint: allow(determinism, fixture: debug meter, result unused)
    let t0 = std::time::Instant::now();
    // lint: allow(determinism, fixture: joined before data is dropped)
    std::thread::spawn(move || t0.elapsed());
    vocab as i32 // lint: allow(determinism, fixture: vocab < 2^31)
}

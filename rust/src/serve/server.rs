//! TCP front-end: newline-delimited JSON over a plain socket (std::net —
//! no tokio offline).  One reader thread per connection; all generation
//! funnels into the single engine thread (continuous batching).
//!
//! Protocol (one JSON object per line).  Generation request — everything
//! after `prompt` is optional and overrides the server default from
//! [`ServeConfig`]:
//!   -> {"prompt": [1,2,3],
//!       "max_new_tokens": 8,       0 = prefill only (empty tokens;
//!                                  uncertainty still reported); values
//!                                  above the server's max_new_limit are
//!                                  REJECTED, never clamped
//!       "temperature": 0.8,        0 = greedy argmax (the default)
//!       "top_k": 40,               0 = off, 1 = greedy
//!       "top_p": 0.95,             >= 1 = off
//!       "seed": 7,                 explicit sampling seed, an integer
//!                                  in [0, 2^53] (see below)
//!       "stop_tokens": [0, 31],    sampling one of these ends the
//!                                  request (stop token included in the
//!                                  output; prompt occurrences ignored)
//!       "eos": 0,                  shorthand: one extra stop token
//!       "uncertainty_temp": 0.5}   c in tau_eff = tau*(1 + c*u), u =
//!                                  slot mean posterior variance
//!   <- {"tokens": [...], "total_ms": 12.3, "queue_ms": 0.1,
//!       "uncertainty": 0.42}
//!
//! Commands:
//!   -> {"cmd": "ping"}     <- {"ok": true}
//!   -> {"cmd": "stats"}    <- {"requests": N, "steps": N,
//!       "tokens_out": N, "prefill_tokens": N}   (live counters)
//!   -> {"cmd": "shutdown"} <- {"ok": true}    (stops the listener —
//!       the handler pokes the accept loop itself, no external
//!       connection needed for the server to quiesce)
//!
//! Errors.  Every malformed or rejected line gets a structured reply and
//! the connection stays usable:
//!   <- {"err": {"code": "<kebab-case-code>", "msg": "<human detail>"}}
//! Codes: bad-json, unknown-cmd, bad-cmd, missing-prompt, bad-prompt,
//! bad-prompt-token (a prompt entry is not an integer in i32 range —
//! previously truncated silently), bad-max-new, max-new-too-large (over
//! the server's max_new_limit — previously clamped silently),
//! bad-temperature, bad-top-k, bad-top-p, bad-seed, bad-stop-tokens,
//! bad-eos, bad-uncertainty-temp, unavailable (engine shut down).
//!
//! Determinism contract: sampling draws are counter-based
//! (`serve::sampling`) — token `t` of a request depends only on its RNG
//! key and `t`.  With an explicit `seed`, the key is
//! `(engine seed, seed)`, so the same request reproduces token-for-token
//! across server restarts, batch widths, and slot assignments (for a
//! fixed prefill-chunk setting; across different chunk sizes logits
//! agree only to the 1e-5 scan tolerance — see `serve::sampling`);
//! without one it falls back to `(engine seed, request id)`, stable for
//! a fixed arrival order.  Greedy requests (temperature 0) are
//! deterministic with no seed at all.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Instant;

use std::path::PathBuf;

use anyhow::{Context, Result};

use super::engine::{run_engine_opts, EngineOptions, EngineRequest,
                    EngineStats, LiveStats};
use super::sampling::SamplerConfig;
use crate::config::ServeConfig;
use crate::runtime::backend::NativeBackend;
use crate::runtime::{Runtime, Value};
use crate::util::Json;

/// Server-side request defaults + limits, shared by the router threads.
#[derive(Clone, Debug)]
struct ProtocolDefaults {
    max_new: usize,
    max_new_limit: usize,
    sampler: SamplerConfig,
}

impl ProtocolDefaults {
    fn from_serve(cfg: &ServeConfig) -> Self {
        ProtocolDefaults {
            max_new: cfg.max_new_tokens,
            max_new_limit: cfg.max_new_limit,
            sampler: SamplerConfig::from_serve(cfg),
        }
    }
}

/// The documented structured error reply:
/// `{"err": {"code": ..., "msg": ...}}`.
fn err_reply(code: &str, msg: &str) -> Json {
    Json::obj(vec![(
        "err",
        Json::obj(vec![("code", Json::str(code)), ("msg", Json::str(msg))]),
    )])
}

pub struct ServerHandle {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<Result<EngineStats>>>,
    listener_join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and collect engine stats.
    pub fn stop(mut self) -> Result<EngineStats> {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(&self.addr);
        if let Some(j) = self.listener_join.take() {
            let _ = j.join();
        }
        match self.join.take() {
            Some(j) => j.join().expect("engine thread panicked"),
            None => Ok(EngineStats::default()),
        }
    }
}

/// Which decode backend the engine thread should build.
///
/// PJRT handles are not Send, so the XLA variant carries plain data
/// (artifact dir + base + params) and the engine thread builds its own
/// Runtime and DecodeSession; the native variant is plain data already
/// and moves straight into the engine thread.
pub enum EngineSpec {
    /// XLA/PJRT over a `{base}_decode` artifact (needs `make artifacts`).
    Xla {
        artifacts_dir: PathBuf,
        artifact: String,
        params: Vec<Value>,
    },
    /// Pure-Rust KLA model — no artifacts required.
    Native(NativeBackend),
}

impl EngineSpec {
    fn kind(&self) -> &'static str {
        match self {
            EngineSpec::Xla { .. } => "xla",
            EngineSpec::Native(_) => "native",
        }
    }
}

/// Start the server on the XLA artifact backend; returns once the socket
/// is listening.  (Kept as the historical entry point — thin wrapper
/// over [`serve_with`].)
pub fn serve(artifacts_dir: PathBuf, artifact_base: String,
             params: Vec<Value>, cfg: &ServeConfig) -> Result<ServerHandle> {
    serve_with(EngineSpec::Xla {
        artifacts_dir,
        artifact: artifact_base,
        params,
    }, cfg)
}

/// Start the server on the pure-Rust native backend — the offline path:
/// no artifacts, no PJRT, same engine/batcher/cache stack.
pub fn serve_native(backend: NativeBackend, cfg: &ServeConfig)
                    -> Result<ServerHandle> {
    serve_with(EngineSpec::Native(backend), cfg)
}

/// Start the server over any [`EngineSpec`]; returns once the socket is
/// listening.
pub fn serve_with(spec: EngineSpec, cfg: &ServeConfig)
                  -> Result<ServerHandle> {
    // boot-time validation of the server-wide sampling defaults (per-
    // request fields are validated protocol-side with {"err": ...})
    SamplerConfig::from_serve(cfg)
        .validate()
        .context("serve config sampling defaults")?;
    // a default max_new above the limit would reject every request that
    // OMITS max_new_tokens with an error about a value the client never
    // sent — refuse to boot instead
    if cfg.max_new_tokens > cfg.max_new_limit {
        anyhow::bail!(
            "serve config: max_new_tokens default {} exceeds \
             max_new_limit {}",
            cfg.max_new_tokens, cfg.max_new_limit);
    }
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?.to_string();
    let (tx, rx) = channel::<EngineRequest>();
    let opts = EngineOptions::from_serve(cfg);
    let shutdown = Arc::new(AtomicBool::new(false));
    let live = Arc::new(LiveStats::default());
    let shutdown_engine = shutdown.clone();
    let live_engine = live.clone();
    let backend_kind = spec.kind();
    let engine_join = std::thread::spawn(move || match spec {
        EngineSpec::Xla { artifacts_dir, artifact, params } => {
            let rt = Runtime::new(&artifacts_dir)?;
            let session = crate::runtime::DecodeSession::new(
                &rt, &artifact, params)?;
            run_engine_opts(&session, rx, &opts, shutdown_engine,
                            &live_engine)
        }
        EngineSpec::Native(backend) => {
            run_engine_opts(&backend, rx, &opts, shutdown_engine,
                            &live_engine)
        }
    });

    let shutdown2 = shutdown.clone();
    let defaults = Arc::new(ProtocolDefaults::from_serve(cfg));
    let self_addr = addr.clone();
    let listener_join = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if shutdown2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let shutdown3 = shutdown2.clone();
            let live3 = live.clone();
            let addr3 = self_addr.clone();
            let defaults3 = defaults.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx, defaults3, shutdown3,
                                    live3, addr3);
            });
        }
        // tx (and all clones in finished handlers) dropping closes the
        // engine's queue, letting run_engine drain and exit.
    });

    crate::log_info!("serving on {addr} ({backend_kind} backend)");
    Ok(ServerHandle {
        addr,
        shutdown,
        join: Some(engine_join),
        listener_join: Some(listener_join),
    })
}

fn handle_conn(stream: TcpStream, tx: Sender<EngineRequest>,
               defaults: Arc<ProtocolDefaults>, shutdown: Arc<AtomicBool>,
               live: Arc<LiveStats>, self_addr: String)
               -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(&line, &tx, &defaults, &shutdown, &live,
                                &self_addr);
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    crate::log_debug!("connection {peer:?} closed");
    Ok(())
}

/// One protocol line in, one reply out.  Every failure mode is a
/// structured `{"err": {"code", "msg"}}` reply (documented atop this
/// file) — the connection always stays usable.
fn handle_line(line: &str, tx: &Sender<EngineRequest>,
               defaults: &ProtocolDefaults, shutdown: &AtomicBool,
               live: &LiveStats, self_addr: &str) -> Json {
    let req = match crate::util::json::parse(line) {
        Ok(v) => v,
        Err(e) => return err_reply("bad-json", &e.to_string()),
    };
    if let Some(cmd) = req.get("cmd") {
        let Ok(cmd) = cmd.as_str() else {
            return err_reply("bad-cmd", "cmd must be a string");
        };
        match cmd {
            "shutdown" => {
                shutdown.store(true, Ordering::SeqCst);
                // poke our own accept() so the listener observes the
                // flag and exits — without this, a client-issued
                // shutdown left the listener thread blocked until some
                // EXTERNAL connection happened to arrive
                let _ = TcpStream::connect(self_addr);
                return Json::obj(vec![("ok", Json::Bool(true))]);
            }
            "ping" => return Json::obj(vec![("ok", Json::Bool(true))]),
            "stats" => {
                let n = |v: usize| Json::num(v as f64);
                return Json::obj(vec![
                    ("requests", n(live.requests.load(Ordering::Relaxed))),
                    ("steps", n(live.steps.load(Ordering::Relaxed))),
                    ("tokens_out",
                     n(live.tokens_out.load(Ordering::Relaxed))),
                    ("prefill_tokens",
                     n(live.prefill_tokens.load(Ordering::Relaxed))),
                ]);
            }
            other => {
                return err_reply("unknown-cmd",
                                 &format!("unknown cmd {other:?}"));
            }
        }
    }
    let (prompt, max_new, sampler) = match parse_request(&req, defaults) {
        Ok(parts) => parts,
        Err(reply) => return reply,
    };
    let (rtx, rrx) = channel();
    if tx
        .send(EngineRequest {
            prompt,
            max_new,
            sampler,
            submitted: Instant::now(),
            resp: rtx,
        })
        .is_err()
    {
        return err_reply("unavailable", "engine is shut down");
    }
    match rrx.recv() {
        Ok(resp) => Json::obj(vec![
            ("tokens",
             Json::Arr(resp.tokens.iter().map(|&t| Json::num(t as f64))
                 .collect())),
            ("queue_ms", Json::num(resp.queue_ms)),
            ("total_ms", Json::num(resp.total_ms)),
            ("uncertainty", Json::num(resp.uncertainty as f64)),
        ]),
        Err(_) => err_reply("unavailable", "engine dropped the request"),
    }
}

/// A JSON number that is an exact integer within [lo, hi].
fn int_in_range(x: &Json, lo: f64, hi: f64) -> Option<f64> {
    let n = x.as_f64().ok()?;
    if n.fract() == 0.0 && n >= lo && n <= hi {
        Some(n)
    } else {
        None
    }
}

/// Parse one i32 token id, rejecting non-integers and out-of-range
/// values (the old `x.as_i64()? as i32` silently truncated both).
fn token_id(x: &Json) -> Option<i32> {
    int_in_range(x, i32::MIN as f64, i32::MAX as f64).map(|n| n as i32)
}

/// Validate a generation request against the server defaults; any
/// violation is the structured error reply to send back.
#[allow(clippy::result_large_err)]
fn parse_request(req: &Json, d: &ProtocolDefaults)
                 -> std::result::Result<(Vec<i32>, usize, SamplerConfig),
                                        Json> {
    let fail = |code: &str, msg: String| Err(err_reply(code, &msg));
    let Some(prompt_val) = req.get("prompt") else {
        return fail("missing-prompt", "request has no \"prompt\"".into());
    };
    let Ok(arr) = prompt_val.as_arr() else {
        return fail("bad-prompt", "\"prompt\" must be an array".into());
    };
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, x) in arr.iter().enumerate() {
        match token_id(x) {
            Some(t) => prompt.push(t),
            None => {
                return fail("bad-prompt-token", format!(
                    "prompt[{i}] = {} is not a token id (want an \
                     integer in [{}, {}])",
                    x.to_string(), i32::MIN, i32::MAX));
            }
        }
    }
    let max_new = match req.get("max_new_tokens") {
        None => d.max_new,
        Some(x) => match int_in_range(x, 0.0, usize::MAX as f64) {
            Some(n) => n as usize,
            None => {
                return fail("bad-max-new", format!(
                    "max_new_tokens = {} must be a non-negative integer",
                    x.to_string()));
            }
        },
    };
    if max_new > d.max_new_limit {
        return fail("max-new-too-large", format!(
            "max_new_tokens {max_new} exceeds the server limit {} (the \
             server never clamps silently — ask for less)",
            d.max_new_limit));
    }
    let mut s = d.sampler.clone();
    if let Some(x) = req.get("temperature") {
        // finiteness is checked AFTER the f32 cast: an f64 like 1e39 is
        // finite but saturates to f32::INFINITY, which would silently
        // turn the softmax uniform
        match x.as_f64() {
            Ok(t) if (t as f32).is_finite() && t >= 0.0 => {
                s.temperature = t as f32;
            }
            _ => {
                return fail("bad-temperature", format!(
                    "temperature = {} must be a finite number >= 0",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("top_k") {
        match int_in_range(x, 0.0, usize::MAX as f64) {
            Some(k) => s.top_k = k as usize,
            None => {
                return fail("bad-top-k", format!(
                    "top_k = {} must be a non-negative integer",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("top_p") {
        match x.as_f64() {
            Ok(p) if p.is_finite() && p > 0.0 => s.top_p = p.min(1.0) as f32,
            _ => {
                return fail("bad-top-p", format!(
                    "top_p = {} must be a finite number in (0, 1] \
                     (>= 1 disables)",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("uncertainty_temp") {
        match x.as_f64() {
            Ok(c) if (c as f32).is_finite() && c >= 0.0 => {
                s.uncertainty_temp = c as f32;
            }
            _ => {
                return fail("bad-uncertainty-temp", format!(
                    "uncertainty_temp = {} must be a finite number >= 0",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("seed") {
        // bounded by 2^53, the largest integer range f64 (and therefore
        // JSON) represents exactly — beyond it distinct seeds would
        // silently collapse to the same key, the very class of silent
        // coercion this protocol rejects elsewhere
        match int_in_range(x, 0.0, (1u64 << 53) as f64) {
            Some(n) => s.seed = Some(n as u64),
            None => {
                return fail("bad-seed", format!(
                    "seed = {} must be an integer in [0, 2^53] (JSON \
                     numbers are exact only up to 2^53)",
                    x.to_string()));
            }
        }
    }
    if let Some(x) = req.get("stop_tokens") {
        let Ok(arr) = x.as_arr() else {
            return fail("bad-stop-tokens",
                        "\"stop_tokens\" must be an array".into());
        };
        let mut stops = Vec::with_capacity(arr.len());
        for (i, t) in arr.iter().enumerate() {
            match token_id(t) {
                Some(id) => stops.push(id),
                None => {
                    return fail("bad-stop-tokens", format!(
                        "stop_tokens[{i}] = {} is not a token id",
                        t.to_string()));
                }
            }
        }
        s.stop_tokens = stops; // REPLACES the server default list
    }
    if let Some(x) = req.get("eos") {
        match token_id(x) {
            Some(id) => s.stop_tokens.push(id),
            None => {
                return fail("bad-eos", format!(
                    "eos = {} is not a token id", x.to_string()));
            }
        }
    }
    Ok((prompt, max_new, s))
}

/// Optional per-request sampling & termination fields for
/// [`Client::request_opts`].  `None` fields are omitted from the wire
/// request, so the server default applies.
#[derive(Clone, Debug, Default)]
pub struct RequestOpts {
    pub temperature: Option<f64>,
    pub top_k: Option<usize>,
    pub top_p: Option<f64>,
    /// Sampling seed; the protocol carries it as a JSON number, so the
    /// server only accepts values up to 2^53 (exact-integer f64 range).
    pub seed: Option<u64>,
    pub stop_tokens: Option<Vec<i32>>,
    pub eos: Option<i32>,
    pub uncertainty_temp: Option<f64>,
}

/// Minimal blocking client (used by tests, the serve_demo example and the
/// throughput bench).
pub struct Client {
    stream: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { stream: BufReader::new(stream) })
    }

    pub fn request(&mut self, prompt: &[i32], max_new: usize)
                   -> Result<Json> {
        self.request_opts(prompt, max_new, &RequestOpts::default())
    }

    /// A generation request with explicit sampling & termination fields
    /// (the protocol line documented atop this file).
    pub fn request_opts(&mut self, prompt: &[i32], max_new: usize,
                        opts: &RequestOpts) -> Result<Json> {
        let mut pairs = vec![
            ("prompt",
             Json::Arr(prompt.iter().map(|&t| Json::num(t as f64))
                 .collect())),
            ("max_new_tokens", Json::num(max_new as f64)),
        ];
        if let Some(t) = opts.temperature {
            pairs.push(("temperature", Json::num(t)));
        }
        if let Some(k) = opts.top_k {
            pairs.push(("top_k", Json::num(k as f64)));
        }
        if let Some(p) = opts.top_p {
            pairs.push(("top_p", Json::num(p)));
        }
        if let Some(s) = opts.seed {
            pairs.push(("seed", Json::num(s as f64)));
        }
        if let Some(stops) = &opts.stop_tokens {
            pairs.push(("stop_tokens",
                        Json::Arr(stops.iter()
                            .map(|&t| Json::num(t as f64))
                            .collect())));
        }
        if let Some(e) = opts.eos {
            pairs.push(("eos", Json::num(e as f64)));
        }
        if let Some(c) = opts.uncertainty_temp {
            pairs.push(("uncertainty_temp", Json::num(c)));
        }
        let req = Json::obj(pairs);
        self.send_line(&req.to_string())
    }

    pub fn ping(&mut self) -> Result<Json> {
        self.send_line(r#"{"cmd":"ping"}"#)
    }

    /// Live engine counters: requests, steps, tokens_out,
    /// prefill_tokens — answered mid-serve, not only after shutdown.
    pub fn stats(&mut self) -> Result<Json> {
        self.send_line(r#"{"cmd":"stats"}"#)
    }

    pub fn shutdown(&mut self) -> Result<Json> {
        self.send_line(r#"{"cmd":"shutdown"}"#)
    }

    fn send_line(&mut self, line: &str) -> Result<Json> {
        let stream = self.stream.get_mut();
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
        let mut reply = String::new();
        self.stream.read_line(&mut reply)?;
        crate::util::json::parse(reply.trim())
    }
}

// Waived variant of counter_engine_bad.rs: the same drift, suppressed
// by waiver comments.  Never compiled —
// only `include_str!`-ed by counter_sync.rs tests.

pub struct EngineStats {
    pub requests: usize,
    pub steps: usize,
    // lint: allow(counter-sync, fixture: counter lands in the next PR)
    pub dropped_frames: usize,
    pub step_ms: Vec<f64>,
}

pub struct LiveStats {
    pub requests: AtomicUsize,
    pub steps: AtomicUsize,
    // lint: allow(counter-sync, fixture: mirror lands in the next PR)
    pub ghost: AtomicUsize,
}

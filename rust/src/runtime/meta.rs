//! Artifact metadata (the L2 -> L3 ABI), parsed from `*.meta.json`.

use anyhow::{bail, Result};

use crate::util::Json;

#[derive(Clone, Debug, PartialEq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }
}

/// One input or output array.
#[derive(Clone, Debug)]
pub struct ArgMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    /// "params" | "opt_m" | "opt_v" | "" (data inputs)
    pub group: String,
}

impl ArgMeta {
    fn from_json(j: &Json) -> Result<ArgMeta> {
        Ok(ArgMeta {
            name: j.req("name")?.as_str()?.to_string(),
            shape: j.req("shape")?.usize_vec()?,
            dtype: DType::parse(j.req("dtype")?.as_str()?)?,
            group: j
                .get("group")
                .and_then(|g| g.as_str().ok())
                .unwrap_or("")
                .to_string(),
        })
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Model hyperparameters as recorded by the AOT bridge.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub kind: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_state: usize,
    pub conv_kernel: usize,
    pub process_noise: bool,
    pub ou_exact: bool,
    pub impl_name: String,
    pub mc_samples: usize,
}

impl ModelMeta {
    fn from_json(j: &Json) -> Result<ModelMeta> {
        Ok(ModelMeta {
            kind: j.req("kind")?.as_str()?.to_string(),
            vocab: j.req("vocab")?.as_usize()?,
            d_model: j.req("d_model")?.as_usize()?,
            n_layers: j.req("n_layers")?.as_usize()?,
            n_state: j.req("n_state")?.as_usize()?,
            conv_kernel: j.req("conv_kernel")?.as_usize()?,
            process_noise: j.req("process_noise")?.as_bool()?,
            ou_exact: j.req("ou_exact")?.as_bool()?,
            impl_name: j.req("impl")?.as_str()?.to_string(),
            mc_samples: j.req("mc_samples")?.as_usize()?,
        })
    }
}

/// Full artifact metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub family: String,
    pub tag: String,
    pub role: String,
    pub model: ModelMeta,
    pub batch: usize,
    pub seq: usize,
    pub inputs: Vec<ArgMeta>,
    pub outputs: Vec<ArgMeta>,
    /// total_steps from the OptConfig (drives the LR schedule).
    pub total_steps: usize,
}

impl ArtifactMeta {
    pub fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let inputs = j
            .req("inputs")?
            .as_arr()?
            .iter()
            .map(ArgMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let outputs = j
            .req("outputs")?
            .as_arr()?
            .iter()
            .map(ArgMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactMeta {
            name: j.req("name")?.as_str()?.to_string(),
            family: j.req("family")?.as_str()?.to_string(),
            tag: j.req("tag")?.as_str()?.to_string(),
            role: j.req("role")?.as_str()?.to_string(),
            model: ModelMeta::from_json(j.req("model")?)?,
            batch: j.req("batch")?.as_usize()?,
            seq: j.req("seq")?.as_usize()?,
            inputs,
            outputs,
            total_steps: j
                .req("opt")?
                .req("total_steps")?
                .as_usize()?,
        })
    }

    /// Input arrays in group "params" (same order as init outputs).
    pub fn param_inputs(&self) -> Vec<&ArgMeta> {
        self.inputs.iter().filter(|a| a.group == "params").collect()
    }

    pub fn n_params(&self) -> usize {
        self.inputs.iter().filter(|a| a.group == "params").count()
    }

    pub fn total_param_elems(&self) -> usize {
        self.inputs
            .iter()
            .filter(|a| a.group == "params")
            .map(|a| a.elem_count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    const META: &str = r#"{
      "name": "mad_kla_train", "family": "mad", "tag": "kla",
      "role": "train",
      "model": {"kind": "kla", "vocab": 64, "d_model": 64, "n_layers": 1,
                "n_state": 8, "n_heads": 4, "conv_kernel": 4,
                "process_noise": true, "ou_exact": true, "impl": "scan",
                "mc_samples": 0},
      "opt": {"lr": 0.002, "total_steps": 400},
      "batch": 32, "seq": 128,
      "inputs": [
        {"name": "embed", "shape": [64, 64], "dtype": "float32",
         "group": "params"},
        {"name": "embed", "shape": [64, 64], "dtype": "float32",
         "group": "opt_m"},
        {"name": "embed", "shape": [64, 64], "dtype": "float32",
         "group": "opt_v"},
        {"name": "step", "shape": [], "dtype": "float32"},
        {"name": "tokens", "shape": [32, 128], "dtype": "int32"}
      ],
      "outputs": [{"name": "loss", "shape": [], "dtype": "float32"}]
    }"#;

    #[test]
    fn parses_meta() {
        let m = ArtifactMeta::from_json(&parse(META).unwrap()).unwrap();
        assert_eq!(m.role, "train");
        assert_eq!(m.model.d_model, 64);
        assert_eq!(m.batch, 32);
        assert_eq!(m.n_params(), 1);
        assert_eq!(m.total_param_elems(), 64 * 64);
        assert_eq!(m.inputs[4].dtype, DType::I32);
        assert_eq!(m.total_steps, 400);
    }

    #[test]
    fn rejects_bad_dtype() {
        assert!(DType::parse("float64").is_err());
        assert!(DType::parse("float32").is_ok());
    }
}

//! Shared experiment helpers for the per-figure benches.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::TaskGen;
use crate::runtime::Runtime;
use crate::train::TrainOutcome;

/// Steps per training-based bench point; override with KLA_BENCH_STEPS.
pub fn bench_steps(default: usize) -> usize {
    std::env::var("KLA_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Seeds per point; override with KLA_BENCH_SEEDS (paper: 5, ours: 1-3).
pub fn bench_seeds(default: usize) -> usize {
    std::env::var("KLA_BENCH_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Train `base` on `task`, return the outcome (accuracy etc.).
pub fn train_point(rt: &Runtime, base: &str, task: &dyn TaskGen,
                   steps: usize, seed: u64) -> Result<TrainOutcome> {
    let cfg = TrainConfig {
        artifact: base.to_string(),
        steps,
        seed,
        eval_every: 0,
        eval_batches: 6,
        log_every: steps.max(1),
        checkpoint_dir: None,
        target_accuracy: None,
    };
    crate::train::run(rt, &cfg, task)
}

/// Mean accuracy over `seeds` runs.
pub fn train_mean_acc(rt: &Runtime, base: &str, task: &dyn TaskGen,
                      steps: usize, seeds: usize) -> Result<(f64, f64)> {
    let mut accs = Vec::new();
    let mut step_ms = 0.0;
    for seed in 0..seeds.max(1) as u64 {
        let out = train_point(rt, base, task, steps, seed)?;
        accs.push(out.accuracy());
        step_ms = out.mean_step_ms();
    }
    let mean = accs.iter().sum::<f64>() / accs.len() as f64;
    Ok((mean, step_ms))
}

/// Skip helper: true if the artifact exists (full-manifest sweeps).
pub fn have(rt: &Runtime, base: &str) -> bool {
    rt.meta(&format!("{base}_train")).is_ok()
        || rt.meta(&format!("{base}_logits")).is_ok()
        || rt.meta(&format!("{base}_decode")).is_ok()
}

//! Pass `determinism`: wall clocks, thread spawns, and narrowing
//! casts only where the architecture says they belong.
//!
//! The repo's determinism contract (counter-based sampling, bit-exact
//! cache-hit ≡ cold-prefill parity, seeded parity across batch
//! widths) survives only if nondeterminism stays quarantined:
//!
//! - **wall clocks** — `Instant::now()` / `SystemTime` are metering
//!   concerns; they are allowed only in the metering modules listed
//!   in [`CLOCK_ALLOW`].  Anywhere else (e.g. a kernel timing itself
//!   to pick a strategy) needs a per-line waiver naming why the clock
//!   cannot leak into results;
//! - **thread spawns** — free-running `std::thread::spawn` threads
//!   belong to `util::thread_pool`, the server's connection
//!   plumbing, and the model checker's controlled threads
//!   ([`SPAWN_ALLOW`]); everything else must use the scoped helpers
//!   (`util::pool`, `std::thread::scope`) so no thread outlives the
//!   data it touches;
//! - **narrowing casts** — bare `as` casts to a narrower integer type
//!   silently truncate token/vocab ids (the PR 4 bug class).  In the
//!   serve modules ([`CAST_SCOPE`]) they are banned outright: use
//!   `i32::try_from(..)` or the clamping helpers in `util::cast`.

use super::{Finding, LintInput, SourceFile};

/// Modules whose *job* is wall-clock metering.
const CLOCK_ALLOW: [(&str, &str); 5] = [
    ("util/timer.rs", "the metering abstraction itself"),
    ("util/logging.rs", "log-line timestamps"),
    ("bench/mod.rs", "benchmark harness wall time"),
    ("serve/engine.rs", "queue/step/prefill meters + batch window"),
    ("serve/server.rs", "request submit stamp for queue metering"),
];

/// Modules allowed to start free-running threads.
const SPAWN_ALLOW: [(&str, &str); 4] = [
    ("util/thread_pool.rs", "the pool owns its workers"),
    ("serve/server.rs", "listener/reader/writer/engine threads"),
    ("mc/thread.rs", "the model checker's controlled threads"),
    ("mc/sched.rs", "model executions own their explored threads"),
];

/// Serve modules where narrowing `as` casts are banned outright.
const CAST_SCOPE: [&str; 4] = [
    "serve/engine.rs",
    "serve/server.rs",
    "serve/batcher.rs",
    "serve/sampling.rs",
];

/// Integer types an `as` cast may narrow token/vocab values into.
const NARROW_INTS: [&str; 6] = ["i8", "i16", "i32", "u8", "u16", "u32"];

pub fn run(input: &LintInput) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &input.files {
        check_file(file, &mut out);
    }
    out
}

fn check_file(file: &SourceFile, out: &mut Vec<Finding>) {
    let clock_ok =
        CLOCK_ALLOW.iter().any(|(p, _)| file.path_ends_with(p));
    let spawn_ok =
        SPAWN_ALLOW.iter().any(|(p, _)| file.path_ends_with(p));
    let cast_scoped =
        CAST_SCOPE.iter().any(|p| file.path_ends_with(p));
    if clock_ok && spawn_ok && !cast_scoped {
        return;
    }

    let code = &file.code;
    for (i, t) in code.iter().enumerate() {
        if file.is_test_line(t.line) {
            continue;
        }
        if !clock_ok {
            let instant_now = t.ident() == Some("Instant")
                && code.get(i + 1).is_some_and(|n| n.is_punct(':'))
                && code.get(i + 2).is_some_and(|n| n.is_punct(':'))
                && code.get(i + 3).and_then(|n| n.ident()) == Some("now");
            if instant_now || t.ident() == Some("SystemTime") {
                out.push(finding(
                    file,
                    t.line,
                    "wall clock outside the metering allowlist \
                     (util::timer / util::logging / bench / the serve \
                     meters); waive with the reason the reading cannot \
                     influence results"
                        .to_string(),
                ));
            }
        }
        if !spawn_ok
            && t.ident() == Some("thread")
            && code.get(i + 1).is_some_and(|n| n.is_punct(':'))
            && code.get(i + 2).is_some_and(|n| n.is_punct(':'))
            && matches!(
                code.get(i + 3).and_then(|n| n.ident()),
                Some("spawn" | "Builder")
            )
        {
            out.push(finding(
                file,
                t.line,
                "free-running thread spawn outside util::thread_pool / \
                 serve::server; use the scoped helpers in util::pool"
                    .to_string(),
            ));
        }
        if cast_scoped && t.ident() == Some("as") {
            if let Some(ty) = code.get(i + 1).and_then(|n| n.ident()) {
                if NARROW_INTS.contains(&ty) {
                    out.push(finding(
                        file,
                        t.line,
                        format!(
                            "narrowing `as {ty}` cast in a serve module \
                             can silently truncate token/vocab ids; use \
                             `{ty}::try_from(..)` or util::cast"
                        ),
                    ));
                }
            }
        }
    }
}

fn finding(file: &SourceFile, line: usize, message: String) -> Finding {
    Finding {
        pass: "determinism",
        file: file.path.clone(),
        line,
        message,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{run as run_all, LintInput, SourceFile};

    fn input(path: &str, src: &str) -> LintInput {
        LintInput {
            files: vec![SourceFile::from_source(path, src)],
            design_md: String::new(),
        }
    }

    #[test]
    fn fixture_fires_on_clock_spawn_and_cast() {
        let src = include_str!("fixtures/determinism_bad.rs");
        // a serve file outside the clock/spawn allowlists, inside the
        // cast scope
        let fs = run(&input("rust/src/serve/batcher.rs", src));
        let msgs: Vec<&str> =
            fs.iter().map(|f| f.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("wall clock")), "{msgs:?}");
        assert!(
            msgs.iter().any(|m| m.contains("thread spawn")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("narrowing `as i32`")),
            "{msgs:?}"
        );
    }

    #[test]
    fn fixture_waivers_suppress_each_category() {
        let src = include_str!("fixtures/determinism_waived.rs");
        let report =
            run_all(&input("rust/src/serve/batcher.rs", src));
        let left: Vec<_> = report
            .findings
            .iter()
            .filter(|f| f.pass == "determinism")
            .collect();
        assert!(left.is_empty(), "waived fixture not clean: {left:?}");
        let s = report
            .summaries
            .iter()
            .find(|s| s.pass == "determinism")
            .unwrap_or_else(|| panic!("no determinism summary"));
        assert!(s.waivers_used >= 3, "waivers used: {}", s.waivers_used);
    }

    #[test]
    fn allowlisted_modules_and_test_code_are_exempt() {
        let clocky = "fn meter() -> Instant { Instant::now() }\n";
        assert!(run(&input("rust/src/util/timer.rs", clocky)).is_empty());
        assert!(run(&input("rust/src/bench/mod.rs", clocky)).is_empty());
        let spawny =
            "fn go() { std::thread::spawn(|| {}); }\n";
        assert!(run(&input("rust/src/util/thread_pool.rs", spawny))
            .is_empty());
        let test_gated = format!(
            "#[cfg(test)]\nmod tests {{\n{clocky}{spawny}}}\n"
        );
        assert!(
            run(&input("rust/src/kla/scan.rs", &test_gated)).is_empty()
        );
    }

    #[test]
    fn widening_and_float_casts_are_fine() {
        let src = "\
fn ok(x: i32, n: usize) -> f64 {\n\
    let a = x as i64;\n\
    let b = n as u64;\n\
    let c = x as f32;\n\
    a as f64 + b as f64 + c as f64\n\
}\n";
        assert!(run(&input("rust/src/serve/engine.rs", src)).is_empty());
    }
}

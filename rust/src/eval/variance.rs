//! Posterior-variance diagnostics (paper Fig. 5b): dump the per-step
//! readout variance of the last KLA block on task sequences and summarise
//! its trend (variance should decay as evidence accumulates, with spikes
//! at copy-relevant tokens).

use anyhow::Result;

use crate::api::{Filter, KlaFilter, ScanPlan};
use crate::data::{Batch, TaskGen};
use crate::kla::{FilterInputs, FilterParams};
use crate::runtime::{Runtime, TrainSession, Value};
use crate::util::Pcg64;

/// Variance trace for one batch: (B, T) row-major.
#[derive(Clone, Debug)]
pub struct VarianceTrace {
    pub b: usize,
    pub t: usize,
    pub values: Vec<f32>,
    pub mask: Vec<f32>,
}

impl VarianceTrace {
    /// Mean variance over early vs late thirds of each sequence.
    pub fn early_late(&self) -> (f64, f64) {
        let third = self.t / 3;
        let (mut e, mut l, mut ne, mut nl) = (0.0, 0.0, 0, 0);
        for bi in 0..self.b {
            for ti in 0..self.t {
                let v = self.values[bi * self.t + ti] as f64;
                if ti < third {
                    e += v;
                    ne += 1;
                } else if ti >= 2 * third {
                    l += v;
                    nl += 1;
                }
            }
        }
        (e / ne.max(1) as f64, l / nl.max(1) as f64)
    }

    /// Mean variance at supervised (copy-relevant) vs background positions.
    pub fn supervised_vs_background(&self) -> (f64, f64) {
        let (mut s, mut g, mut ns, mut ng) = (0.0, 0.0, 0, 0);
        for i in 0..self.values.len() {
            let v = self.values[i] as f64;
            if self.mask[i] > 0.0 {
                s += v;
                ns += 1;
            } else {
                g += v;
                ng += 1;
            }
        }
        (s / ns.max(1) as f64, g / ng.max(1) as f64)
    }

    /// CSV dump (one row per sequence) for plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        for bi in 0..self.b {
            let row: Vec<String> = (0..self.t)
                .map(|ti| format!("{:.6}", self.values[bi * self.t + ti]))
                .collect();
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Native (artifact-free) variance trace through the unified `Filter`
/// API: run the information filter over one sequence and record the mean
/// posterior variance (1/lam over the state grid) at every step — the
/// B=1 analogue of the `{base}_variance` artifact, usable by diagnostics
/// and tests without any XLA build.
pub fn native_trace(p: &FilterParams, inp: &FilterInputs, plan: &ScanPlan)
                    -> VarianceTrace {
    let s = p.state();
    if s == 0 {
        return VarianceTrace {
            b: 1,
            t: inp.t,
            values: vec![0.0; inp.t],
            mask: vec![0.0; inp.t],
        };
    }
    let (out, _) = KlaFilter::prefix(p, inp, &KlaFilter::init(p), plan);
    let values: Vec<f32> = (0..inp.t)
        .map(|t| crate::api::mean_variance(&out.lam[t * s..(t + 1) * s]))
        .collect();
    VarianceTrace { b: 1, t: inp.t, values, mask: vec![0.0; inp.t] }
}

/// Run the `{base}_variance` artifact on a fresh task batch.
pub fn trace(rt: &Runtime, session: &TrainSession, task: &dyn TaskGen,
             seed: u64) -> Result<VarianceTrace> {
    let (b, t) = session.batch_shape();
    let mut rng = Pcg64::seeded(seed);
    let batch: Batch = task.batch(&mut rng, b, t);
    let out = session.run_role(rt, "variance",
                               &[Value::I32(batch.tokens.clone())])?;
    let var = out[0].as_f32()?;
    Ok(VarianceTrace {
        b,
        t,
        values: var.data().to_vec(),
        mask: batch.mask.data().to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kla::scan::random_inputs;

    #[test]
    fn native_trace_variance_decays_with_evidence() {
        // abar = 1, pbar = 0: precision only accumulates, so the mean
        // posterior variance must decay early -> late (paper Fig. 5b).
        let (n, d, t) = (2, 3, 48);
        let p = FilterParams::uniform(n, d, 1.0, 0.0);
        let mut rng = Pcg64::seeded(17);
        let inp = random_inputs(&mut rng, t, n, d);
        let tr = native_trace(&p, &inp, &ScanPlan::sequential());
        assert_eq!(tr.t, t);
        let (early, late) = tr.early_late();
        assert!(late <= early + 1e-9, "variance grew: {early} -> {late}");
        // strategy-independent: chunked plan gives the same trace
        let tr2 = native_trace(&p, &inp, &ScanPlan::chunked(4));
        for (a, b) in tr.values.iter().zip(&tr2.values) {
            assert!((a - b).abs() < 1e-5 * (1.0 + a.abs()));
        }
    }

    #[test]
    fn early_late_split() {
        let tr = VarianceTrace {
            b: 1,
            t: 9,
            values: vec![9.0, 9.0, 9.0, 5.0, 5.0, 5.0, 1.0, 1.0, 1.0],
            mask: vec![0.0; 9],
        };
        let (e, l) = tr.early_late();
        assert!((e - 9.0).abs() < 1e-9);
        assert!((l - 1.0).abs() < 1e-9);
    }

    #[test]
    fn supervised_split() {
        let tr = VarianceTrace {
            b: 1,
            t: 4,
            values: vec![1.0, 2.0, 3.0, 4.0],
            mask: vec![0.0, 1.0, 0.0, 1.0],
        };
        let (s, g) = tr.supervised_vs_background();
        assert!((s - 3.0).abs() < 1e-9);
        assert!((g - 2.0).abs() < 1e-9);
    }
}

//! Pass `atomic-ordering`: every atomic memory ordering is either an
//! allowlisted stats counter or carries a reviewed rationale.
//!
//! The model checker (`rust/src/mc/`) runs the shimmed code under a
//! global total order, so it can never distinguish `Relaxed` from
//! `SeqCst` — ordering bugs are exactly the class that survives it.
//! This pass is the static complement.  A *site* is any
//! `Ordering::<name>` argument in non-test code; its receiver is the
//! last field of the call chain (`live.steps.fetch_add(1, ..)` →
//! `steps`).  Rules:
//!
//! - **`Relaxed` allowlist** — `Relaxed` is only free on fields of
//!   the `LiveStats` struct (monotonic stats counters read by the
//!   `{"cmd":"stats"}` reply; drift there is cosmetic).  Any other
//!   `Relaxed` site is a finding: either upgrade the ordering or
//!   waive it with the invariant that makes relaxation safe.
//! - **rationale** — every non-`Relaxed` site must have an `// ord:`
//!   comment within [`ORD_WINDOW`] lines above it, and the comment
//!   run from that anchor down to the site must name the ordering
//!   actually used (`Acquire`, `Release`, `AcqRel`, `SeqCst`) — so a
//!   site cannot silently change strength under a stale rationale.
//! - **stale-ord audit** — an `// ord:` anchor with no atomic site
//!   within [`ORD_WINDOW`] lines below it is itself a finding; ord
//!   rationales cannot rot after a refactor moves the site away.
//!
//! `// ord:` is plain-comment syntax like the waiver syntax: doc
//! comments (`///`, `//!`) never count, so prose about the mechanism
//! cannot satisfy (or stale-trip) the audit.

use super::{Finding, LintInput, SourceFile};
use crate::lint::counter_sync::struct_fields;
use crate::lint::lexer::Token;
use crate::lint::lock_order::chain_last_ident;

const PASS: &str = "atomic-ordering";

/// How far above a site its `// ord:` rationale may sit, and how far
/// below an anchor its site must exist.
pub const ORD_WINDOW: usize = 10;

const ORDERINGS: [&str; 5] =
    ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// One `Ordering::<name>` argument occurrence.
struct Site {
    line: usize,
    ordering: &'static str,
    receiver: Option<String>,
    in_test: bool,
}

/// One `// ord:` rationale comment.
struct Anchor {
    line: usize,
}

pub fn run(input: &LintInput) -> Vec<Finding> {
    let mut out = Vec::new();

    // The Relaxed allowlist: LiveStats stats-counter field names,
    // wherever the struct is defined in the scanned set.
    let mut allow: Vec<String> = Vec::new();
    for f in &input.files {
        if let Some(fields) = struct_fields(&f.code, "LiveStats") {
            allow.extend(fields.into_iter().map(|fld| fld.name));
        }
    }

    for file in &input.files {
        check_file(file, &allow, &mut out);
    }
    out
}

fn check_file(file: &SourceFile, allow: &[String], out: &mut Vec<Finding>) {
    let sites = collect_sites(file);
    let anchors = collect_anchors(file);

    for s in sites.iter().filter(|s| !s.in_test) {
        let allowlisted = s
            .receiver
            .as_ref()
            .is_some_and(|r| allow.iter().any(|a| a == r));
        if s.ordering == "Relaxed" {
            if !allowlisted {
                out.push(Finding {
                    pass: PASS,
                    file: file.path.clone(),
                    line: s.line,
                    message: format!(
                        "`Ordering::Relaxed` on `{}` which is not a \
                         LiveStats stats counter; relaxed loads/stores \
                         order nothing — upgrade the ordering or waive \
                         with the invariant that makes relaxation safe",
                        s.receiver.as_deref().unwrap_or("<unknown>")
                    ),
                });
            }
            continue;
        }
        // Non-Relaxed: require a reviewed rationale within the window.
        let anchor = anchors
            .iter()
            .filter(|a| a.line <= s.line && s.line - a.line <= ORD_WINDOW)
            .map(|a| a.line)
            .max();
        let Some(anchor_line) = anchor else {
            out.push(Finding {
                pass: PASS,
                file: file.path.clone(),
                line: s.line,
                message: format!(
                    "`Ordering::{}` without an `// ord:` rationale \
                     within {ORD_WINDOW} lines above; state which \
                     accesses this ordering pairs with",
                    s.ordering
                ),
            });
            continue;
        };
        let text = comment_run(file, anchor_line, s.line);
        if !text.contains(s.ordering) {
            out.push(Finding {
                pass: PASS,
                file: file.path.clone(),
                line: s.line,
                message: format!(
                    "the `// ord:` rationale above does not name \
                     `{}` — the site's ordering changed under a stale \
                     rationale, or the rationale never matched; \
                     rewrite it for the ordering actually used",
                    s.ordering
                ),
            });
        }
    }

    // Stale-ord audit: every anchor must still have a site below it.
    // Test and allowlisted sites count — the anchor documents them
    // just as well.
    for a in &anchors {
        let covered = sites
            .iter()
            .any(|s| s.line >= a.line && s.line - a.line <= ORD_WINDOW);
        if !covered {
            out.push(Finding {
                pass: PASS,
                file: file.path.clone(),
                line: a.line,
                message: format!(
                    "stale `// ord:` rationale: no atomic ordering \
                     site within {ORD_WINDOW} lines below it — the \
                     site moved or died; move or remove the comment"
                ),
            });
        }
    }
}

/// Every `Ordering::<name>` occurrence in the code token stream.
fn collect_sites(file: &SourceFile) -> Vec<Site> {
    let code = &file.code;
    let mut out = Vec::new();
    for i in 3..code.len() {
        let Some(name) = code[i].ident() else { continue };
        let Some(ordering) = ORDERINGS.iter().find(|o| **o == name)
        else {
            continue;
        };
        if !(code[i - 1].is_punct(':')
            && code[i - 2].is_punct(':')
            && code[i - 3].ident() == Some("Ordering"))
        {
            continue;
        }
        out.push(Site {
            line: code[i].line,
            ordering,
            receiver: call_receiver(code, i),
            in_test: file.is_test_line(code[i].line),
        });
    }
    out
}

/// The receiver field of the atomic call this `Ordering::` argument
/// belongs to: walk back to the unmatched `(` opening the call, then
/// name the chain before its method ident.
fn call_receiver(code: &[Token], ord_idx: usize) -> Option<String> {
    let mut depth = 0usize;
    let mut k = ord_idx.checked_sub(3)?; // the `Ordering` ident
    loop {
        k = k.checked_sub(1)?;
        if code[k].is_punct(')') {
            depth += 1;
        } else if code[k].is_punct('(') {
            if depth == 0 {
                break;
            }
            depth -= 1;
        }
    }
    // `<chain> . <method> (` — the method ident sits right before the
    // open paren, the chain before its dot.
    let method = k.checked_sub(1)?;
    code[method].ident()?;
    let dot = method.checked_sub(1)?;
    if !code[dot].is_punct('.') {
        return None;
    }
    chain_last_ident(code, dot)
}

/// Every plain-comment `// ord:` anchor (doc comments excluded).
fn collect_anchors(file: &SourceFile) -> Vec<Anchor> {
    let mut out = Vec::new();
    for t in &file.toks {
        let Some(text) = t.comment_text() else { continue };
        if text.starts_with('/') || text.starts_with('!') {
            continue;
        }
        if text.trim_start().starts_with("ord:") {
            out.push(Anchor { line: t.line });
        }
    }
    out
}

/// All plain-comment text on lines `[from, to]` joined — the
/// rationale run checked for the ordering name.
fn comment_run(file: &SourceFile, from: usize, to: usize) -> String {
    let mut text = String::new();
    for t in &file.toks {
        if t.line < from || t.line > to {
            continue;
        }
        if let Some(c) = t.comment_text() {
            if !c.starts_with('/') && !c.starts_with('!') {
                text.push_str(c);
                text.push('\n');
            }
        }
    }
    text
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{run as run_all, LintInput, SourceFile};

    fn input(path: &str, src: &str) -> LintInput {
        LintInput {
            files: vec![SourceFile::from_source(path, src)],
            design_md: String::new(),
        }
    }

    #[test]
    fn fixture_fires_on_every_bad_site() {
        let src = include_str!("fixtures/atomic_ordering_bad.rs");
        let fs = run(&input("rust/src/util/thread_pool.rs", src));
        let msgs: Vec<&str> =
            fs.iter().map(|f| f.message.as_str()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("not a LiveStats")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter()
                .any(|m| m.contains("without an `// ord:` rationale")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("does not name `Acquire`")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("stale `// ord:`")),
            "{msgs:?}"
        );
        assert_eq!(fs.len(), 4, "{msgs:?}");
    }

    #[test]
    fn fixture_waivers_suppress_and_are_counted() {
        let src = include_str!("fixtures/atomic_ordering_waived.rs");
        let report = run_all(&input("rust/src/util/thread_pool.rs", src));
        assert!(
            report.findings.is_empty(),
            "waived fixture should be clean:\n{}",
            report.render()
        );
        let s = report
            .summaries
            .iter()
            .find(|s| s.pass == "atomic-ordering")
            .unwrap_or_else(|| panic!("no atomic-ordering summary"));
        assert!(s.waivers_used >= 2, "waivers used: {}", s.waivers_used);
    }

    #[test]
    fn allowlisted_counters_and_good_rationales_are_clean() {
        let src = "\
use std::sync::atomic::{AtomicUsize, Ordering};\n\
pub struct LiveStats {\n\
    pub steps: AtomicUsize,\n\
    pub tokens_out: AtomicUsize,\n\
}\n\
pub struct Gate {\n\
    pub open: AtomicUsize,\n\
}\n\
pub fn f(s: &LiveStats, g: &Gate) -> usize {\n\
    s.steps.fetch_add(1, Ordering::Relaxed);\n\
    // ord: SeqCst — pairs with the store in close(); the reader\n\
    // must observe the final counter value\n\
    g.open.load(Ordering::SeqCst)\n\
        + s.tokens_out.load(Ordering::Relaxed)\n\
}\n";
        let fs = run(&input("rust/src/serve/engine.rs", src));
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn test_code_sites_are_exempt_but_cover_anchors() {
        let src = "\
use std::sync::atomic::{AtomicUsize, Ordering};\n\
#[cfg(test)]\n\
mod tests {\n\
    use super::*;\n\
    #[test]\n\
    fn t() {\n\
        let n = AtomicUsize::new(0);\n\
        n.store(1, Ordering::Release);\n\
    }\n\
}\n";
        let fs = run(&input("rust/src/serve/engine.rs", src));
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn doc_comments_are_not_ord_anchors() {
        // an `ord:` mention in a doc comment neither satisfies a site
        // nor trips the stale audit
        let src = "\
//! ord: prose about the mechanism, not an anchor\n\
use std::sync::atomic::{AtomicUsize, Ordering};\n\
pub fn f(n: &AtomicUsize) {\n\
    n.store(1, Ordering::Release);\n\
}\n";
        let fs = run(&input("rust/src/serve/engine.rs", src));
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("without an `// ord:`"));
    }
}

"""Training-graph tests: convergence, schedule, clipping, param groups,
KLA+ MC loss, and eval/score/decode builders."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.models.common import flatten_params
from compile.models.lm import ModelConfig, init_lm, lm_forward
from compile.train_step import (OptConfig, _param_groups, _schedule,
                                build_decode, build_eval_step, build_logits,
                                build_score_step, build_train_step,
                                build_variance)

CFG = dict(vocab=32, d_model=32, n_layers=1, n_state=4)


def setup(kind="kla", opt=None, **kw):
    cfg = ModelConfig(kind=kind, **{**CFG, **kw})
    opt = opt or OptConfig(lr=3e-3, total_steps=100)
    tpl = init_lm(cfg, 0)
    flat = [a for _, a in flatten_params(tpl)]
    return cfg, opt, tpl, flat


def pattern_batch(B=4, T=32, V=32):
    pat = jnp.asarray(np.tile(np.arange(8), (B, T // 8)), jnp.int32)
    return pat, jnp.roll(pat, -1, axis=1), jnp.ones((B, T), jnp.float32)


def run_steps(cfg, opt, tpl, flat, steps=60):
    ts = jax.jit(build_train_step(cfg, opt, tpl))
    m = [jnp.zeros_like(a) for a in flat]
    v = [jnp.zeros_like(a) for a in flat]
    toks, tgt, mask = pattern_batch()
    losses = []
    for s in range(steps):
        loss, flat, m, v = ts(flat, m, v, jnp.float32(s), toks, tgt, mask)
        losses.append(float(loss))
    return losses, flat


class TestTrainStep:
    @pytest.mark.parametrize("kind", ["kla", "mamba", "gla", "gpt"])
    def test_converges(self, kind):
        cfg, opt, tpl, flat = setup(kind)
        losses, _ = run_steps(cfg, opt, tpl, flat)
        assert losses[-1] < losses[0] * 0.4, (kind, losses[0], losses[-1])
        assert all(np.isfinite(losses))

    def test_gdn_converges(self):
        cfg, opt, tpl, flat = setup("gdn")
        losses, _ = run_steps(cfg, opt, tpl, flat, steps=80)
        assert losses[-1] < losses[0] * 0.6

    def test_kla_plus_mc_loss_converges(self):
        cfg, opt, tpl, flat = setup("kla", mc_samples=2)
        losses, _ = run_steps(cfg, opt, tpl, flat, steps=50)
        assert losses[-1] < losses[0] * 0.6
        assert all(np.isfinite(losses))

    def test_nonoise_ablation_trains(self):
        cfg, opt, tpl, flat = setup("kla", process_noise=False)
        losses, _ = run_steps(cfg, opt, tpl, flat, steps=40)
        assert all(np.isfinite(losses))

    def test_schedule_trapezoidal(self):
        opt = OptConfig(lr=1.0, total_steps=100, warmdown_frac=0.4)
        assert float(_schedule(jnp.float32(0), opt)) == pytest.approx(1.0)
        assert float(_schedule(jnp.float32(59), opt)) == pytest.approx(1.0)
        mid = float(_schedule(jnp.float32(80), opt))
        assert 0.4 < mid < 0.6
        assert float(_schedule(jnp.float32(100), opt)) == pytest.approx(0.0)

    def test_param_groups(self):
        cfg, _, tpl, _ = setup("kla")
        names = [n for n, _ in flatten_params(tpl)]
        lr_mults, wd_mults = _param_groups(names)
        by_name = dict(zip(names, zip(lr_mults, wd_mults)))
        for n, (lm_, wm) in by_name.items():
            leaf = n.rsplit(".", 1)[-1]
            if leaf in ("a_raw", "p_raw", "dt_raw", "lam0_raw"):
                assert lm_ == 0.1 and wm == 0.0, n
            if leaf == "norm" or leaf == "embed":
                assert wm == 0.0, n
            if leaf in ("wk", "wv", "head"):
                assert lm_ == 1.0 and wm == 1.0, n

    def test_grad_clip_bounds_update(self):
        """With a huge LR and tiny clip the update magnitude stays bounded."""
        cfg, _, tpl, flat = setup("kla",
                                  opt=OptConfig(lr=1e-3, grad_clip=1e-6,
                                                total_steps=100))
        opt = OptConfig(lr=1e-3, grad_clip=1e-6, total_steps=100)
        ts = jax.jit(build_train_step(cfg, opt, tpl))
        m = [jnp.zeros_like(a) for a in flat]
        v = [jnp.zeros_like(a) for a in flat]
        toks, tgt, mask = pattern_batch()
        _, flat2, _, _ = ts(flat, m, v, jnp.float32(0), toks, tgt, mask)
        # AdamW normalises by sqrt(v), so update ~ lr regardless; but with
        # clip ~0 the first-step m/sqrt(v) ratio is finite; just assert all
        # params remain finite and close to the originals.
        for a, b in zip(flat, flat2):
            assert np.isfinite(np.asarray(b)).all()
            assert np.max(np.abs(np.asarray(a) - np.asarray(b))) < 0.01


class TestOtherBuilders:
    def test_eval_step(self):
        cfg, _, tpl, flat = setup("kla")
        ev = jax.jit(build_eval_step(cfg, tpl))
        toks, tgt, mask = pattern_batch()
        loss_sum, correct, count = ev(flat, toks, tgt, mask)
        assert float(count) == float(mask.sum())
        assert 0.0 <= float(correct) <= float(count)
        assert float(loss_sum) > 0

    def test_score_step_ranks_likely_continuation(self):
        cfg, opt, tpl, flat = setup("kla")
        _, trained = run_steps(cfg, opt, tpl, flat, steps=60)
        sc = jax.jit(build_score_step(cfg, tpl))
        toks, tgt, mask = pattern_batch(B=2)
        good = sc(trained, toks, tgt, mask)
        bad_tgt = (tgt + 3) % 32
        bad = sc(trained, toks, bad_tgt, mask)
        assert (np.asarray(good) > np.asarray(bad)).all()

    def test_logits_matches_forward(self):
        cfg, _, tpl, flat = setup("kla")
        lg = jax.jit(build_logits(cfg, tpl))
        toks, _, _ = pattern_batch(B=2)
        np.testing.assert_allclose(np.asarray(lg(flat, toks)),
                                   np.asarray(lm_forward(cfg, tpl, toks)),
                                   rtol=2e-4, atol=2e-4)

    def test_variance_builder(self):
        cfg, _, tpl, flat = setup("kla")
        vf = jax.jit(build_variance(cfg, tpl))
        toks, _, _ = pattern_batch(B=2)
        var = vf(flat, toks)
        assert var.shape == toks.shape
        assert (np.asarray(var) > 0).all()

    def test_decode_builder_matches_logits(self):
        from compile.models.decode import decode_init_state
        cfg, _, tpl, flat = setup("kla")
        dec = jax.jit(build_decode(cfg, tpl))
        lg = jax.jit(build_logits(cfg, tpl))
        rng = np.random.default_rng(0)
        B, T = 2, 6
        toks = jnp.asarray(rng.integers(0, 32, (B, T)), jnp.int32)
        full = np.asarray(lg(flat, toks))
        conv, lam, eta = decode_init_state(cfg, tpl, B)
        for t in range(T):
            logits, conv, lam, eta = dec(flat, toks[:, t], conv, lam, eta)
            np.testing.assert_allclose(np.asarray(logits), full[:, t],
                                       rtol=2e-3, atol=2e-3)

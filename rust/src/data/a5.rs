//! A5 word problem (paper §5.4, Merrill et al. 2024): hard state tracking.
//!
//! The alternating group A5 (even permutations of 5 elements, |A5| = 60) is
//! the smallest non-solvable group; computing running products of group
//! elements is NC^1-complete, so linear/diagonal SSMs and fixed-depth
//! transformers (TC^0) cannot solve it at growing length while KLA's
//! Moebius (nonlinear) updates can (paper Fig. 1a).
//!
//! Tokens: element g_i at position t; target at t is the index of the
//! running product g_1 * g_2 * ... * g_t.  Every position is supervised.
//! Vocabulary: 0..59 = group elements (PAD-free: all positions used),
//! artifact vocab 64 leaves room for specials.

use super::{Sample, TaskGen};
use crate::util::Pcg64;

/// Precomputed A5: 60 even permutations of {0..4} and the Cayley table.
pub struct A5 {
    pub perms: Vec<[u8; 5]>,
    /// table[a * 60 + b] = index of perm a ∘ perm b (apply b first).
    pub table: Vec<u8>,
}

impl A5 {
    pub fn new() -> Self {
        // enumerate all permutations of 5 elements, keep even ones
        let mut perms = Vec::with_capacity(60);
        let mut items = [0u8, 1, 2, 3, 4];
        permute(&mut items, 0, &mut |p| {
            if parity(p) == 0 {
                perms.push(*p);
            }
        });
        perms.sort();
        assert_eq!(perms.len(), 60);
        let index = |p: &[u8; 5]| -> u8 {
            perms.binary_search(p).expect("perm in A5") as u8
        };
        let mut table = vec![0u8; 60 * 60];
        for (a, pa) in perms.iter().enumerate() {
            for (b, pb) in perms.iter().enumerate() {
                // (pa ∘ pb)(x) = pa[pb[x]]
                let mut comp = [0u8; 5];
                for (x, c) in comp.iter_mut().enumerate() {
                    *c = pa[pb[x] as usize];
                }
                table[a * 60 + b] = index(&comp);
            }
        }
        A5 { perms, table }
    }

    #[inline]
    pub fn mul(&self, a: u8, b: u8) -> u8 {
        self.table[a as usize * 60 + b as usize]
    }

    pub fn identity(&self) -> u8 {
        self.perms
            .iter()
            .position(|p| p == &[0, 1, 2, 3, 4])
            .unwrap() as u8
    }
}

impl Default for A5 {
    fn default() -> Self {
        Self::new()
    }
}

fn permute<F: FnMut(&[u8; 5])>(items: &mut [u8; 5], k: usize, f: &mut F) {
    if k == 5 {
        f(items);
        return;
    }
    for i in k..5 {
        items.swap(k, i);
        permute(items, k + 1, f);
        items.swap(k, i);
    }
}

fn parity(p: &[u8; 5]) -> u8 {
    let mut inv = 0;
    for i in 0..5 {
        for j in i + 1..5 {
            if p[i] > p[j] {
                inv += 1;
            }
        }
    }
    inv % 2
}

/// The sequence task over A5.
pub struct A5Task {
    group: A5,
}

impl A5Task {
    pub fn new() -> Self {
        A5Task { group: A5::new() }
    }

    pub fn group(&self) -> &A5 {
        &self.group
    }
}

impl Default for A5Task {
    fn default() -> Self {
        Self::new()
    }
}

impl TaskGen for A5Task {
    fn name(&self) -> &str {
        "a5"
    }

    fn sample(&self, rng: &mut Pcg64, t: usize) -> Sample {
        let mut s = Sample::with_capacity(t);
        // new element each step; target = running product (composition
        // convention: newest element applied LAST, i.e. prod = g_t ∘ prod)
        let mut prod = self.group.identity();
        for _ in 0..t {
            let g = rng.below(60) as u8;
            prod = self.group.mul(g, prod);
            s.push(g as i32, prod as i32, true);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::property;

    #[test]
    fn group_axioms() {
        let g = A5::new();
        let e = g.identity();
        // identity
        for a in 0..60u8 {
            assert_eq!(g.mul(e, a), a);
            assert_eq!(g.mul(a, e), a);
        }
        // closure is by construction; associativity:
        property("a5_assoc", 200, |gen| {
            let (a, b, c) = (
                gen.rng.below(60) as u8,
                gen.rng.below(60) as u8,
                gen.rng.below(60) as u8,
            );
            let left = g.mul(g.mul(a, b), c);
            let right = g.mul(a, g.mul(b, c));
            if left != right {
                return Err(format!("({a}*{b})*{c} = {left} != {right}"));
            }
            Ok(())
        });
    }

    #[test]
    fn every_element_has_inverse() {
        let g = A5::new();
        let e = g.identity();
        for a in 0..60u8 {
            let found = (0..60u8).any(|b| g.mul(a, b) == e && g.mul(b, a) == e);
            assert!(found, "no inverse for {a}");
        }
    }

    #[test]
    fn nonabelian() {
        let g = A5::new();
        let noncommuting = (0..60u8)
            .flat_map(|a| (0..60u8).map(move |b| (a, b)))
            .any(|(a, b)| g.mul(a, b) != g.mul(b, a));
        assert!(noncommuting, "A5 must be non-abelian");
    }

    #[test]
    fn task_targets_are_running_products() {
        let task = A5Task::new();
        let mut rng = Pcg64::seeded(0);
        let s = task.sample(&mut rng, 24);
        let g = task.group();
        let mut prod = g.identity();
        for i in 0..24 {
            prod = g.mul(s.tokens[i] as u8, prod);
            assert_eq!(s.targets[i], prod as i32);
            assert_eq!(s.mask[i], 1.0);
        }
    }
}

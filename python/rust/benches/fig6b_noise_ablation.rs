fn main() {}

//! Fig. 1a: A5 state-tracking expressivity — minimum depth to solve the
//! word problem (accuracy >= 0.9, paper G.1).
//!
//! Claim shape: KLA solves at depth 1-2 (Moebius nonlinearity); linear
//! SSMs / attention do not at the same depth.  Depths 3-4 for baselines
//! come from `make artifacts-full`.

use kla::bench::exp::{bench_seeds, bench_steps, have, train_mean_acc};
use kla::bench::Suite;
use kla::data::task_by_name;
use kla::runtime::Runtime;

fn main() {
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP fig1a: {e}");
            return;
        }
    };
    let steps = bench_steps(400);
    let seeds = bench_seeds(1);
    let task = task_by_name("a5").unwrap();
    let mut suite = Suite::new("fig1a_a5");
    for model in ["kla", "mamba", "gla", "gpt"] {
        let mut min_depth_solved: Option<usize> = None;
        for depth in [1usize, 2, 3, 4] {
            let base = format!("a5_{model}_l{depth}");
            if !have(&rt, &base) {
                continue;
            }
            let (acc, _) =
                train_mean_acc(&rt, &base, task.as_ref(), steps, seeds)
                    .unwrap();
            suite.metric_row(&format!("{model}/l{depth}"),
                             vec![("acc".into(), acc)]);
            if acc >= 0.9 && min_depth_solved.is_none() {
                min_depth_solved = Some(depth);
            }
        }
        match min_depth_solved {
            Some(d) => println!("{model:8} solves A5 at depth {d}"),
            None => println!("{model:8} does not solve A5 at tested depths"),
        }
    }
    suite.finish();
}

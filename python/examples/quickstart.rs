fn main() { println!("todo"); }

//! Thread spawn/join routed through the model checker.
//!
//! `util::thread_pool` (and any future concurrent module) spawns its
//! OS threads through [`spawn_named`] instead of `std::thread`.  In
//! normal builds this is a thin alias for `std::thread::Builder`;
//! under `--features mc-shim`, threads spawned *inside* a model
//! execution become controlled model threads: they run only when the
//! scheduler grants them the baton, and `join` becomes a blocking
//! model operation (enabled once the target thread finishes).
//!
//! A spawned model thread that unwinds with a user panic fails the
//! whole execution (the diagnosis names the thread); the teardown
//! unwind ([`crate::mc::sched`]'s private abort payload) is absorbed
//! silently.

#[cfg(not(feature = "mc-shim"))]
pub type JoinHandle<T> = std::thread::JoinHandle<T>;

/// Spawn a named thread (std passthrough in normal builds).
#[cfg(not(feature = "mc-shim"))]
pub fn spawn_named<T, F>(name: &str, f: F) -> std::io::Result<JoinHandle<T>>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    std::thread::Builder::new().name(name.to_string()).spawn(f)
}

#[cfg(feature = "mc-shim")]
pub use shim::{spawn_named, JoinHandle};

#[cfg(feature = "mc-shim")]
mod shim {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Arc;

    use crate::mc::sched::{self, Exec, Intent};

    pub struct JoinHandle<T> {
        inner: std::thread::JoinHandle<Option<T>>,
        mc: Option<(Arc<Exec>, usize)>,
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            if let Some((exec, tid)) = &self.mc {
                if !std::thread::panicking() {
                    if let Some((cur, me)) = sched::current_ctx() {
                        if Arc::ptr_eq(&cur, exec) {
                            // model join: enabled once `tid` finishes
                            cur.op(me, Intent::Join(*tid));
                        }
                    }
                }
                // the target already ran finish(); the OS-level join
                // below completes without model interaction
            }
            match self.inner.join() {
                Ok(Some(v)) => Ok(v),
                // the thread was torn down by a model abort; the
                // joiner unwinds at its own next scheduling point
                Ok(None) => Err(Box::new("mc: thread aborted")),
                Err(e) => Err(e),
            }
        }
    }

    pub fn spawn_named<T, F>(
        name: &str,
        f: F,
    ) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let builder =
            std::thread::Builder::new().name(name.to_string());
        if let Some((exec, me)) = sched::current_ctx() {
            // the spawn itself is a visible op of the parent
            exec.op(me, Intent::Step);
            let tid = exec.register_thread(name);
            let e2 = Arc::clone(&exec);
            let inner = builder.spawn(move || {
                sched::enter(&e2, tid);
                let r = catch_unwind(AssertUnwindSafe(|| {
                    e2.park_start(tid);
                    f()
                }));
                match r {
                    Ok(v) => {
                        e2.finish(tid);
                        Some(v)
                    }
                    Err(p) if sched::is_mc_abort(p.as_ref()) => {
                        e2.finish(tid);
                        None
                    }
                    Err(p) => {
                        let msg = sched::panic_text(p.as_ref());
                        e2.finish_panicked(tid, msg);
                        None
                    }
                }
            })?;
            Ok(JoinHandle {
                inner,
                mc: Some((exec, tid)),
            })
        } else {
            let inner = builder.spawn(move || Some(f()))?;
            Ok(JoinHandle { inner, mc: None })
        }
    }
}

//! Belief-state cache manager — the O(1)-state analogue of a KV-cache
//! manager (DESIGN.md §S15).
//!
//! A KLA model's per-sequence decode state is CONSTANT-SIZE: a causal-conv
//! window plus the posterior (precision, information mean).  Slots live in
//! the batch dimension of one `DecodeState`; the manager hands out slots,
//! resets them to the learned prior on release, and supports snapshotting
//! a slot's belief state for conversation resume (the belief-state
//! analogue of prefix caching).

use anyhow::{bail, Result};

use crate::api::KlaBelief;
use crate::runtime::backend::{DecodeBackend, DecodeState};

/// Snapshot of one slot's state: the causal-conv window plus one
/// posterior belief per layer — the same [`crate::api::Filter::Belief`]
/// type (`KlaBelief`) the native training-side scan produces, so a
/// serving slot's uncertainty flows through the exact carry the `prefix`
/// / `step` API defines.
#[derive(Clone, Debug)]
pub struct SlotSnapshot {
    pub conv: Vec<f32>,
    /// Per-layer posterior (precision, information mean).
    pub beliefs: Vec<KlaBelief>,
}

impl SlotSnapshot {
    /// Payload size in bytes (conv window + per-layer lam and eta) — the
    /// unit the prefix cache's LRU budget accounts in.  Constant per
    /// model geometry: this is the whole point of a belief-state cache
    /// versus a sequence-length KV cache.
    pub fn bytes(&self) -> usize {
        let floats = self.conv.len()
            + self.beliefs.iter().map(|b| 2 * b.state()).sum::<usize>();
        floats * std::mem::size_of::<f32>()
    }
}

/// Why [`BeliefStateCache::restore`] refused a snapshot.  Structured (not
/// a rendered string) so callers can react to the exact geometry
/// mismatch; converts into `anyhow::Error` through `?` like any
/// `std::error::Error`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RestoreError {
    /// The snapshot was taken under a different layer count.
    LayerCount { snapshot: usize, cache: usize },
    /// The conv window length differs (e.g. a different conv_kernel).
    ConvLen { snapshot: usize, cache: usize },
    /// A per-layer belief has the wrong N*D width.
    BeliefWidth { layer: usize, snapshot: usize, cache: usize },
}

impl std::fmt::Display for RestoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RestoreError::LayerCount { snapshot, cache } => write!(
                f,
                "snapshot has {snapshot} layers, cache expects {cache}"),
            RestoreError::ConvLen { snapshot, cache } => write!(
                f,
                "snapshot conv window holds {snapshot} floats, cache \
                 expects {cache}"),
            RestoreError::BeliefWidth { layer, snapshot, cache } => write!(
                f,
                "snapshot belief for layer {layer} is {snapshot} wide, \
                 cache expects {cache}"),
        }
    }
}

impl std::error::Error for RestoreError {}

pub struct BeliefStateCache {
    /// live batched state, shapes (L,B,K-1,D) / (L,B,N,D) / (L,B,N,D)
    state: DecodeState,
    init: DecodeState,
    free: Vec<usize>,
    batch: usize,
    layers: usize,
    conv_row: usize, // (K-1)*D
    post_row: usize, // N*D
}

impl BeliefStateCache {
    pub fn new(init: DecodeState) -> Self {
        let s = init.lam.shape();
        let (layers, batch) = (s[0], s[1]);
        let post_row = s[2] * s[3];
        let cs = init.conv.shape();
        let conv_row = cs[2] * cs[3];
        BeliefStateCache {
            state: init.clone(),
            init,
            free: (0..batch).rev().collect(),
            batch,
            layers,
            conv_row,
            post_row,
        }
    }

    /// Slot pool over a backend's prior state — works identically for
    /// the XLA artifact session and the native model, since both share
    /// the `DecodeState` layout.
    pub fn for_backend<B: DecodeBackend + ?Sized>(backend: &B)
                                                  -> Result<Self> {
        Ok(Self::new(backend.init_state()?))
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Claim a fresh slot (state reset to the prior).
    pub fn acquire(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.reset_slot(slot);
        Some(slot)
    }

    /// Release a slot back to the pool.  The slot's state is reset to
    /// the learned prior immediately (not lazily at the next acquire),
    /// so a released slot can never leak a previous request's posterior
    /// — the invariant `prop_state_cache.rs` pins.
    pub fn release(&mut self, slot: usize) {
        debug_assert!(slot < self.batch);
        debug_assert!(!self.free.contains(&slot));
        self.reset_slot(slot);
        self.free.push(slot);
    }

    /// Reset one slot's state to the learned prior (lam0, zeros).
    pub fn reset_slot(&mut self, slot: usize) {
        for l in 0..self.layers {
            let c0 = (l * self.batch + slot) * self.conv_row;
            self.state.conv.data_mut()[c0..c0 + self.conv_row]
                .copy_from_slice(
                    &self.init.conv.data()[c0..c0 + self.conv_row]);
            let p0 = (l * self.batch + slot) * self.post_row;
            self.state.lam.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(
                    &self.init.lam.data()[p0..p0 + self.post_row]);
            self.state.eta.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(
                    &self.init.eta.data()[p0..p0 + self.post_row]);
        }
    }

    /// One layer's posterior belief for a slot, as the shared carry type.
    pub fn slot_belief(&self, layer: usize, slot: usize) -> KlaBelief {
        debug_assert!(layer < self.layers && slot < self.batch);
        let p0 = (layer * self.batch + slot) * self.post_row;
        KlaBelief::from_parts(
            self.state.lam.data()[p0..p0 + self.post_row].to_vec(),
            self.state.eta.data()[p0..p0 + self.post_row].to_vec(),
        )
    }

    /// Snapshot a slot (e.g. end of a conversation turn).
    pub fn snapshot(&self, slot: usize) -> SlotSnapshot {
        let mut snap = SlotSnapshot {
            conv: Vec::with_capacity(self.layers * self.conv_row),
            beliefs: Vec::with_capacity(self.layers),
        };
        for l in 0..self.layers {
            let c0 = (l * self.batch + slot) * self.conv_row;
            snap.conv
                .extend_from_slice(&self.state.conv.data()[c0..c0 + self.conv_row]);
            snap.beliefs.push(self.slot_belief(l, slot));
        }
        snap
    }

    /// Restore a previously snapshotted belief state into a slot.  Every
    /// geometry mismatch is a structured [`RestoreError`] — a snapshot
    /// taken under a different layer count, conv_kernel or state width
    /// must error (with the exact mismatch), never panic inside
    /// `copy_from_slice`.
    pub fn restore(&mut self, slot: usize, snap: &SlotSnapshot)
                   -> std::result::Result<(), RestoreError> {
        if snap.beliefs.len() != self.layers {
            return Err(RestoreError::LayerCount {
                snapshot: snap.beliefs.len(),
                cache: self.layers,
            });
        }
        if snap.conv.len() != self.layers * self.conv_row {
            return Err(RestoreError::ConvLen {
                snapshot: snap.conv.len(),
                cache: self.layers * self.conv_row,
            });
        }
        for (l, b) in snap.beliefs.iter().enumerate() {
            if b.state() != self.post_row {
                return Err(RestoreError::BeliefWidth {
                    layer: l,
                    snapshot: b.state(),
                    cache: self.post_row,
                });
            }
        }
        for (l, belief) in snap.beliefs.iter().enumerate() {
            let c0 = (l * self.batch + slot) * self.conv_row;
            self.state.conv.data_mut()[c0..c0 + self.conv_row]
                .copy_from_slice(
                    &snap.conv[l * self.conv_row..(l + 1) * self.conv_row]);
            let p0 = (l * self.batch + slot) * self.post_row;
            self.state.lam.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(&belief.lam);
            self.state.eta.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(&belief.eta);
        }
        Ok(())
    }

    /// Write a single-lane (B=1) state — the result of a
    /// `DecodeBackend::prefill` call — into `slot` of the batched state.
    /// Shape-checked; no other lane is touched.
    pub fn write_slot(&mut self, slot: usize, lane: &DecodeState)
                      -> Result<()> {
        if slot >= self.batch {
            bail!("write_slot: slot {slot} out of range for batch {}",
                  self.batch);
        }
        let cs = self.state.conv.shape();
        let ps = self.state.lam.shape();
        if lane.conv.shape() != [cs[0], 1, cs[2], cs[3]]
            || lane.lam.shape() != [ps[0], 1, ps[2], ps[3]]
            || lane.eta.shape() != [ps[0], 1, ps[2], ps[3]]
        {
            bail!("write_slot: lane shapes {:?}/{:?}/{:?} do not match \
                   cache layout {:?}/{:?}",
                  lane.conv.shape(), lane.lam.shape(), lane.eta.shape(),
                  cs, ps);
        }
        for l in 0..self.layers {
            let c0 = (l * self.batch + slot) * self.conv_row;
            self.state.conv.data_mut()[c0..c0 + self.conv_row]
                .copy_from_slice(
                    &lane.conv.data()
                        [l * self.conv_row..(l + 1) * self.conv_row]);
            let p0 = (l * self.batch + slot) * self.post_row;
            self.state.lam.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(
                    &lane.lam.data()
                        [l * self.post_row..(l + 1) * self.post_row]);
            self.state.eta.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(
                    &lane.eta.data()
                        [l * self.post_row..(l + 1) * self.post_row]);
        }
        Ok(())
    }

    pub fn state(&self) -> &DecodeState {
        &self.state
    }

    /// Overwrite the whole batched state (after a decode step).
    pub fn set_state(&mut self, state: DecodeState) {
        debug_assert_eq!(state.lam.shape(), self.state.lam.shape());
        self.state = state;
    }

    /// Mean posterior variance (1/lam) of a slot — the serving-side
    /// uncertainty signal (paper §7: epistemic uncertainty applications),
    /// computed with the same `api::mean_variance` formula the belief
    /// type and the native variance trace use.  Since protocol v2 the
    /// engine reads this once per sampled token (every streamed `token`
    /// event carries the post-step value), so it stays allocation-free
    /// over borrowed slices by design, not just thrift.
    pub fn slot_uncertainty(&self, slot: usize) -> f32 {
        if self.layers == 0 {
            return 0.0;
        }
        let mut acc = 0.0f64;
        for l in 0..self.layers {
            let p0 = (l * self.batch + slot) * self.post_row;
            let lam = &self.state.lam.data()[p0..p0 + self.post_row];
            acc += crate::api::mean_variance(lam) as f64;
        }
        (acc / self.layers as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tiny_state() -> DecodeState {
        let (l, b, k1, d, n) = (2, 3, 3, 4, 2);
        let mut lam = Tensor::zeros(&[l, b, n, d]);
        lam.data_mut().iter_mut().for_each(|x| *x = 1.5);
        DecodeState {
            conv: Tensor::zeros(&[l, b, k1, d]),
            lam,
            eta: Tensor::zeros(&[l, b, n, d]),
        }
    }

    #[test]
    fn acquire_release_cycle() {
        let mut cache = BeliefStateCache::new(tiny_state());
        assert_eq!(cache.free_slots(), 3);
        let a = cache.acquire().unwrap();
        let b = cache.acquire().unwrap();
        assert_ne!(a, b);
        assert_eq!(cache.free_slots(), 1);
        cache.release(a);
        assert_eq!(cache.free_slots(), 2);
        let c = cache.acquire().unwrap();
        let d = cache.acquire().unwrap();
        assert_eq!(cache.free_slots(), 0);
        assert!(cache.acquire().is_none());
        let _ = (c, d);
    }

    #[test]
    fn reset_restores_prior() {
        let mut cache = BeliefStateCache::new(tiny_state());
        let slot = cache.acquire().unwrap();
        // dirty the slot
        let mut s = cache.state().clone();
        s.lam.data_mut().iter_mut().for_each(|x| *x = 99.0);
        cache.set_state(s);
        cache.reset_slot(slot);
        // slot entries back to 1.5; others still 99
        let lam = cache.state().lam.clone();
        assert_eq!(lam.get(&[0, slot, 0, 0]), 1.5);
        let other = (slot + 1) % 3;
        assert_eq!(lam.get(&[0, other, 0, 0]), 99.0);
    }

    #[test]
    fn snapshot_roundtrip() {
        let mut cache = BeliefStateCache::new(tiny_state());
        let slot = cache.acquire().unwrap();
        let mut s = cache.state().clone();
        s.eta.data_mut().iter_mut().for_each(|x| *x = 7.0);
        cache.set_state(s);
        let snap = cache.snapshot(slot);
        cache.reset_slot(slot);
        assert_eq!(cache.state().eta.get(&[0, slot, 0, 0]), 0.0);
        cache.restore(slot, &snap).unwrap();
        assert_eq!(cache.state().eta.get(&[0, slot, 0, 0]), 7.0);
    }

    #[test]
    fn snapshot_exposes_filter_beliefs() {
        let cache = BeliefStateCache::new(tiny_state());
        let snap = cache.snapshot(0);
        assert_eq!(snap.beliefs.len(), 2); // one KlaBelief per layer
        for belief in &snap.beliefs {
            assert_eq!(belief.state(), 2 * 4); // N*D
            // lam was initialised to 1.5 everywhere
            assert!((belief.mean_variance() - 1.0 / 1.5).abs() < 1e-6);
        }
        // slot_belief agrees with the snapshot
        assert_eq!(cache.slot_belief(1, 0), snap.beliefs[1]);
    }

    #[test]
    fn release_resets_slot_to_prior() {
        let mut cache = BeliefStateCache::new(tiny_state());
        let slot = cache.acquire().unwrap();
        let mut s = cache.state().clone();
        s.lam.data_mut().iter_mut().for_each(|x| *x = 77.0);
        s.eta.data_mut().iter_mut().for_each(|x| *x = -3.0);
        cache.set_state(s);
        cache.release(slot);
        // released slot is back at the prior even before re-acquire
        assert_eq!(cache.state().lam.get(&[0, slot, 0, 0]), 1.5);
        assert_eq!(cache.state().eta.get(&[0, slot, 0, 0]), 0.0);
    }

    #[test]
    fn write_slot_roundtrips_an_extracted_lane() {
        let mut cache = BeliefStateCache::new(tiny_state());
        // dirty lane 1, extract it, reset it, write it back
        let mut s = cache.state().clone();
        s.eta.data_mut().iter_mut().for_each(|x| *x = 4.0);
        cache.set_state(s);
        let lane = cache.state().slot(1).unwrap();
        cache.reset_slot(1);
        assert_eq!(cache.state().eta.get(&[0, 1, 0, 0]), 0.0);
        cache.write_slot(1, &lane).unwrap();
        assert_eq!(cache.state().eta.get(&[0, 1, 0, 0]), 4.0);
        assert_eq!(cache.state().eta.get(&[1, 1, 1, 3]), 4.0);
        // neighbouring lanes untouched
        assert_eq!(cache.state().lam.get(&[0, 0, 0, 0]), 1.5);
    }

    #[test]
    fn write_slot_rejects_bad_shapes_and_slots() {
        let mut cache = BeliefStateCache::new(tiny_state());
        let lane = cache.state().slot(0).unwrap();
        assert!(cache.write_slot(3, &lane).is_err()); // batch is 3
        // lane from a different geometry (K-1 = 1 instead of 3)
        let bad = DecodeState {
            conv: Tensor::zeros(&[2, 1, 1, 4]),
            lam: Tensor::zeros(&[2, 1, 2, 4]),
            eta: Tensor::zeros(&[2, 1, 2, 4]),
        };
        assert!(cache.write_slot(0, &bad).is_err());
        // a full batched state is not a lane
        let full = cache.state().clone();
        assert!(cache.write_slot(0, &full).is_err());
    }

    #[test]
    fn restore_rejects_conv_window_length_mismatch() {
        let mut cache = BeliefStateCache::new(tiny_state());
        let mut snap = cache.snapshot(0);
        // a snapshot from a model with a different conv_kernel: beliefs
        // match but the conv window does not — must error, not panic
        snap.conv.truncate(snap.conv.len() - 1);
        assert_eq!(cache.restore(0, &snap),
                   Err(RestoreError::ConvLen { snapshot: 23, cache: 24 }));
        snap.conv.clear();
        assert_eq!(cache.restore(0, &snap),
                   Err(RestoreError::ConvLen { snapshot: 0, cache: 24 }));
    }

    #[test]
    fn restore_rejects_layer_count_mismatch_with_structured_error() {
        // regression: a snapshot taken under a DIFFERENT layer count
        // (e.g. a cache file from an older model config) — drop layer
        // 1's belief and conv rows so only the layer count disagrees
        let mut cache = BeliefStateCache::new(tiny_state());
        let mut snap = cache.snapshot(0);
        snap.beliefs.truncate(1);
        snap.conv.truncate(snap.conv.len() / 2);
        assert_eq!(cache.restore(0, &snap),
                   Err(RestoreError::LayerCount { snapshot: 1, cache: 2 }));
        // a belief of the wrong width reports the offending layer
        let mut snap = cache.snapshot(0);
        snap.beliefs[1] =
            KlaBelief::from_parts(vec![1.0; 4], vec![0.0; 4]);
        assert_eq!(cache.restore(0, &snap),
                   Err(RestoreError::BeliefWidth {
                       layer: 1,
                       snapshot: 4,
                       cache: 8,
                   }));
        // structured errors still render and convert into anyhow
        let e: anyhow::Error =
            cache.restore(0, &snap).unwrap_err().into();
        assert!(e.to_string().contains("layer 1"));
    }

    #[test]
    fn snapshot_bytes_accounts_conv_and_posteriors() {
        // tiny_state: L=2, K-1=3, D=4, N=2 — conv 2*12 floats plus
        // 2 layers * (8 lam + 8 eta) floats = 56 floats
        let cache = BeliefStateCache::new(tiny_state());
        let snap = cache.snapshot(0);
        assert_eq!(snap.bytes(), 56 * 4);
        // constant in sequence length by construction: a restored-and-
        // re-snapshotted slot costs exactly the same
        let mut cache = BeliefStateCache::new(tiny_state());
        cache.restore(1, &snap).unwrap();
        assert_eq!(cache.snapshot(1).bytes(), snap.bytes());
    }

    #[test]
    fn for_backend_pools_native_batch() {
        use crate::kla::model::NativeLmConfig;
        use crate::runtime::backend::NativeBackend;
        let cfg = NativeLmConfig {
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_state: 2,
            conv_kernel: 3,
            ..Default::default()
        };
        let backend = NativeBackend::seeded(&cfg, 9, 5);
        let cache = BeliefStateCache::for_backend(&backend).unwrap();
        assert_eq!(cache.batch(), 5);
        assert_eq!(cache.free_slots(), 5);
        // prior precision is the learned lam0 (> the 1e-3 floor)
        assert!(cache.slot_uncertainty(0) > 0.0);
    }

    #[test]
    fn uncertainty_decreases_with_precision() {
        let mut cache = BeliefStateCache::new(tiny_state());
        let u0 = cache.slot_uncertainty(0);
        let mut s = cache.state().clone();
        s.lam.data_mut().iter_mut().for_each(|x| *x = 100.0);
        cache.set_state(s);
        assert!(cache.slot_uncertainty(0) < u0);
    }
}

//! Fig. 4 / Fig. 9: compute scaling of parallel KLA vs the recurrent
//! (time-stepped) Kalman baseline.
//!
//! Implementations benchmarked (paper's four, mapped to this testbed):
//!   recurrent/native      — naive time-stepped filter, single thread
//!   recurrent/xla-step    — XLA decode artifact driven once per token
//!                           (the production recurrent path)
//!   scan/native-1t        — associative reparameterisation, one thread
//!                           ("Torch scan" analogue: math only)
//!   scan/native-chunked   — multi-threaded chunked scan ("CUDA kernel"
//!                           analogue: math + parallel hardware)
//!   scan/xla              — AOT scan artifact forward (T in {128..2048})
//!   scan/xla-pallas       — AOT Pallas-kernel artifact (T=512)

use kla::bench::{black_box, Suite};
use kla::kla::{filter_chunked, filter_sequential, random_inputs,
               random_params};
use kla::runtime::{Runtime, Value};
use kla::util::Pcg64;

fn main() {
    let mut suite = Suite::new("fig4_scaling");
    suite.max_iters = 12;
    suite.time_budget = std::time::Duration::from_secs(4);
    let threads = kla::util::pool::default_threads();
    let (n, d) = (8, 64);

    // ---- native paths across T ----
    for &t in &[128usize, 512, 2048, 8192, 32768] {
        let mut rng = Pcg64::seeded(t as u64);
        let p = random_params(&mut rng, n, d);
        let inp = random_inputs(&mut rng, t, n, d);
        suite.bench(&format!("recurrent/native T={t}"), || {
            black_box(filter_sequential(&p, &inp));
        });
        suite.bench(&format!("scan/native-1t T={t}"), || {
            black_box(filter_chunked(&p, &inp, 1));
        });
        suite.bench(&format!("scan/native-chunked({threads}t) T={t}"), || {
            black_box(filter_chunked(&p, &inp, threads));
        });
    }

    // ---- XLA paths (artifacts) ----
    match Runtime::discover() {
        Err(e) => println!("(skipping XLA points: {e})"),
        Ok(rt) => {
            // scan artifacts: full KLA block forward at various T
            for &t in &[128usize, 512, 2048, 8192] {
                let name = format!("fig4_scan_t{t}_logits");
                let Ok(art) = rt.load(&name) else {
                    println!("({name} not built — `make artifacts-full` \
                              for T=8192)");
                    continue;
                };
                let init = rt.load("fig4_kla_decode_b1_init").unwrap();
                let params = init.run(&[]).unwrap();
                let toks = kla::tensor::IntTensor::zeros(&[1, t]);
                let mut args: Vec<Value> = params.clone();
                args.push(Value::I32(toks));
                suite.bench(&format!("scan/xla T={t}"), || {
                    black_box(art.run(&args).unwrap());
                });
            }
            // pallas-kernel artifact
            if let Ok(art) = rt.load("fig4_pallas_t512_logits") {
                let init = rt.load("fig4_kla_decode_b1_init").unwrap();
                let params = init.run(&[]).unwrap();
                let toks = kla::tensor::IntTensor::zeros(&[1, 512]);
                let mut args: Vec<Value> = params;
                args.push(Value::I32(toks));
                suite.bench("scan/xla-pallas T=512", || {
                    black_box(art.run(&args).unwrap());
                });
            }
            // recurrent XLA: decode step driven T times
            let init = rt.load("fig4_kla_decode_b1_init").unwrap();
            let params = init.run(&[]).unwrap();
            let dec = kla::runtime::DecodeSession::new(
                &rt, "fig4_kla_decode_b1", params).unwrap();
            for &t in &[128usize, 512] {
                let state0 = dec.init_state().unwrap();
                suite.bench(&format!("recurrent/xla-step T={t}"), || {
                    let mut state = state0.clone();
                    let tok =
                        kla::tensor::IntTensor::new(&[1], vec![1]).unwrap();
                    for _ in 0..t {
                        let (lg, next) = dec.step(&tok, &state).unwrap();
                        black_box(lg);
                        state = next;
                    }
                });
            }
        }
    }

    suite.finish();
    // headline ratio (paper: ~350x CUDA vs recurrent at T=2048)
    let rec = suite.results().iter()
        .find(|r| r.name == "recurrent/native T=2048");
    let par = suite.results().iter()
        .find(|r| r.name.starts_with("scan/native-chunked")
            && r.name.ends_with("T=2048"));
    if let (Some(r), Some(p)) = (rec, par) {
        println!("\nheadline: chunked scan is {:.1}x faster than the \
                  recurrent update at T=2048 (paper: ~350x on A100 CUDA \
                  vs torch recurrent)", r.mean_ms / p.mean_ms);
    }
}

//! Offline substrate utilities: RNG, JSON, logging, timers, thread pool.
//!
//! The build environment has no network access to crates.io, so the usual
//! ecosystem crates (rand / serde_json / env_logger / rayon) are replaced
//! by these minimal, tested in-repo equivalents (DESIGN.md §S16).

pub mod cast;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prefix;
pub mod rng;
pub mod thread_pool;
pub mod timer;

pub use json::Json;
pub use rng::Pcg64;
pub use timer::{Stats, Timer};

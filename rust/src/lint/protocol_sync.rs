//! Pass `protocol-sync`: the wire protocol and its doc cannot drift.
//!
//! `serve/server.rs` carries the protocol spec as its module doc; the
//! sets it promises are checked against what the code actually emits,
//! in both directions:
//!
//! - **error codes** — every string passed to `err_reply(..)` (or the
//!   request-validation `fail(..)` closure) must be listed in the
//!   doc's `Codes:` paragraph, and every code listed there must be
//!   emitted somewhere;
//! - **event types** — every `("event", Json::str("<kind>"))` the
//!   server constructs must be listed in the doc's `Event kinds:`
//!   paragraph, and vice versa.
//!
//! The doc lists are machine-readable on purpose: a code or kind
//! counts as documented only when it appears **backticked** inside
//! the paragraph that starts at the marker and ends at the first
//! blank doc line — surrounding prose is ignored, so explanatory
//! parentheticals never register as phantom codes.

use super::{Finding, LintInput, SourceFile};
use crate::lint::lexer::Tok;

pub fn run(input: &LintInput) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &input.files {
        if file.path_ends_with("serve/server.rs") {
            check_server(file, &mut out);
        }
    }
    out
}

/// An emitted name with the line of its emission site.
struct Emission {
    name: String,
    line: usize,
}

fn check_server(file: &SourceFile, out: &mut Vec<Finding>) {
    let doc = file.module_doc();
    let codes_doc = backticked_after(&doc, "Codes:");
    let events_doc = backticked_after(&doc, "Event kinds:");
    let codes_line = marker_line(file, "Codes:");
    let events_line = marker_line(file, "Event kinds:");

    let emitted_codes = emitted_error_codes(file);
    let emitted_events = emitted_event_kinds(file);

    match &codes_doc {
        None => out.push(finding(
            file,
            1,
            "protocol doc has no `Codes:` paragraph listing the \
             backticked error codes"
                .to_string(),
        )),
        Some(listed) => {
            for e in &emitted_codes {
                if !listed.contains(&e.name) {
                    out.push(finding(
                        file,
                        e.line,
                        format!(
                            "error code `{}` is emitted but not listed \
                             in the protocol doc's `Codes:` paragraph",
                            e.name
                        ),
                    ));
                }
            }
            for c in listed {
                if !emitted_codes.iter().any(|e| &e.name == c) {
                    out.push(finding(
                        file,
                        codes_line,
                        format!(
                            "error code `{c}` is documented but never \
                             emitted — remove it from the `Codes:` \
                             paragraph or emit it"
                        ),
                    ));
                }
            }
        }
    }

    match &events_doc {
        None => out.push(finding(
            file,
            1,
            "protocol doc has no `Event kinds:` paragraph listing the \
             backticked event types"
                .to_string(),
        )),
        Some(listed) => {
            for e in &emitted_events {
                if !listed.contains(&e.name) {
                    out.push(finding(
                        file,
                        e.line,
                        format!(
                            "event kind `{}` is emitted but not listed \
                             in the protocol doc's `Event kinds:` \
                             paragraph",
                            e.name
                        ),
                    ));
                }
            }
            for c in listed {
                if !emitted_events.iter().any(|e| &e.name == c) {
                    out.push(finding(
                        file,
                        events_line,
                        format!(
                            "event kind `{c}` is documented but never \
                             emitted — remove it from the `Event \
                             kinds:` paragraph or emit it"
                        ),
                    ));
                }
            }
        }
    }
}

/// Every string literal passed to `err_reply(..)` / `fail(..)` that
/// looks like a kebab-case code.  Calls with no literal argument
/// (re-emission of an already-parsed code) contribute nothing.
fn emitted_error_codes(file: &SourceFile) -> Vec<Emission> {
    let code = &file.code;
    let mut out: Vec<Emission> = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if file.is_test_line(t.line) {
            continue;
        }
        let Some(name) = t.ident() else { continue };
        if name != "err_reply" && name != "fail" {
            continue;
        }
        // skip the definition (`fn err_reply(..)`, `let fail = ..`)
        // and method calls on foreign receivers
        if i > 0
            && (code[i - 1].ident() == Some("fn")
                || code[i - 1].is_punct('.'))
        {
            continue;
        }
        if !code.get(i + 1).is_some_and(|n| n.is_punct('(')) {
            continue;
        }
        // first string literal inside the call's parentheses
        let mut depth = 1usize;
        let mut j = i + 2;
        while depth > 0 {
            let Some(t) = code.get(j) else { break };
            if t.is_punct('(') {
                depth += 1;
            } else if t.is_punct(')') {
                depth -= 1;
            } else if let Tok::Str(s) = &t.tok {
                if is_kebab(s) && !out.iter().any(|e| &e.name == s) {
                    out.push(Emission { name: s.clone(), line: t.line });
                }
                break;
            }
            j += 1;
        }
    }
    out
}

/// Every `("event", Json::str("<kind>"))` construction: a `"event"`
/// string literal with another string literal within the next eight
/// code tokens — exactly far enough for the `, Json :: str ( "<kind>"`
/// shape, and one short of the first match arm in parsing code like
/// `match j.req("event")?.as_str()? { "start" => .. }`.
fn emitted_event_kinds(file: &SourceFile) -> Vec<Emission> {
    let code = &file.code;
    let mut out: Vec<Emission> = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if file.is_test_line(t.line) {
            continue;
        }
        if !matches!(&t.tok, Tok::Str(s) if s == "event") {
            continue;
        }
        for n in code.iter().skip(i + 1).take(8) {
            if let Tok::Str(s) = &n.tok {
                if is_kebab(s) && !out.iter().any(|e| &e.name == s) {
                    out.push(Emission { name: s.clone(), line: n.line });
                }
                break;
            }
        }
    }
    out
}

fn is_kebab(s: &str) -> bool {
    !s.is_empty()
        && s.starts_with(|c: char| c.is_ascii_lowercase())
        && s.chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-')
}

/// Backticked kebab-case names in the paragraph that starts at
/// `marker` and ends at the first blank line (None if no marker).
fn backticked_after(doc: &str, marker: &str) -> Option<Vec<String>> {
    let at = doc.find(marker)?;
    let rest = &doc[at + marker.len()..];
    let para = match rest.find("\n\n") {
        Some(end) => &rest[..end],
        None => rest,
    };
    let mut names = Vec::new();
    let mut parts = para.split('`');
    // odd-indexed split pieces are inside backticks
    while let (Some(_outside), Some(inside)) =
        (parts.next(), parts.next())
    {
        if is_kebab(inside) && !names.iter().any(|n| n == inside) {
            names.push(inside.to_string());
        }
    }
    Some(names)
}

/// Source line of the doc comment containing `marker` (1 if absent).
fn marker_line(file: &SourceFile, marker: &str) -> usize {
    file.toks
        .iter()
        .find(|t| {
            t.comment_text().is_some_and(|c| c.contains(marker))
        })
        .map_or(1, |t| t.line)
}

fn finding(file: &SourceFile, line: usize, message: String) -> Finding {
    Finding {
        pass: "protocol-sync",
        file: file.path.clone(),
        line,
        message,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{run as run_all, LintInput, SourceFile};

    fn input(src: &str) -> LintInput {
        LintInput {
            files: vec![SourceFile::from_source(
                "rust/src/serve/server.rs",
                src,
            )],
            design_md: String::new(),
        }
    }

    #[test]
    fn fixture_fires_in_both_directions() {
        let src = include_str!("fixtures/protocol_server_bad.rs");
        let fs = run(&input(src));
        let msgs: Vec<&str> =
            fs.iter().map(|f| f.message.as_str()).collect();
        // emitted but undocumented
        assert!(
            msgs.iter().any(|m| m.contains("`bad-json`")
                && m.contains("not listed")),
            "{msgs:?}"
        );
        // documented but never emitted
        assert!(
            msgs.iter().any(|m| m.contains("`bad-phantom`")
                && m.contains("never emitted")),
            "{msgs:?}"
        );
        // event drift, both directions
        assert!(
            msgs.iter().any(|m| m.contains("`token`")
                && m.contains("not listed")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("`heartbeat`")
                && m.contains("never emitted")),
            "{msgs:?}"
        );
    }

    #[test]
    fn missing_markers_are_a_finding() {
        let fs = run(&input("fn quiet() {}\n"));
        assert!(fs.iter().any(|f| f.message.contains("`Codes:`")));
        assert!(fs.iter().any(|f| f.message.contains("`Event kinds:`")));
    }

    #[test]
    fn fixture_waiver_suppresses_undocumented_emission() {
        let src = include_str!("fixtures/protocol_server_waived.rs");
        let report = run_all(&input(src));
        let left: Vec<_> = report
            .findings
            .iter()
            .filter(|f| f.pass == "protocol-sync")
            .collect();
        assert!(left.is_empty(), "waived fixture not clean: {left:?}");
        let s = report
            .summaries
            .iter()
            .find(|s| s.pass == "protocol-sync")
            .unwrap_or_else(|| panic!("no protocol-sync summary"));
        assert!(s.waivers_used >= 1);
    }

    #[test]
    fn coherent_doc_and_code_are_clean() {
        let src = "\
//! Codes: `boom` (an example).\n\
//!\n\
//! Event kinds: `err`.\n\
fn emit() -> Json {\n\
    err_reply(None, \"boom\", \"x\")\n\
}\n\
fn ev() -> (&'static str, Json) {\n\
    (\"event\", Json::str(\"err\"))\n\
}\n";
        let fs = run(&input(src));
        assert!(fs.is_empty(), "{fs:?}");
    }
}

//! Property-testing lite (offline stand-in for proptest).
//!
//! `property` runs a closure over many PCG-seeded random cases and, on
//! failure, retries with simpler shrink candidates produced by the
//! generator at smaller "size" budgets — a coarse but effective shrink.

use crate::util::Pcg64;

/// Generation budget passed to generators; `size` scales dimensions.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg64,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size.max(1));
        lo + self.rng.usize_below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.range_f32(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal_f32()).collect()
    }
}

/// Relative-closeness predicate used by the scan-conformance style
/// assertions: `|a - e| <= tol * (1 + max(|a|, |e|))`.  One definition so
/// the tolerance formula cannot drift between suites (the conformance
/// tolerance itself is 1e-5; callers pass a looser `tol` only where
/// deviations legitimately compound, and say so).
pub fn rel_close(a: f32, e: f32, tol: f32) -> bool {
    (a - e).abs() <= tol * (1.0 + a.abs().max(e.abs()))
}

/// f64 twin of [`rel_close`] (e.g. for JSON-roundtripped metrics).
pub fn rel_close64(a: f64, e: f64, tol: f64) -> bool {
    (a - e).abs() <= tol * (1.0 + a.abs().max(e.abs()))
}

/// Run `cases` random checks of `prop`.  `prop` returns Err(description)
/// on failure.  Panics with the seed and description so failures are
/// reproducible by re-running with `KLA_PROP_SEED`.
pub fn property<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("KLA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let size = 2 + case * 16 / cases.max(1); // grow sizes over cases
        let mut rng = Pcg64::seeded(seed);
        let mut g = Gen { rng: &mut rng, size: size.max(2) };
        if let Err(msg) = prop(&mut g) {
            // shrink: retry same seed at smaller sizes to find minimal repro
            let mut minimal = (size, msg.clone());
            for s in (1..size).rev() {
                let mut rng = Pcg64::seeded(seed);
                let mut g = Gen { rng: &mut rng, size: s };
                if let Err(m) = prop(&mut g) {
                    minimal = (s, m);
                }
            }
            panic!(
                "property {name:?} failed (seed {seed}, size {}): {}\n\
                 reproduce with KLA_PROP_SEED={seed}",
                minimal.0, minimal.1
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        property("add_commutes", 50, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            prop_assert!((a + b - (b + a)).abs() < 1e-9, "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property \"always_fails\" failed")]
    fn failing_property_panics_with_seed() {
        property("always_fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn sizes_grow() {
        let mut max_seen = 0usize;
        property("sizes", 20, |g| {
            let n = g.usize_in(1, 1000);
            if n > max_seen {
                max_seen = n;
            }
            Ok(())
        });
        assert!(max_seen > 2, "sizes never grew: {max_seen}");
    }
}

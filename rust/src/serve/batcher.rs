//! Continuous-batching scheduler (pure logic, no I/O — unit-testable).
//!
//! vLLM-style iteration-level scheduling adapted to recurrent models:
//! every engine iteration advances EVERY active slot by one token (prefill
//! tokens and decode tokens interleave freely since both are single
//! recurrent steps), admits queued requests into free slots, and retires
//! finished ones.  There is no KV-cache memory pressure — the belief state
//! is constant-size — so admission is purely slot-bound.

use std::collections::VecDeque;

use super::sampling::SamplerConfig;

/// A request as seen by the scheduler.
#[derive(Clone, Debug)]
pub struct SchedRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// How many tokens to sample.  0 is honoured: prefill only — the
    /// prompt is consumed (belief state advances, uncertainty reported)
    /// and the request finishes with empty `tokens`.
    pub max_new: usize,
    /// Per-request sampling & termination config.
    pub sampler: SamplerConfig,
    /// Counter-based RNG key (`sampling::request_key`), stamped by the
    /// engine at submit from `(engine seed, request id, client seed)`.
    pub key: u64,
    /// Prefix-cache participation (protocol `"cache": false` opts out):
    /// false bypasses both lookup at admit AND snapshot insertion during
    /// prefill, so an opted-out prompt never touches the shared cache.
    pub cache: bool,
}

impl SchedRequest {
    /// A request with the historical greedy behaviour (tests, defaults).
    pub fn greedy(id: u64, prompt: Vec<i32>, max_new: usize) -> Self {
        SchedRequest {
            id,
            prompt,
            max_new,
            sampler: SamplerConfig::greedy(),
            key: 0,
            cache: true,
        }
    }
}

/// Per-slot progress.
#[derive(Clone, Debug)]
pub enum Slot {
    Free,
    Active {
        id: u64,
        prompt: Vec<i32>,
        /// next prompt index to feed; >= prompt.len() means decoding
        cursor: usize,
        generated: Vec<i32>,
        max_new: usize,
        sampler: SamplerConfig,
        key: u64,
        /// See [`SchedRequest::cache`].
        cache: bool,
    },
}

impl Slot {
    pub fn is_free(&self) -> bool {
        matches!(self, Slot::Free)
    }
}

/// What the engine should feed a slot this iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Feed {
    /// Feed this token; output logits are ignored (prompt prefill).
    Prefill(i32),
    /// Feed this token; sample the output (last prompt token or a
    /// previously generated token).
    Decode(i32),
    /// Slot idle: feed PAD, ignore output.
    Idle,
}

/// Snapshot of one slot's prefill progress (see
/// [`Scheduler::prefill_view`]).
#[derive(Clone, Copy, Debug)]
pub struct PrefillView<'a> {
    pub prompt: &'a [i32],
    /// Next prompt index prefill will feed.
    pub cursor: usize,
    /// Trailing prompt tokens prefill never consumes (1 when the request
    /// samples: its last prompt token becomes a `Feed::Decode`; 0 for
    /// prefill-only requests).
    pub keep: usize,
    /// Prefix-cache participation ([`SchedRequest::cache`]).
    pub cache: bool,
}

impl PrefillView<'_> {
    /// Prompt tokens prefill will ever consume — the upper bound for
    /// prefix-cache matching (a cached offset beyond this could cover
    /// the token the sampled `Feed::Decode` step must still feed).
    pub fn usable(&self) -> usize {
        self.prompt.len() - self.keep
    }
}

/// One finished generation.
#[derive(Clone, Debug)]
pub struct Finished {
    pub id: u64,
    pub slot: usize,
    pub tokens: Vec<i32>,
}

/// Outcome of [`Scheduler::cancel`].
#[derive(Clone, Debug)]
pub enum Cancelled {
    /// The request was still queued: removed before it ever touched a
    /// slot (no tokens, no state to clean up).
    Queued,
    /// The request was active: the tokens generated so far come back,
    /// and — like [`Scheduler::advance`] — the slot stays occupied until
    /// `release` (the engine must reset belief state first).
    Active(Finished),
}

pub struct Scheduler {
    pub queue: VecDeque<SchedRequest>,
    pub slots: Vec<Slot>,
    pad: i32,
    /// Engine-owned (chunked) prefill mode: the engine drains prompts via
    /// [`Self::take_prefill`], so [`Self::feeds`] reports mid-prefill
    /// slots as `Feed::Idle` and [`Self::advance`] leaves their cursors
    /// alone.  Without this, the batched step between two chunked rounds
    /// would feed such a slot one stray `Feed::Prefill` token, drifting
    /// its cursor off the `k * chunk` grid the prefix cache aligns
    /// snapshots to.
    chunked: bool,
}

impl Scheduler {
    pub fn new(n_slots: usize, pad: i32) -> Self {
        Scheduler {
            queue: VecDeque::new(),
            slots: vec![Slot::Free; n_slots],
            pad,
            chunked: false,
        }
    }

    /// Switch the scheduler into engine-owned (chunked) prefill mode.
    /// The engine sets this once, iff it runs chunked prefill rounds.
    pub fn set_chunked_prefill(&mut self, chunked: bool) {
        self.chunked = chunked;
    }

    pub fn submit(&mut self, req: SchedRequest) {
        self.queue.push_back(req);
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(|s| !s.is_free())
    }

    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| !s.is_free()).count()
    }

    /// Admit queued requests into free slots; returns `(slot, request id)`
    /// pairs — the slot must be state-reset before the next step, and the
    /// id lets the engine stamp the admit time (queue time ends here, not
    /// at submit).
    pub fn admit(&mut self) -> Vec<(usize, u64)> {
        let mut admitted = Vec::new();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if !slot.is_free() {
                continue;
            }
            let Some(req) = self.queue.pop_front() else { break };
            let id = req.id;
            *slot = Slot::Active {
                id,
                prompt: if req.prompt.is_empty() {
                    vec![self.pad]
                } else {
                    req.prompt
                },
                cursor: 0,
                generated: Vec::new(),
                // max_new passes through unclamped: 0 means prefill-only
                // (the old `.max(1)` silently generated a token the
                // client never asked for)
                max_new: req.max_new,
                sampler: req.sampler,
                key: req.key,
                cache: req.cache,
            };
            admitted.push((i, id));
        }
        admitted
    }

    /// Take up to `max` prompt tokens from `slot` for chunked prefill,
    /// advancing its cursor (the cursor jumps, instead of moving one
    /// token per engine iteration through `Feed::Prefill`).  The LAST
    /// prompt token is never taken when the request will sample: it stays
    /// behind for a sampled `Feed::Decode` step, so chunked and
    /// token-per-iteration prefill hand the engine identical feeds from
    /// there on.  A `max_new == 0` request has nothing to sample, so its
    /// prompt is consumed to the very end (`take_prefill_only_finished`
    /// then retires it without a batched step).  Returns empty for free
    /// slots, slots with no prefill work left, and `max == 0`.
    pub fn take_prefill(&mut self, slot: usize, max: usize) -> Vec<i32> {
        let Some(Slot::Active { prompt, cursor, max_new, .. }) =
            self.slots.get_mut(slot)
        else {
            return Vec::new();
        };
        let keep = usize::from(*max_new > 0);
        if *cursor + keep >= prompt.len() {
            return Vec::new();
        }
        let hi = (*cursor + max).min(prompt.len() - keep);
        // cursor < hi <= len - keep, both pinned by the guards above
        let out = prompt[*cursor..hi].to_vec(); // lint: allow(panic, range bounded by the keep guard and min() above)
        *cursor = hi;
        out
    }

    /// Jump a slot's prefill cursor to `offset`: the engine restored a
    /// cached belief snapshot covering the first `offset` prompt tokens,
    /// so they must not be fed again.  Clamped so the cursor never moves
    /// backwards and the last prompt token of a sampling request stays
    /// behind for its `Feed::Decode` step (the same `keep` rule as
    /// [`Self::take_prefill`] — a full-prompt hit on a `max_new > 0`
    /// request therefore skips to `len - 1` and still samples from the
    /// restored state).  Returns how many tokens were actually skipped.
    pub fn skip_prefill(&mut self, slot: usize, offset: usize) -> usize {
        let Some(Slot::Active { prompt, cursor, max_new, .. }) =
            self.slots.get_mut(slot)
        else {
            return 0;
        };
        let keep = usize::from(*max_new > 0);
        let hi = offset.min(prompt.len() - keep).max(*cursor);
        let skipped = hi - *cursor;
        *cursor = hi;
        skipped
    }

    /// Read-only view of a slot's prefill progress — what the engine's
    /// prefix cache needs for lookup (at admit) and snapshot insertion
    /// (after each chunk): the prompt, the cursor, how many trailing
    /// tokens are held back for the sampled `Feed::Decode` step, and
    /// whether the request opted into caching.  `None` for free slots.
    pub fn prefill_view(&self, slot: usize) -> Option<PrefillView<'_>> {
        match self.slots.get(slot) {
            Some(Slot::Active { prompt, cursor, max_new, cache, .. }) => {
                Some(PrefillView {
                    prompt,
                    cursor: *cursor,
                    keep: usize::from(*max_new > 0),
                    cache: *cache,
                })
            }
            _ => None,
        }
    }

    /// Retire `max_new == 0` requests whose prompt has been fully
    /// consumed by chunked prefill: they finish with empty tokens WITHOUT
    /// a batched step, so the reported uncertainty reflects exactly the
    /// prompt (no stray pad feed).  Like `advance`, slots stay occupied
    /// until `release`.  (On the legacy token-per-iteration path the last
    /// prompt token arrives as a `Feed::Prefill` and `advance` retires
    /// the request instead.)
    pub fn take_prefill_only_finished(&mut self) -> Vec<Finished> {
        let mut done = Vec::new();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let Slot::Active { id, prompt, cursor, max_new, .. } = slot
            else {
                continue;
            };
            if *max_new == 0 && *cursor >= prompt.len() {
                done.push(Finished { id: *id, slot: i, tokens: Vec::new() });
            }
        }
        done
    }

    /// Tokens to feed this iteration, one per slot.
    pub fn feeds(&self) -> Vec<Feed> {
        self.slots
            .iter()
            .map(|slot| match slot {
                Slot::Free => Feed::Idle,
                Slot::Active { prompt, cursor, generated, max_new, .. } => {
                    let keep = usize::from(*max_new > 0);
                    if self.chunked && *cursor + keep < prompt.len() {
                        // engine-owned prefill: the next chunked round
                        // consumes these tokens; feeding one here would
                        // drift the cursor off the chunk grid
                        Feed::Idle
                    } else if *cursor < prompt.len() {
                        let tok = prompt[*cursor]; // lint: allow(panic, index guarded by the branch condition)
                        if *cursor + 1 == prompt.len() && *max_new > 0 {
                            Feed::Decode(tok) // last prompt token: sample
                        } else {
                            // mid-prompt, or a prefill-only request whose
                            // last token needs no sampling
                            Feed::Prefill(tok)
                        }
                    } else if *max_new == 0 {
                        // prefill-only request already fully consumed
                        // (awaiting take_prefill_only_finished): nothing
                        // to feed, nothing to sample
                        Feed::Idle
                    } else {
                        // feed the last generated token, sample again
                        Feed::Decode(*generated.last().unwrap_or(&self.pad))
                    }
                }
            })
            .collect()
    }

    /// Sampling context for the token a slot is about to emit: the
    /// request's [`SamplerConfig`], its RNG key, and the per-request draw
    /// counter (tokens sampled so far).  Counter-based: the draw for
    /// token `t` of a request depends only on `(key, t)`, never on batch
    /// composition, slot assignment, or prefill chunking.
    pub fn sampling_lane(&self, slot: usize)
                         -> Option<(&SamplerConfig, u64, u64)> {
        match self.slots.get(slot) {
            Some(Slot::Active { sampler, key, generated, .. }) => {
                Some((sampler, *key, generated.len() as u64))
            }
            _ => None,
        }
    }

    /// Apply the engine's sampled tokens (one per slot; ignored for idle /
    /// prefill slots).  Returns finished requests (their slots stay
    /// occupied until `release` — the engine must free state first).
    /// A request finishes when it has `max_new` tokens OR its sampled
    /// token is one of its stop tokens (stop ids inside the prompt never
    /// terminate — only sampled tokens are checked).
    pub fn advance(&mut self, sampled: &[i32]) -> Vec<Finished> {
        let chunked = self.chunked;
        let mut done = Vec::new();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let Slot::Active {
                id, prompt, cursor, generated, max_new, sampler, ..
            } = slot
            else {
                continue;
            };
            // mirror of feeds(): a mid-prefill slot in engine-owned
            // (chunked) mode was fed nothing this step, so its cursor
            // must not move
            let keep = usize::from(*max_new > 0);
            if chunked && *cursor + keep < prompt.len() {
                continue;
            }
            // tolerate a short `sampled` (fewer rows than slots): the
            // lane simply keeps its pad — advance never panics on the
            // engine's behalf
            let tok = sampled.get(i).copied();
            let mut pushed = None;
            if *cursor < prompt.len() {
                let sampled_now =
                    *cursor + 1 == prompt.len() && *max_new > 0;
                *cursor += 1;
                if sampled_now {
                    if let Some(t) = tok {
                        generated.push(t);
                        pushed = Some(t);
                    }
                }
            } else if *max_new > 0 {
                if let Some(t) = tok {
                    generated.push(t);
                    pushed = Some(t);
                }
            }
            let stop_hit = pushed.is_some_and(|t| sampler.is_stop(t));
            if stop_hit
                || (*cursor >= prompt.len() && generated.len() >= *max_new)
            {
                done.push(Finished {
                    id: *id,
                    slot: i,
                    tokens: std::mem::take(generated),
                });
            }
        }
        done
    }

    /// Cancel a request by engine id, wherever it is in its lifecycle:
    /// still queued (dropped, `Cancelled::Queued`), or active in a slot
    /// (`Cancelled::Active` with the tokens generated so far; the slot
    /// stays occupied until `release`, mirroring `advance`'s contract so
    /// the engine resets belief state before the slot is reused).
    /// `None` means the id is unknown — already finished or never
    /// submitted — and nothing changed.
    pub fn cancel(&mut self, id: u64) -> Option<Cancelled> {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            self.queue.remove(pos);
            return Some(Cancelled::Queued);
        }
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Slot::Active { id: sid, generated, .. } = slot {
                if *sid == id {
                    return Some(Cancelled::Active(Finished {
                        id,
                        slot: i,
                        tokens: std::mem::take(generated),
                    }));
                }
            }
        }
        None
    }

    /// The engine id occupying a slot (None for free slots) — the
    /// engine's per-token event stream uses it to route each sampled
    /// token to its request's sink.
    pub fn slot_id(&self, slot: usize) -> Option<u64> {
        match self.slots.get(slot) {
            Some(Slot::Active { id, .. }) => Some(*id),
            _ => None,
        }
    }

    pub fn release(&mut self, slot: usize) {
        if let Some(s) = self.slots.get_mut(slot) {
            *s = Slot::Free;
        }
    }

    pub fn pad(&self) -> i32 {
        self.pad
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drive(sched: &mut Scheduler, iters: usize) -> Vec<Finished> {
        let mut all = Vec::new();
        for step in 0..iters {
            sched.admit();
            let feeds = sched.feeds();
            // fake engine: "sample" token 100 + step
            let sampled: Vec<i32> =
                feeds.iter().map(|_| 100 + step as i32).collect();
            let done = sched.advance(&sampled);
            for f in &done {
                sched.release(f.slot);
            }
            all.extend(done);
            if !sched.has_work() {
                break;
            }
        }
        all
    }

    #[test]
    fn single_request_lifecycle() {
        let mut s = Scheduler::new(2, 0);
        s.submit(SchedRequest::greedy(1, vec![5, 6, 7], 3));
        let done = drive(&mut s, 20);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(done[0].tokens.len(), 3);
        assert!(!s.has_work());
    }

    #[test]
    fn prefill_then_decode_feeds() {
        let mut s = Scheduler::new(1, 0);
        s.submit(SchedRequest::greedy(9, vec![5, 6], 2));
        s.admit();
        assert_eq!(s.feeds(), vec![Feed::Prefill(5)]);
        s.advance(&[0]);
        assert_eq!(s.feeds(), vec![Feed::Decode(6)]); // last prompt token
        s.advance(&[42]);
        assert_eq!(s.feeds(), vec![Feed::Decode(42)]); // generated token
    }

    #[test]
    fn continuous_batching_overlaps_requests() {
        let mut s = Scheduler::new(2, 0);
        s.submit(SchedRequest::greedy(1, vec![1; 10], 5));
        s.submit(SchedRequest::greedy(2, vec![2], 2));
        s.submit(SchedRequest::greedy(3, vec![3], 2));
        s.admit();
        // both slots busy, third queued
        assert_eq!(s.active_count(), 2);
        assert_eq!(s.queue.len(), 1);
        let done = drive(&mut s, 40);
        assert_eq!(done.len(), 3);
        // short request finishes before the long one
        assert_eq!(done[0].id, 2);
    }

    #[test]
    fn admit_reports_slot_and_id() {
        let mut s = Scheduler::new(2, 0);
        s.submit(SchedRequest::greedy(7, vec![1], 1));
        s.submit(SchedRequest::greedy(8, vec![2], 1));
        s.submit(SchedRequest::greedy(9, vec![3], 1));
        let adm = s.admit();
        assert_eq!(adm, vec![(0, 7), (1, 8)]);
        assert!(s.admit().is_empty()); // no free slots left
        assert_eq!(s.queue.len(), 1); // id 9 still waiting
    }

    #[test]
    fn empty_prompt_handled() {
        let mut s = Scheduler::new(1, 0);
        s.submit(SchedRequest::greedy(4, vec![], 1));
        let done = drive(&mut s, 5);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 1);
    }

    #[test]
    fn idle_slots_feed_pad() {
        let s = Scheduler::new(3, 7);
        assert_eq!(s.feeds(), vec![Feed::Idle; 3]);
    }

    // ------------------------------------------------- edge cases -----

    #[test]
    fn max_new_zero_is_prefill_only() {
        // regression: `max_new: req.max_new.max(1)` used to silently
        // generate a token the client never asked for.  Now the prompt is
        // consumed as pure prefill and the request finishes empty.
        let mut s = Scheduler::new(1, 0);
        s.submit(SchedRequest::greedy(1, vec![4, 5], 0));
        s.admit();
        assert_eq!(s.feeds(), vec![Feed::Prefill(4)]);
        assert!(s.advance(&[9]).is_empty());
        // the LAST prompt token is still a Prefill feed — nothing will
        // ever be sampled for this request
        assert_eq!(s.feeds(), vec![Feed::Prefill(5)]);
        let done = s.advance(&[9]);
        assert_eq!(done.len(), 1);
        assert!(done[0].tokens.is_empty());
        s.release(done[0].slot);
        assert!(!s.has_work());
    }

    #[test]
    fn max_new_zero_chunked_prefill_consumes_whole_prompt() {
        // the chunked path: take_prefill keeps no token back (there is no
        // Decode step to hold it for) and take_prefill_only_finished
        // retires the request without a batched step
        let mut s = Scheduler::new(1, 0);
        s.submit(SchedRequest::greedy(1, vec![1, 2, 3], 0));
        s.admit();
        assert!(s.take_prefill_only_finished().is_empty());
        assert_eq!(s.take_prefill(0, 100), vec![1, 2, 3]);
        assert!(s.take_prefill(0, 100).is_empty());
        // nothing left to feed or sample
        assert_eq!(s.feeds(), vec![Feed::Idle]);
        let done = s.take_prefill_only_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert!(done[0].tokens.is_empty());
        s.release(done[0].slot);
        assert!(!s.has_work());
        // a sampling request is never retired by the prefill-only sweep
        s.submit(SchedRequest::greedy(2, vec![1, 2, 3], 1));
        s.admit();
        assert_eq!(s.take_prefill(0, 100), vec![1, 2]);
        assert!(s.take_prefill_only_finished().is_empty());
        assert_eq!(s.feeds(), vec![Feed::Decode(3)]);
    }

    #[test]
    fn stop_token_terminates_early_and_is_included() {
        let mut s = Scheduler::new(1, 0);
        let mut req = SchedRequest::greedy(1, vec![5], 4);
        req.sampler.stop_tokens = vec![42];
        s.submit(req);
        s.admit();
        assert_eq!(s.feeds(), vec![Feed::Decode(5)]);
        assert!(s.advance(&[7]).is_empty()); // 7 is not a stop
        let done = s.advance(&[42]);
        assert_eq!(done.len(), 1);
        // terminated at 2 of 4 tokens; the stop token IS in the output
        assert_eq!(done[0].tokens, vec![7, 42]);
    }

    #[test]
    fn stop_token_on_first_sampled_token_and_not_in_prompt() {
        // stop id 5 appears in the PROMPT: prefill must not terminate
        let mut s = Scheduler::new(1, 0);
        let mut req = SchedRequest::greedy(1, vec![5, 5, 6], 3);
        req.sampler.stop_tokens = vec![5];
        s.submit(req);
        s.admit();
        assert_eq!(s.feeds(), vec![Feed::Prefill(5)]);
        assert!(s.advance(&[5]).is_empty()); // prefill output ignored
        assert_eq!(s.feeds(), vec![Feed::Prefill(5)]);
        assert!(s.advance(&[5]).is_empty());
        // first SAMPLED token (at the last prompt token) is the stop
        assert_eq!(s.feeds(), vec![Feed::Decode(6)]);
        let done = s.advance(&[5]);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens, vec![5]);
    }

    #[test]
    fn sampling_lane_exposes_config_key_and_counter() {
        let mut s = Scheduler::new(2, 0);
        let mut req = SchedRequest::greedy(1, vec![5, 6], 3);
        req.sampler.temperature = 0.8;
        req.key = 0xdead_beef;
        s.submit(req);
        s.admit();
        // free slot has no lane
        assert!(s.sampling_lane(1).is_none());
        let (cfg, key, counter) = s.sampling_lane(0).unwrap();
        assert_eq!(cfg.temperature, 0.8);
        assert_eq!(key, 0xdead_beef);
        assert_eq!(counter, 0);
        s.advance(&[9]); // prefill token
        assert_eq!(s.sampling_lane(0).unwrap().2, 0); // still no samples
        s.advance(&[9]); // last prompt token: first sample
        // counter == tokens sampled so far, independent of prompt length
        assert_eq!(s.sampling_lane(0).unwrap().2, 1);
        s.advance(&[9]);
        assert_eq!(s.sampling_lane(0).unwrap().2, 2);
    }

    #[test]
    fn max_new_one_samples_exactly_at_last_prompt_token() {
        let mut s = Scheduler::new(1, 0);
        s.submit(SchedRequest::greedy(2, vec![1, 2, 3], 1));
        s.admit();
        assert_eq!(s.feeds(), vec![Feed::Prefill(1)]);
        assert!(s.advance(&[9]).is_empty());
        assert_eq!(s.feeds(), vec![Feed::Prefill(2)]);
        assert!(s.advance(&[9]).is_empty());
        // last prompt token: the output of this step IS the one token
        assert_eq!(s.feeds(), vec![Feed::Decode(3)]);
        let done = s.advance(&[42]);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens, vec![42]);
    }

    #[test]
    fn admit_is_fifo_under_slot_starvation() {
        // one slot, four queued requests: completion order must follow
        // submission order exactly (no overtaking when slots free up)
        let mut s = Scheduler::new(1, 0);
        for id in 1..=4u64 {
            s.submit(SchedRequest::greedy(id, vec![id as i32], 2));
        }
        let done = drive(&mut s, 40);
        let order: Vec<u64> = done.iter().map(|f| f.id).collect();
        assert_eq!(order, vec![1, 2, 3, 4]);
        // while the slot is held, admit() must not touch the queue
        let mut s = Scheduler::new(1, 0);
        s.submit(SchedRequest::greedy(9, vec![1], 5));
        assert_eq!(s.admit().len(), 1);
        s.submit(SchedRequest::greedy(10, vec![2], 1));
        assert!(s.admit().is_empty());
        assert_eq!(s.queue.len(), 1);
        assert_eq!(s.queue[0].id, 10);
    }

    #[test]
    fn take_prefill_jumps_cursor_but_leaves_last_prompt_token() {
        let mut s = Scheduler::new(2, 0);
        s.submit(SchedRequest::greedy(1, (1..=10).collect(), 2));
        s.admit();
        // free slot: nothing to prefill
        assert!(s.take_prefill(1, 4).is_empty());
        // chunked consumption: 4 + 4 + 1 (token 10 is held back)
        assert_eq!(s.take_prefill(0, 4), vec![1, 2, 3, 4]);
        assert_eq!(s.take_prefill(0, 4), vec![5, 6, 7, 8]);
        assert_eq!(s.take_prefill(0, 4), vec![9]);
        assert!(s.take_prefill(0, 4).is_empty());
        // the last prompt token still arrives as a sampled Decode feed
        assert_eq!(s.feeds()[0], Feed::Decode(10));
        // decode proceeds as if the prompt had been fed token by token
        let done = s.advance(&[42, 0]);
        assert!(done.is_empty());
        assert_eq!(s.feeds()[0], Feed::Decode(42));
    }

    #[test]
    fn take_prefill_edge_cases() {
        let mut s = Scheduler::new(1, 7);
        // empty prompt becomes a single PAD token: no prefill work
        s.submit(SchedRequest::greedy(1, vec![], 1));
        s.admit();
        assert!(s.take_prefill(0, 8).is_empty());
        assert_eq!(s.feeds(), vec![Feed::Decode(7)]);
        s.advance(&[3]);
        s.release(0);
        // single-token prompt: no prefill either
        s.submit(SchedRequest::greedy(2, vec![5], 1));
        s.admit();
        assert!(s.take_prefill(0, 8).is_empty());
        // chunk larger than the prompt: one call takes all but the last
        s.release(0);
        s.submit(SchedRequest::greedy(3, vec![1, 2, 3], 1));
        s.admit();
        assert_eq!(s.take_prefill(0, 100), vec![1, 2]);
        // max == 0 takes nothing
        s.release(0);
        s.submit(SchedRequest::greedy(4, vec![1, 2, 3], 1));
        s.admit();
        assert!(s.take_prefill(0, 0).is_empty());
        assert_eq!(s.feeds(), vec![Feed::Prefill(1)]);
    }

    #[test]
    fn skip_prefill_jumps_cursor_within_the_keep_rule() {
        let mut s = Scheduler::new(2, 0);
        s.submit(SchedRequest::greedy(1, (1..=10).collect(), 2));
        s.admit();
        // free slot: nothing to skip
        assert_eq!(s.skip_prefill(1, 4), 0);
        // jump to a restored offset; the remainder chunks from there
        assert_eq!(s.skip_prefill(0, 4), 4);
        assert_eq!(s.take_prefill(0, 100), vec![5, 6, 7, 8, 9]);
        assert_eq!(s.feeds()[0], Feed::Decode(10));
        // the cursor never moves backwards
        assert_eq!(s.skip_prefill(0, 2), 0);
        assert_eq!(s.feeds()[0], Feed::Decode(10));
        // a sampling request keeps its last prompt token even for a
        // full-prompt hit: offset 10 clamps to 9
        s.release(0);
        s.submit(SchedRequest::greedy(2, (1..=10).collect(), 1));
        s.admit();
        assert_eq!(s.skip_prefill(0, 10), 9);
        assert!(s.take_prefill(0, 100).is_empty());
        assert_eq!(s.feeds()[0], Feed::Decode(10));
        // a prefill-only request (max_new 0) may skip the WHOLE prompt;
        // take_prefill_only_finished then retires it without a step
        s.release(0);
        s.submit(SchedRequest::greedy(3, vec![1, 2, 3], 0));
        s.admit();
        assert_eq!(s.skip_prefill(0, 3), 3);
        assert_eq!(s.take_prefill_only_finished().len(), 1);
    }

    #[test]
    fn prefill_view_exposes_progress_and_cache_opt_out() {
        let mut s = Scheduler::new(2, 0);
        assert!(s.prefill_view(0).is_none());
        let mut req = SchedRequest::greedy(1, vec![5, 6, 7, 8], 2);
        req.cache = false;
        s.submit(req);
        s.admit();
        let v = s.prefill_view(0).unwrap();
        assert_eq!(v.prompt, &[5, 6, 7, 8]);
        assert_eq!((v.cursor, v.keep, v.usable()), (0, 1, 3));
        assert!(!v.cache, "opt-out must be visible to the engine");
        s.take_prefill(0, 2);
        assert_eq!(s.prefill_view(0).unwrap().cursor, 2);
        // prefill-only request: keep 0, whole prompt usable
        s.submit(SchedRequest::greedy(2, vec![9, 9], 0));
        s.admit();
        let v = s.prefill_view(1).unwrap();
        assert_eq!((v.keep, v.usable(), v.cache), (0, 2, true));
    }

    #[test]
    fn cancel_drops_queued_and_retires_active_requests() {
        let mut s = Scheduler::new(1, 0);
        s.submit(SchedRequest::greedy(1, vec![1, 2], 8));
        s.submit(SchedRequest::greedy(2, vec![3], 8));
        s.admit();
        // id 2 never got a slot: cancelling it only touches the queue
        assert!(matches!(s.cancel(2), Some(Cancelled::Queued)));
        assert!(s.queue.is_empty());
        assert_eq!(s.active_count(), 1);
        // advance id 1 into decode so it has generated tokens
        assert!(s.advance(&[7]).is_empty()); // prefill token
        assert!(s.advance(&[8]).is_empty()); // last prompt token: sampled
        let Some(Cancelled::Active(f)) = s.cancel(1) else {
            panic!("active request must cancel as Active");
        };
        assert_eq!(f.id, 1);
        assert_eq!(f.slot, 0);
        assert_eq!(f.tokens, vec![8]);
        // like advance(), the slot stays occupied until release
        assert_eq!(s.active_count(), 1);
        s.release(f.slot);
        assert!(!s.has_work());
        // unknown / already-cancelled ids are a no-op
        assert!(s.cancel(1).is_none());
        assert!(s.cancel(99).is_none());
        // the freed slot admits the next submission
        s.submit(SchedRequest::greedy(3, vec![5], 1));
        assert_eq!(s.admit(), vec![(0, 3)]);
    }

    #[test]
    fn slot_id_maps_slots_to_requests() {
        let mut s = Scheduler::new(2, 0);
        assert_eq!(s.slot_id(0), None);
        s.submit(SchedRequest::greedy(42, vec![1], 1));
        s.admit();
        assert_eq!(s.slot_id(0), Some(42));
        assert_eq!(s.slot_id(1), None);
        s.release(0);
        assert_eq!(s.slot_id(0), None);
    }

    #[test]
    fn has_work_and_active_count_through_full_lifecycle() {
        let mut s = Scheduler::new(2, 0);
        // idle: no work, no active slots
        assert!(!s.has_work());
        assert_eq!(s.active_count(), 0);
        // queued but not admitted: work pending, still zero active
        s.submit(SchedRequest::greedy(1, vec![5], 1));
        assert!(s.has_work());
        assert_eq!(s.active_count(), 0);
        // admitted: one active slot, queue drained
        s.admit();
        assert_eq!(s.active_count(), 1);
        assert!(s.queue.is_empty());
        assert!(s.has_work());
        // feeds always covers every slot (active + idle)
        assert_eq!(s.feeds().len(), 2);
        // finished requests keep their slot until release (the engine
        // must free belief state first)
        let done = s.advance(&[7]);
        assert_eq!(done.len(), 1);
        assert_eq!(s.active_count(), 1);
        assert!(s.has_work());
        // released: back to fully idle
        s.release(done[0].slot);
        assert_eq!(s.active_count(), 0);
        assert!(!s.has_work());
    }

    // ---------------------------- engine-owned (chunked) prefill -----

    #[test]
    fn chunked_mode_idles_mid_prefill_slots_and_freezes_cursors() {
        // regression for the alignment-drift bug: between two chunked
        // rounds, feeds() used to hand the engine one Feed::Prefill token
        // for a mid-prefill slot and advance() bumped its cursor, so
        // cursors landed at k*(chunk+1) and block-aligned snapshot
        // insertion never fired after the first chunk
        let mut s = Scheduler::new(2, 0);
        s.set_chunked_prefill(true);
        s.submit(SchedRequest::greedy(1, (1..=10).collect(), 2));
        s.submit(SchedRequest::greedy(2, vec![7], 2)); // already at Decode
        s.admit();
        assert_eq!(s.take_prefill(0, 4), vec![1, 2, 3, 4]);
        // slot 0 is mid-prefill: Idle, NOT Prefill(5)
        assert_eq!(s.feeds(), vec![Feed::Idle, Feed::Decode(7)]);
        let done = s.advance(&[99, 42]);
        assert!(done.is_empty());
        // slot 0's cursor did not move; the next chunk starts at 5
        assert_eq!(s.prefill_view(0).unwrap().cursor, 4);
        assert_eq!(s.take_prefill(0, 4), vec![5, 6, 7, 8]);
        assert_eq!(s.take_prefill(0, 4), vec![9]);
        // prefill done: the held-back token is a sampled Decode feed
        assert_eq!(s.feeds()[0], Feed::Decode(10));
        // slot 1 kept decoding normally throughout
        assert_eq!(s.feeds()[1], Feed::Decode(42));
    }

    #[test]
    fn chunked_mode_idles_prefill_only_requests_until_consumed() {
        // max_new == 0 in chunked mode: never fed by the batched step,
        // retired by take_prefill_only_finished once fully consumed
        let mut s = Scheduler::new(1, 0);
        s.set_chunked_prefill(true);
        s.submit(SchedRequest::greedy(1, vec![1, 2, 3], 0));
        s.admit();
        assert_eq!(s.feeds(), vec![Feed::Idle]);
        s.advance(&[9]);
        assert_eq!(s.prefill_view(0).unwrap().cursor, 0);
        assert_eq!(s.take_prefill(0, 2), vec![1, 2]);
        assert_eq!(s.feeds(), vec![Feed::Idle]);
        assert!(s.take_prefill_only_finished().is_empty());
        assert_eq!(s.take_prefill(0, 2), vec![3]);
        assert_eq!(s.take_prefill_only_finished().len(), 1);
    }

    #[test]
    fn legacy_mode_still_feeds_prefill_tokens() {
        // without the flag, behaviour is unchanged (XLA fallback path)
        let mut s = Scheduler::new(1, 0);
        s.submit(SchedRequest::greedy(1, vec![5, 6, 7], 1));
        s.admit();
        assert_eq!(s.feeds(), vec![Feed::Prefill(5)]);
        s.advance(&[9]);
        assert_eq!(s.prefill_view(0).unwrap().cursor, 1);
    }

    // --------------------------------- cursor invariants (property) --

    #[test]
    fn prefill_cursor_invariants_hold_under_random_take_and_skip() {
        // Property test over take_prefill / skip_prefill: across random
        // prompt lengths, chunk sizes, and skip offsets the cursor
        //   (a) never passes len - keep,
        //   (b) never moves backwards,
        //   (c) every take returns exactly prompt[before..after] — so
        //       with no skips the concatenated takes are a prompt prefix.
        let mut rng = crate::util::Pcg64::seeded(0x5eed_cafe);
        for trial in 0..500u64 {
            let len = 1 + (rng.next_u64() % 64) as usize;
            let max_new = [0usize, 1, 4][(rng.next_u64() % 3) as usize];
            let keep = usize::from(max_new > 0);
            let prompt: Vec<i32> = (0..len as i32).map(|t| t * 3 + 1).collect();
            let mut s = Scheduler::new(1, -1);
            s.set_chunked_prefill(true);
            s.submit(SchedRequest::greedy(trial, prompt.clone(), max_new));
            s.admit();
            let mut cursor = 0usize;
            let mut taken: Vec<i32> = Vec::new();
            let mut skipped_any = false;
            for _ in 0..12 {
                let view = s.prefill_view(0).unwrap();
                assert_eq!(view.cursor, cursor, "trial {trial}");
                if rng.next_u64() % 4 == 0 {
                    let offset = (rng.next_u64() % (len as u64 + 5)) as usize;
                    let skipped = s.skip_prefill(0, offset);
                    let expect =
                        offset.min(len - keep).max(cursor) - cursor;
                    assert_eq!(skipped, expect, "trial {trial}");
                    if skipped > 0 {
                        skipped_any = true;
                    }
                    cursor += skipped;
                } else {
                    let chunk = (rng.next_u64() % 17) as usize;
                    let toks = s.take_prefill(0, chunk);
                    // (c) each take is exactly the next prompt slice
                    assert_eq!(toks, &prompt[cursor..cursor + toks.len()],
                               "trial {trial}");
                    assert!(toks.len() <= chunk, "trial {trial}");
                    cursor += toks.len();
                    taken.extend_from_slice(&toks);
                }
                // (a) the held-back token is never consumed or skipped
                assert!(cursor <= len - keep, "trial {trial}");
                // (b) monotone: prefill_view re-checked at loop top
            }
            if !skipped_any {
                // no skips: the takes concatenate to a prompt prefix
                assert_eq!(taken, &prompt[..cursor], "trial {trial}");
            }
            // drained: nothing further to take, cursor parked at len-keep
            // after a big final take
            s.take_prefill(0, len);
            assert_eq!(s.prefill_view(0).unwrap().cursor, len - keep);
            assert!(s.take_prefill(0, len).is_empty());
        }
    }
}

//! Per-request sampling & termination (DESIGN.md §S15).
//!
//! A [`SamplerConfig`] composes the classic decoding controls — greedy,
//! temperature, top-k, top-p — plus two KLA-specific pieces:
//!
//! - **uncertainty-scaled temperature**: the serving engine already
//!   computes each slot's mean posterior variance (the belief-state
//!   uncertainty the paper surfaces, `BeliefStateCache::slot_uncertainty`).
//!   With `uncertainty_temp = c > 0` the effective temperature becomes
//!   `tau * (1 + c * u)` — the model samples more conservatively where its
//!   belief is precise and more exploratorily where it is diffuse, in the
//!   spirit of Robust Filter Attention's precision-weighted estimation.
//! - **stop tokens**: sampling a token in `stop_tokens` terminates the
//!   request early (the stop token IS included in the returned tokens).
//!   Stop ids appearing inside the *prompt* never terminate anything —
//!   only sampled tokens are checked.
//!
//! **Determinism contract.** Draws are *counter-based*: the uniform used
//! for token `t` of a request is a pure function of `(key, t)` where
//! `key = request_key(engine seed, request id, client seed)`.  No RNG
//! state is shared across slots or steps, so the DRAWS a request sees are
//! identical regardless of batch composition, slot assignment, and
//! prefill chunking.  Token identity follows wherever the logits are
//! identical too: the native model computes each lane independently, so
//! with an explicit client `seed` the same `(engine seed, client seed,
//! prompt, sampler, prefill chunk)` reproduces token-for-token across
//! server restarts, batch widths, and slot assignments.  Across
//! *different* prefill chunk sizes the logits agree only to the 1e-5
//! scan-conformance tolerance (different scan plans), so a draw landing
//! within 1e-5 of a CDF boundary can — rarely — pick a different token;
//! greedy requests inherit the same caveat the chunked-prefill parity
//! pin documents.  Without a client seed the key falls back to
//! `(engine seed, request id)` — stable for a fixed arrival order.
//!
//! Greedy is the exact special case: `temperature == 0`, `top_k == 1`,
//! `top_p -> 0`, and `temperature <= 1e-6` all reduce to the NaN-aware
//! argmax ([`crate::tensor::argmax_row`]), bit-identical to the engine's
//! old batched `argmax_last` path.

use anyhow::{bail, Result};

use crate::config::ServeConfig;
use crate::tensor::argmax_row;
use crate::util::cast;

/// Temperatures at or below this are treated as exactly greedy, so the
/// "temperature -> 0 reproduces greedy" property holds token-for-token
/// instead of merely with overwhelming probability.
pub const GREEDY_TEMPERATURE: f32 = 1e-6;

/// Per-request sampling & termination configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplerConfig {
    /// Softmax temperature; `<= GREEDY_TEMPERATURE` means greedy argmax.
    pub temperature: f32,
    /// Keep only the `top_k` highest logits before sampling (0 = off;
    /// 1 = greedy).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest probability-sorted prefix with
    /// cumulative mass >= `top_p` (>= 1.0 = off; -> 0 = greedy).
    pub top_p: f32,
    /// Explicit client seed; see the determinism contract above.
    pub seed: Option<u64>,
    /// Uncertainty->temperature coupling coefficient `c` in
    /// `tau_eff = tau * (1 + c * u)`; 0 = off.
    pub uncertainty_temp: f32,
    /// Sampling any of these ids terminates the request early (the stop
    /// token is included in the output).
    pub stop_tokens: Vec<i32>,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self::greedy()
    }
}

impl SamplerConfig {
    /// The engine's historical behaviour: deterministic argmax, no stops.
    pub fn greedy() -> Self {
        SamplerConfig {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: None,
            uncertainty_temp: 0.0,
            stop_tokens: Vec::new(),
        }
    }

    /// Server-wide defaults from [`ServeConfig`] (per-request protocol
    /// fields override them; the config never carries a seed).
    pub fn from_serve(cfg: &ServeConfig) -> Self {
        SamplerConfig {
            temperature: cfg.temperature as f32,
            top_k: cfg.top_k,
            top_p: cfg.top_p as f32,
            seed: None,
            uncertainty_temp: cfg.uncertainty_temp as f32,
            stop_tokens: cfg.stop_tokens.clone(),
        }
    }

    /// Degenerate configs that reduce to exact argmax.
    pub fn is_greedy(&self) -> bool {
        self.temperature <= GREEDY_TEMPERATURE || self.top_k == 1
    }

    /// `tau * (1 + c * u)`, with non-finite or negative uncertainty
    /// ignored (a slot's mean posterior variance is >= 0 by construction;
    /// anything else is a numerical accident that must not poison the
    /// temperature).
    pub fn effective_temperature(&self, uncertainty: f32) -> f32 {
        let u = if uncertainty.is_finite() { uncertainty.max(0.0) } else { 0.0 };
        self.temperature * (1.0 + self.uncertainty_temp * u)
    }

    pub fn is_stop(&self, tok: i32) -> bool {
        self.stop_tokens.contains(&tok)
    }

    /// Boot-time validation (server defaults and CLI flags go through
    /// this; per-request fields are validated protocol-side with
    /// structured error replies).
    pub fn validate(&self) -> Result<()> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            bail!("temperature must be finite and >= 0, got {}",
                  self.temperature);
        }
        if !self.top_p.is_finite() || self.top_p <= 0.0 {
            bail!("top_p must be in (0, 1] (>= 1 disables), got {}",
                  self.top_p);
        }
        if !self.uncertainty_temp.is_finite() || self.uncertainty_temp < 0.0 {
            bail!("uncertainty_temp must be finite and >= 0, got {}",
                  self.uncertainty_temp);
        }
        Ok(())
    }
}

/// SplitMix64 finalizer — the bijective mixer behind the counter-based
/// draws (Steele et al. 2014; same construction the JAX threefry-style
/// key-splitting relies on conceptually: statelessness via hashing).
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Derive a request's RNG key.  Explicit client seeds make the key
/// independent of the engine-assigned request id (and therefore of
/// arrival order and batch composition); otherwise the key is a stable
/// function of `(engine seed, request id)`.
pub fn request_key(engine_seed: u64, request_id: u64,
                   client_seed: Option<u64>) -> u64 {
    match client_seed {
        Some(s) => splitmix64(splitmix64(s ^ 0x5eed_5eed_5eed_5eed)
            ^ engine_seed.rotate_left(32)),
        None => splitmix64(splitmix64(engine_seed) ^ request_id),
    }
}

/// One uniform draw in [0, 1) that depends ONLY on `(key, counter)` —
/// counter-based, no carried RNG state.
pub fn draw(key: u64, counter: u64) -> f64 {
    let x = splitmix64(
        key ^ splitmix64(counter.wrapping_add(0x517c_c1b7_2722_0a95)));
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Sample one token from a logits row under `cfg`, using the counter-based
/// draw for `(key, counter)`.  `counter` is the number of tokens this
/// request has sampled so far; `uncertainty` is the slot's current mean
/// posterior variance (only read when `uncertainty_temp != 0`).
///
/// NaN logits are excluded from the support entirely (and the greedy path
/// shares [`argmax_row`]'s NaN handling); an all-NaN row debug-asserts
/// and falls back to token 0.
pub fn sample(logits: &[f32], cfg: &SamplerConfig, key: u64, counter: u64,
              uncertainty: f32) -> i32 {
    debug_assert!(!logits.is_empty(), "sampling from an empty logits row");
    if cfg.is_greedy() {
        return cast::token_from_index(argmax_row(logits));
    }
    let tau = cfg.effective_temperature(uncertainty);
    if tau <= GREEDY_TEMPERATURE {
        return cast::token_from_index(argmax_row(logits));
    }
    let tau = tau as f64;

    // candidate set: non-NaN logits, sorted descending (stable, so ties
    // keep the lowest index first — matching argmax_row's tie rule)
    let mut cand: Vec<(usize, f64)> = logits
        .iter()
        .enumerate()
        .filter(|(_, x)| !x.is_nan())
        .map(|(i, &x)| (i, x as f64))
        .collect();
    debug_assert!(!cand.is_empty(), "sampling from an all-NaN logits row");
    if cand.is_empty() {
        return 0;
    }
    cand.sort_by(|a, b| b.1.partial_cmp(&a.1).expect("NaNs filtered"));
    if cfg.top_k > 0 && cfg.top_k < cand.len() {
        cand.truncate(cfg.top_k);
    }

    // softmax with max-subtraction, in f64
    let m = cand[0].1;
    let mut probs: Vec<f64> =
        cand.iter().map(|(_, l)| ((l - m) / tau).exp()).collect();

    // nucleus: smallest probability-sorted prefix with mass >= top_p
    if (cfg.top_p as f64) < 1.0 {
        let total: f64 = probs.iter().sum();
        let target = (cfg.top_p as f64).max(0.0) * total;
        let mut acc = 0.0;
        let mut keep = cand.len();
        for (i, p) in probs.iter().enumerate() {
            acc += p;
            if acc >= target {
                keep = i + 1;
                break;
            }
        }
        cand.truncate(keep);
        probs.truncate(keep);
    }

    let total: f64 = probs.iter().sum();
    let u = draw(key, counter) * total;
    let mut acc = 0.0;
    for ((i, _), p) in cand.iter().zip(&probs) {
        acc += p;
        if u < acc {
            return cast::token_from_index(*i);
        }
    }
    cast::token_from_index(cand.last().expect("non-empty candidate set").0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Vec<f32> {
        vec![0.1, 2.0, -1.0, 1.5, 0.0, -3.0, 1.9, 0.5]
    }

    #[test]
    fn degenerate_configs_reduce_to_exact_argmax() {
        let logits = row();
        let am = argmax_row(&logits) as i32;
        assert_eq!(am, 1);
        let configs = [
            SamplerConfig::greedy(),
            SamplerConfig { temperature: 1e-7, ..SamplerConfig::greedy() },
            SamplerConfig {
                temperature: 1.3,
                top_k: 1,
                ..SamplerConfig::greedy()
            },
            SamplerConfig {
                temperature: 1.3,
                top_p: 1e-9,
                ..SamplerConfig::greedy()
            },
        ];
        for cfg in &configs {
            for key in 0..64u64 {
                for counter in 0..4u64 {
                    assert_eq!(sample(&logits, cfg, key, counter, 0.0), am,
                               "cfg {cfg:?} key {key} counter {counter}");
                }
            }
        }
    }

    #[test]
    fn tiny_temperature_matches_greedy_past_the_shortcut() {
        // 1e-3 is above GREEDY_TEMPERATURE, so this goes through the real
        // softmax path; the top-2 logit gap of 0.1 gives the runner-up
        // relative mass e^{-100} — no 53-bit draw can land on it
        let logits = row();
        let cfg =
            SamplerConfig { temperature: 1e-3, ..SamplerConfig::greedy() };
        for key in 0..64u64 {
            assert_eq!(sample(&logits, &cfg, key, 0, 0.0), 1);
        }
    }

    #[test]
    fn draws_are_counter_based_and_uniform() {
        assert_eq!(draw(1, 2), draw(1, 2));
        assert_ne!(draw(1, 2), draw(1, 3));
        assert_ne!(draw(1, 2), draw(2, 2));
        let mut sum = 0.0;
        for c in 0..10_000u64 {
            let u = draw(7, c);
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn request_key_contract() {
        // explicit client seed: independent of the request id
        assert_eq!(request_key(5, 0, Some(9)), request_key(5, 77, Some(9)));
        assert_ne!(request_key(5, 0, Some(9)), request_key(5, 0, Some(10)));
        assert_ne!(request_key(4, 0, Some(9)), request_key(5, 0, Some(9)));
        // derived: distinct per request, reproducible per (seed, id)
        assert_ne!(request_key(5, 0, None), request_key(5, 1, None));
        assert_eq!(request_key(5, 3, None), request_key(5, 3, None));
    }

    #[test]
    fn top_k_restricts_support_without_killing_it() {
        // near-flat at high temperature: top-2 support is {1, 6}
        let logits = row();
        let cfg = SamplerConfig {
            temperature: 50.0,
            top_k: 2,
            ..SamplerConfig::greedy()
        };
        let mut seen = [false; 8];
        for key in 0..256u64 {
            let s = sample(&logits, &cfg, key, 0, 0.0) as usize;
            assert!(s == 1 || s == 6, "sampled {s} outside top-2");
            seen[s] = true;
        }
        assert!(seen[1] && seen[6], "high temperature must reach both");
    }

    #[test]
    fn top_p_keeps_smallest_sufficient_nucleus() {
        // peaked: the top token holds ~all mass, p=0.5 pins it
        let peaked = vec![10.0, 0.0, 0.0, 0.0];
        let cfg = SamplerConfig {
            temperature: 1.0,
            top_p: 0.5,
            ..SamplerConfig::greedy()
        };
        for key in 0..128u64 {
            assert_eq!(sample(&peaked, &cfg, key, 0, 0.0), 0);
        }
        // flat: p=1.0 (off) leaves every index reachable
        let flat = vec![0.0; 4];
        let cfg =
            SamplerConfig { temperature: 1.0, ..SamplerConfig::greedy() };
        let mut seen = [false; 4];
        for key in 0..256u64 {
            seen[sample(&flat, &cfg, key, 0, 0.0) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "flat sampling must cover: {seen:?}");
    }

    #[test]
    fn nan_logits_never_win() {
        let logits = vec![f32::NAN, 1.0, 3.0, 2.0];
        assert_eq!(sample(&logits, &SamplerConfig::greedy(), 0, 0, 0.0), 2);
        let cfg =
            SamplerConfig { temperature: 10.0, ..SamplerConfig::greedy() };
        for key in 0..256u64 {
            assert_ne!(sample(&logits, &cfg, key, 0, 0.0), 0,
                       "NaN index sampled");
        }
    }

    #[test]
    fn uncertainty_scales_temperature() {
        let cfg = SamplerConfig {
            temperature: 0.5,
            uncertainty_temp: 2.0,
            ..SamplerConfig::greedy()
        };
        assert_eq!(cfg.effective_temperature(0.0), 0.5);
        assert!((cfg.effective_temperature(1.0) - 1.5).abs() < 1e-6);
        // off by default; robust to non-finite uncertainty
        assert_eq!(SamplerConfig::greedy().effective_temperature(10.0), 0.0);
        assert_eq!(cfg.effective_temperature(f32::NAN), 0.5);
        assert_eq!(cfg.effective_temperature(-3.0), 0.5);
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(SamplerConfig::greedy().validate().is_ok());
        let bad_t = SamplerConfig {
            temperature: -0.1,
            ..SamplerConfig::greedy()
        };
        assert!(bad_t.validate().is_err());
        let bad_p =
            SamplerConfig { top_p: 0.0, ..SamplerConfig::greedy() };
        assert!(bad_p.validate().is_err());
        let bad_u = SamplerConfig {
            uncertainty_temp: f32::NAN,
            ..SamplerConfig::greedy()
        };
        assert!(bad_u.validate().is_err());
    }

    #[test]
    fn stop_membership() {
        let cfg = SamplerConfig {
            stop_tokens: vec![0, 31],
            ..SamplerConfig::greedy()
        };
        assert!(cfg.is_stop(0));
        assert!(cfg.is_stop(31));
        assert!(!cfg.is_stop(5));
        assert!(!SamplerConfig::greedy().is_stop(0));
    }
}

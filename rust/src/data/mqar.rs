//! Multi-Query Associative Recall (Arora et al. 2023; paper §5.3, Fig. 6a).
//!
//! A block of key-value bindings followed by multiple queries; the model
//! must recall each queried value.  The paper's "hard" configuration
//! (T=2048, V=256) stresses storage capacity; ours scales both sides down
//! (T=256, V=64 keys+values) per DESIGN.md §3 — the capacity ratio
//! (#bindings x log V vs state size) is the preserved quantity.

use super::{Sample, TaskGen};
use crate::util::Pcg64;

pub const PAD: i32 = 0;
pub const KEY_BASE: i32 = 4;
pub const VAL_BASE: i32 = 34;

pub struct Mqar {
    pub n_keys: i32,
    pub n_vals: i32,
    pub n_pairs: usize,
    pub n_queries: usize,
}

impl Default for Mqar {
    fn default() -> Self {
        Mqar { n_keys: 30, n_vals: 30, n_pairs: 24, n_queries: 24 }
    }
}

impl TaskGen for Mqar {
    fn name(&self) -> &str {
        "mqar"
    }

    fn sample(&self, rng: &mut Pcg64, t: usize) -> Sample {
        let n_pairs = self.n_pairs.min((t / 2).saturating_sub(1)).max(1);
        let n_queries = self.n_queries.min(t / 2 - n_pairs).max(1);
        // distinct keys, random values
        let keys = rng.choose_distinct(self.n_keys as usize, n_pairs);
        let vals: Vec<i32> = (0..n_pairs)
            .map(|_| VAL_BASE + rng.below(self.n_vals as u64) as i32)
            .collect();
        let mut s = Sample::with_capacity(t);
        for i in 0..n_pairs {
            s.push(KEY_BASE + keys[i] as i32, PAD, false);
            s.push(vals[i], PAD, false);
        }
        // queries: re-present keys (uniform over bound keys), supervise
        // value prediction at the key position
        for _ in 0..n_queries {
            let qi = rng.usize_below(n_pairs);
            s.push(KEY_BASE + keys[qi] as i32, vals[qi], true);
            s.push(vals[qi], PAD, false);
        }
        s.fit(t);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queries_match_bindings() {
        let task = Mqar::default();
        let mut rng = Pcg64::seeded(0);
        let s = task.sample(&mut rng, 256);
        // build binding map from the first n_pairs pairs
        let mut map = std::collections::HashMap::new();
        for i in (0..2 * task.n_pairs).step_by(2) {
            map.insert(s.tokens[i], s.tokens[i + 1]);
        }
        let mut n_sup = 0;
        for i in 0..s.tokens.len() {
            if s.mask[i] > 0.0 {
                n_sup += 1;
                assert_eq!(s.targets[i], map[&s.tokens[i]]);
            }
        }
        assert_eq!(n_sup, task.n_queries);
    }

    #[test]
    fn keys_distinct_within_sequence() {
        let task = Mqar::default();
        let mut rng = Pcg64::seeded(1);
        let s = task.sample(&mut rng, 256);
        let mut seen = std::collections::HashSet::new();
        for i in (0..2 * task.n_pairs).step_by(2) {
            assert!(seen.insert(s.tokens[i]), "duplicate key in bindings");
        }
    }

    #[test]
    fn short_sequences_degrade_gracefully() {
        let task = Mqar::default();
        let mut rng = Pcg64::seeded(2);
        let s = task.sample(&mut rng, 16);
        assert_eq!(s.tokens.len(), 16);
        assert!(s.mask.iter().sum::<f32>() >= 1.0);
    }
}

//! Serving demo: boot the belief-state server, fire concurrent requests,
//! print per-request latency + the posterior-uncertainty signal, then
//! shut down and report engine stats.  Uses the XLA artifact backend when
//! artifacts are present, else the pure-Rust native backend — the demo
//! always runs.
//!
//!   cargo run --release --example serve_demo [n_requests]

use anyhow::Result;
use kla::config::ServeConfig;
use kla::kla::NativeLmConfig;
use kla::runtime::{NativeBackend, Runtime};
use kla::serve::{serve, serve_native, Client, RequestOpts, StreamEvent};

fn main() -> Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);

    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        artifact: "serve_kla_b8".into(),
        max_new_tokens: 8,
        batch_window_us: 300,
        // belief-state prefix cache: shared system prompts prefill once
        // (native chunked-prefill path; a no-op on the XLA fallback)
        prefix_cache_bytes: 8 << 20,
        ..Default::default()
    };
    // try the full XLA setup; ANY failure (missing dir, missing
    // artifact, compile error) falls back to the native backend so the
    // demo always runs
    let xla = || -> Result<kla::serve::ServerHandle> {
        let rt = Runtime::discover()?;
        let init = rt.load("lm_kla_init")?;
        let params = init.run(&[])?;
        serve(rt.dir().to_path_buf(), cfg.artifact.clone(), params, &cfg)
    };
    let handle = match xla() {
        Ok(h) => h,
        Err(e) => {
            println!("xla backend unavailable ({e}); using the native \
                      backend");
            let backend =
                NativeBackend::seeded(&NativeLmConfig::default(), 0, 8);
            serve_native(backend, &cfg)?
        }
    };
    let addr = handle.addr.clone();
    println!("server up on {addr}; sending {n_requests} concurrent \
              requests (8 slots, continuous batching)\n");

    let mut joins = Vec::new();
    for i in 0..n_requests {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || -> Result<String> {
            let mut c = Client::connect(&addr)?;
            let prompt: Vec<i32> =
                (0..4 + i % 5).map(|j| ((i * 7 + j) % 200) as i32).collect();
            let r = c.request(&prompt, 8)?;
            Ok(format!(
                "req {i:>2}: {} tokens, total {:>7.1} ms, uncertainty {:.4}",
                r.req("tokens")?.as_arr()?.len(),
                r.req("total_ms")?.as_f64()?,
                r.req("uncertainty")?.as_f64()?
            ))
        }));
    }
    for j in joins {
        println!("{}", j.join().unwrap()?);
    }

    // sampled decoding: same prompt, two explicit seeds — reproducible
    // per seed, and the uncertainty-scaled temperature samples hotter
    // where the belief is diffuse (uncertainty_temp couples them)
    println!("\nseeded sampling (temperature 0.9, top_p 0.95, \
              uncertainty_temp 0.5):");
    let mut c = Client::connect(&addr)?;
    let prompt: Vec<i32> = (0..6).map(|j| (j * 17) % 200).collect();
    for seed in [7u64, 8] {
        let opts = RequestOpts {
            temperature: Some(0.9),
            top_p: Some(0.95),
            uncertainty_temp: Some(0.5),
            seed: Some(seed),
            ..Default::default()
        };
        let r = c.request_opts(&prompt, 8, &opts)?;
        let toks: Vec<String> = r
            .req("tokens")?
            .as_arr()?
            .iter()
            .map(|t| t.to_string())
            .collect();
        println!("  seed {seed}: [{}] uncertainty {:.4}",
                 toks.join(", "), r.req("uncertainty")?.as_f64()?);
    }

    // protocol v2 streaming: tokens arrive the moment they are sampled,
    // each tagged with the slot's post-step posterior uncertainty — the
    // paper's belief trajectory, printed live instead of summarised
    println!("\nstreaming (per-token posterior uncertainty trajectory):");
    let stream_opts = RequestOpts {
        temperature: Some(0.9),
        top_p: Some(0.95),
        uncertainty_temp: Some(0.5),
        seed: Some(7),
        ..Default::default()
    };
    for ev in c.stream(&prompt, 10, &stream_opts)? {
        match ev {
            StreamEvent::Start { queue_ms, .. } => {
                println!("  start (queued {queue_ms:.2} ms)");
            }
            StreamEvent::Token { index, token, uncertainty, .. } => {
                println!("  token[{index:>2}] = {token:<5} \
                          uncertainty {uncertainty:.4}");
            }
            StreamEvent::Done { total_ms, uncertainty, .. } => {
                println!("  done in {total_ms:.1} ms \
                          (final uncertainty {uncertainty:.4})");
            }
            StreamEvent::Err { code, msg, .. } => {
                println!("  err {code}: {msg}");
            }
        }
    }

    // belief-state prefix cache: two greedy requests sharing a system
    // prompt.  The second restores the first's end-of-prefill snapshot
    // (cached_tokens > 0) and — the identity guarantee — generates the
    // same tokens with the same uncertainty trajectory from the restore
    // point: the snapshot IS the cold end-of-prefill belief state.
    println!("\nshared system prompt (belief-state prefix cache):");
    let shared: Vec<i32> = (0..96).map(|j| ((j * 11) % 200) as i32)
        .collect();
    let mut trajectories: Vec<Vec<(i32, f64)>> = Vec::new();
    for pass in ["cold", "warm"] {
        let mut traj = Vec::new();
        let mut cached = 0usize;
        let mut ms = 0.0;
        for ev in c.stream(&shared, 6, &RequestOpts::default())? {
            match ev {
                StreamEvent::Token { token, uncertainty, .. } => {
                    traj.push((token, uncertainty));
                }
                StreamEvent::Done { cached_tokens, total_ms, .. } => {
                    cached = cached_tokens;
                    ms = total_ms;
                }
                _ => {}
            }
        }
        let toks: Vec<String> =
            traj.iter().map(|(t, _)| t.to_string()).collect();
        println!("  {pass}: cached_tokens {cached:>2}, {ms:>6.1} ms, \
                  tokens [{}]", toks.join(", "));
        trajectories.push(traj);
    }
    if trajectories[0] == trajectories[1] {
        println!("  warm pass: identical tokens AND uncertainty \
                  trajectory from the restore point");
    }

    let stats = handle.stop()?;
    println!("\nengine: {} requests, {} steps, {} tokens out",
             stats.requests, stats.steps, stats.tokens_out);
    println!("throughput {:.1} tok/s, mean step {:.2} ms, mean batch \
              occupancy {:.2}",
             stats.tokens_per_sec(), stats.mean_step_ms(),
             stats.batch_occupancy.iter().sum::<f64>()
                 / stats.batch_occupancy.len().max(1) as f64);
    // chunked scan prefill runs on the native backend; the XLA path
    // interleaves token-by-token, so the line stays backend-agnostic
    println!("prefill: {} prompt tokens at {:.1} tok/s",
             stats.prefill_tokens, stats.prefill_tokens_per_sec());
    println!("prefix cache: {} hits ({} partial), {} misses, {} prompt \
              tokens restored, {} bytes in {} entries",
             stats.prefix_hits, stats.prefix_partial_hits,
             stats.prefix_misses, stats.prefix_cached_tokens,
             stats.prefix_bytes, stats.prefix_entries);
    Ok(())
}

//! Generation engine: marries the scheduler (batcher.rs) to a
//! [`DecodeBackend`] (XLA artifact session or the pure-Rust native model)
//! and the belief-state cache.  One engine thread owns the model; the
//! router (server.rs) talks to it over an mpsc channel.  The engine is
//! generic over the backend, so the continuous-batching logic is tested
//! end-to-end offline on `NativeBackend` and runs unchanged on PJRT.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Feed, SchedRequest, Scheduler};
use super::state_cache::BeliefStateCache;
use crate::runtime::backend::DecodeBackend;
use crate::tensor::IntTensor;
use crate::util::Stats;

/// A request entering the engine.
pub struct EngineRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Stamped by the producer at enqueue time, so queue_ms includes
    /// time spent in the mpsc channel before engine intake (under
    /// overload, intake stops draining once the scheduler queue reaches
    /// batch size — that channel wait is real queueing).
    pub submitted: Instant,
    pub resp: Sender<EngineResponse>,
}

/// The reply (tokens + timing; uncertainty from the belief state).
#[derive(Clone, Debug)]
pub struct EngineResponse {
    pub tokens: Vec<i32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub uncertainty: f32,
}

/// Engine statistics (read after shutdown).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: usize,
    pub steps: usize,
    pub tokens_out: usize,
    pub step_ms: Vec<f64>,
    pub batch_occupancy: Vec<f64>,
}

impl EngineStats {
    pub fn tokens_per_sec(&self) -> f64 {
        let total_s: f64 = self.step_ms.iter().sum::<f64>() / 1e3;
        if total_s > 0.0 {
            self.tokens_out as f64 / total_s
        } else {
            0.0
        }
    }

    pub fn mean_step_ms(&self) -> f64 {
        let mut s = Stats::new();
        for &x in &self.step_ms {
            s.push(x);
        }
        s.mean()
    }
}

/// Submit/admit/finish bookkeeping for in-flight requests.
///
/// Queue time is the interval from submit until the scheduler actually
/// admits the request into a batch slot — NOT submit-to-submit (the old
/// code stamped `start_time` at submit and never updated it, so
/// `queue_ms` was always ~0 even for requests that waited behind a full
/// batch).  `admit()` is driven by the `(slot, id)` pairs
/// `Scheduler::admit` reports.
struct PendingTable {
    rows: Vec<PendingRow>,
}

struct PendingRow {
    id: u64,
    resp: Sender<EngineResponse>,
    submitted: Instant,
    admitted: Option<Instant>,
}

impl PendingTable {
    fn new() -> Self {
        PendingTable { rows: Vec::new() }
    }

    fn submit(&mut self, id: u64, resp: Sender<EngineResponse>,
              now: Instant) {
        self.rows.push(PendingRow {
            id,
            resp,
            submitted: now,
            admitted: None,
        });
    }

    /// Record the moment `id` entered a batch slot (idempotent).
    fn admit(&mut self, id: u64, now: Instant) {
        if let Some(row) = self.rows.iter_mut().find(|r| r.id == id) {
            if row.admitted.is_none() {
                row.admitted = Some(now);
            }
        }
    }

    /// Retire `id`: returns the response channel plus
    /// `(queue_ms, total_ms)` measured at `now`.
    fn finish(&mut self, id: u64, now: Instant)
              -> Option<(Sender<EngineResponse>, f64, f64)> {
        let pos = self.rows.iter().position(|r| r.id == id)?;
        let row = self.rows.swap_remove(pos);
        let admitted = row.admitted.unwrap_or(now);
        let queue_ms =
            admitted.saturating_duration_since(row.submitted).as_secs_f64()
                * 1e3;
        let total_ms =
            now.saturating_duration_since(row.submitted).as_secs_f64() * 1e3;
        Some((row.resp, queue_ms, total_ms))
    }
}

/// Run the engine loop until `rx` disconnects (or `shutdown` is set) and
/// all admitted work drains.  `batch_window` bounds how long we wait to
/// fill empty slots before stepping a partially-full batch.
///
/// The intake NEVER blocks indefinitely: connection-handler threads hold
/// `tx` clones for as long as their sockets live, so a blocking `recv()`
/// would deadlock `ServerHandle::stop()` against any client that keeps its
/// connection open (seen in integration_serve).
pub fn run_engine<B: DecodeBackend>(backend: &B,
                                    rx: Receiver<EngineRequest>,
                                    batch_window: Duration,
                                    shutdown: Arc<AtomicBool>)
                                    -> Result<EngineStats> {
    let b = backend.batch();
    let mut cache = BeliefStateCache::for_backend(backend)?;
    let mut sched = Scheduler::new(b, 0);
    let mut pending = PendingTable::new();
    let mut next_id = 0u64;
    let mut stats = EngineStats::default();
    let mut disconnected = false;

    while (!disconnected && !shutdown.load(Ordering::SeqCst))
        || sched.has_work()
    {
        // intake: block briefly when idle, else drain without blocking
        let deadline = Instant::now() + batch_window;
        loop {
            let timeout = deadline.saturating_duration_since(Instant::now());
            let msg = if sched.active_count() == 0 && sched.queue.is_empty()
            {
                // fully idle: wait in short slices so shutdown is observed
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if shutdown.load(Ordering::SeqCst) {
                            disconnected = true;
                        }
                        None
                    }
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            } else if sched.queue.is_empty()
                && sched.active_count() < b
                && !timeout.is_zero()
            {
                match rx.recv_timeout(timeout) {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(_) => {
                        disconnected = true;
                        None
                    }
                }
            };
            match msg {
                Some(req) => {
                    let id = next_id;
                    next_id += 1;
                    pending.submit(id, req.resp, req.submitted);
                    sched.submit(SchedRequest {
                        id,
                        prompt: req.prompt,
                        max_new: req.max_new,
                    });
                    stats.requests += 1;
                }
                None => break,
            }
            if sched.queue.len() >= b {
                break;
            }
        }
        if !sched.has_work() {
            continue;
        }

        // admit into slots: reset belief state for new slots and stamp
        // the admit time (queue time ends here)
        let admit_now = Instant::now();
        for (slot, id) in sched.admit() {
            cache.reset_slot(slot);
            pending.admit(id, admit_now);
        }

        // build the token vector for this iteration; ids are clamped
        // into [0, vocab) HERE so the trait contract holds for every
        // backend (the XLA gather has no clamp of its own)
        let vmax = (backend.vocab() as i32 - 1).max(0);
        let feeds = sched.feeds();
        let tokens: Vec<i32> = feeds
            .iter()
            .map(|f| match f {
                Feed::Prefill(t) | Feed::Decode(t) => (*t).clamp(0, vmax),
                Feed::Idle => sched.pad(),
            })
            .collect();

        let t0 = Instant::now();
        let (logits, new_state) =
            backend.step(&IntTensor::new(&[b], tokens)?, cache.state())?;
        cache.set_state(new_state);
        stats.step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        stats.steps += 1;
        stats.batch_occupancy
            .push(sched.active_count() as f64 / b as f64);

        // greedy sampling per slot
        let am = logits.argmax_last();
        let sampled: Vec<i32> = am.data().to_vec();
        let finished = sched.advance(&sampled);
        for f in &finished {
            stats.tokens_out += f.tokens.len();
            let uncertainty = cache.slot_uncertainty(f.slot);
            cache.reset_slot(f.slot);
            sched.release(f.slot);
            if let Some((resp, queue_ms, total_ms)) =
                pending.finish(f.id, Instant::now())
            {
                let _ = resp.send(EngineResponse {
                    tokens: f.tokens.clone(),
                    queue_ms,
                    total_ms,
                    uncertainty,
                });
            }
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn queue_time_measured_at_admit_not_submit() {
        let (tx, _rx) = channel();
        let mut table = PendingTable::new();
        let t0 = Instant::now();
        table.submit(1, tx, t0);
        let admit = t0 + Duration::from_millis(25);
        table.admit(1, admit);
        // a later admit call must not move the stamp (idempotent)
        table.admit(1, admit + Duration::from_millis(50));
        let finish = admit + Duration::from_millis(10);
        let (_resp, queue_ms, total_ms) = table.finish(1, finish).unwrap();
        assert!((queue_ms - 25.0).abs() < 1e-6, "queue_ms {queue_ms}");
        assert!((total_ms - 35.0).abs() < 1e-6, "total_ms {total_ms}");
        // finished rows are gone
        assert!(table.finish(1, finish).is_none());
    }

    #[test]
    fn unadmitted_request_counts_full_wait_as_queue_time() {
        let (tx, _rx) = channel();
        let mut table = PendingTable::new();
        let t0 = Instant::now();
        table.submit(2, tx, t0);
        let finish = t0 + Duration::from_millis(7);
        let (_resp, queue_ms, total_ms) = table.finish(2, finish).unwrap();
        assert!((queue_ms - 7.0).abs() < 1e-6, "queue_ms {queue_ms}");
        assert!((total_ms - 7.0).abs() < 1e-6, "total_ms {total_ms}");
    }
}

//! Moebius (fractional-linear) maps on the posterior precision (Theorem 1).
//!
//! A per-token precision update is the map
//!     lam' = (a*lam + b) / (c*lam + d)
//! represented by a 2x2 matrix up to scale.  Composition = matrix product,
//! which is associative — the key fact that makes exact Kalman filtering a
//! parallel prefix scan (Corollary 1.1).

/// One Moebius map, `[[a, b], [c, d]]`, scale-free.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mobius {
    pub a: f32,
    pub b: f32,
    pub c: f32,
    pub d: f32,
}

impl Mobius {
    pub const IDENTITY: Mobius = Mobius { a: 1.0, b: 0.0, c: 0.0, d: 1.0 };

    /// The KLA token map from Theorem 1:
    /// `M_t = [[1 + pbar*phi, abar^2*phi], [pbar, abar^2]]` with
    /// `phi = k^2 * lam_v`.
    #[inline]
    pub fn kla_step(abar: f32, pbar: f32, phi: f32) -> Mobius {
        let a2 = abar * abar;
        Mobius { a: 1.0 + pbar * phi, b: a2 * phi, c: pbar, d: a2 }
    }

    /// Apply to a precision value.
    #[inline]
    pub fn apply(&self, lam: f32) -> f32 {
        (self.a * lam + self.b) / (self.c * lam + self.d)
    }

    /// `self ∘ other`: apply `other` first, then `self`
    /// (matrix product self * other), renormalised by the max-abs entry so
    /// long products stay inside f32 range (Moebius maps are scale-free).
    #[inline]
    pub fn compose(&self, other: &Mobius) -> Mobius {
        let a = self.a * other.a + self.b * other.c;
        let b = self.a * other.b + self.b * other.d;
        let c = self.c * other.a + self.d * other.c;
        let d = self.c * other.b + self.d * other.d;
        // Lazy renormalisation: Moebius maps are scale-free, so we only
        // rescale when entries threaten f32 range.  The branch is almost
        // never taken, and the single reciprocal replaces four divides —
        // this is the hot op of the chunked scan's composition pass.
        let m = a.abs().max(b.abs()).max(c.abs()).max(d.abs());
        if m > 1e18 || (m < 1e-18 && m > 0.0) {
            let inv = 1.0 / m.max(1e-30);
            Mobius { a: a * inv, b: b * inv, c: c * inv, d: d * inv }
        } else {
            Mobius { a, b, c, d }
        }
    }

    pub fn det(&self) -> f32 {
        self.a * self.d - self.b * self.c
    }

    /// Widen to the f64 representation used for carry composition.
    pub fn widen(&self) -> Mobius64 {
        Mobius64 {
            a: self.a as f64,
            b: self.b as f64,
            c: self.c as f64,
            d: self.d as f64,
        }
    }

    /// Approximate equality as *maps* (up to scale): compare normalised
    /// entries with the sign fixed by the largest entry.
    pub fn approx_eq(&self, other: &Mobius, tol: f32) -> bool {
        let n1 = self.normalised();
        let n2 = other.normalised();
        (n1.a - n2.a).abs() < tol
            && (n1.b - n2.b).abs() < tol
            && (n1.c - n2.c).abs() < tol
            && (n1.d - n2.d).abs() < tol
    }

    fn normalised(&self) -> Mobius {
        let entries = [self.a, self.b, self.c, self.d];
        let (mut s, mut mag) = (1.0f32, 0.0f32);
        for &e in &entries {
            if e.abs() > mag {
                mag = e.abs();
                s = if e < 0.0 { -1.0 } else { 1.0 };
            }
        }
        let scale = s * mag.max(1e-30);
        Mobius {
            a: self.a / scale,
            b: self.b / scale,
            c: self.c / scale,
            d: self.d / scale,
        }
    }
}

/// f64 Moebius map — used where long products feed carries (chunk
/// summaries in the chunked scan, the Blelloch tree): composing in f64
/// keeps cross-chunk carries accurate to well below the 1e-5 conformance
/// tolerance even for T in the tens of thousands, while the per-token
/// replay stays in f32 (bit-matching the sequential path).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mobius64 {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
}

impl Mobius64 {
    pub const IDENTITY: Mobius64 =
        Mobius64 { a: 1.0, b: 0.0, c: 0.0, d: 1.0 };

    /// The KLA token map from Theorem 1 (see `Mobius::kla_step`).
    #[inline]
    pub fn kla_step(abar: f64, pbar: f64, phi: f64) -> Mobius64 {
        let a2 = abar * abar;
        Mobius64 { a: 1.0 + pbar * phi, b: a2 * phi, c: pbar, d: a2 }
    }

    #[inline]
    pub fn apply(&self, lam: f64) -> f64 {
        (self.a * lam + self.b) / (self.c * lam + self.d)
    }

    /// `self ∘ other` (apply `other` first), with the same lazy
    /// renormalisation as the f32 map — maps are scale-free.
    #[inline]
    pub fn compose(&self, other: &Mobius64) -> Mobius64 {
        let a = self.a * other.a + self.b * other.c;
        let b = self.a * other.b + self.b * other.d;
        let c = self.c * other.a + self.d * other.c;
        let d = self.c * other.b + self.d * other.d;
        let m = a.abs().max(b.abs()).max(c.abs()).max(d.abs());
        if m > 1e120 || (m < 1e-120 && m > 0.0) {
            let inv = 1.0 / m.max(1e-300);
            Mobius64 { a: a * inv, b: b * inv, c: c * inv, d: d * inv }
        } else {
            Mobius64 { a, b, c, d }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{property, Gen};
    use crate::util::Pcg64;

    fn rand_kla_map(rng: &mut Pcg64) -> Mobius {
        Mobius::kla_step(
            rng.range_f32(0.5, 0.999),
            rng.range_f32(1e-4, 0.3),
            rng.range_f32(1e-3, 3.0),
        )
    }

    #[test]
    fn identity_applies() {
        assert_eq!(Mobius::IDENTITY.apply(3.25), 3.25);
        let m = Mobius::kla_step(0.9, 0.01, 1.0);
        assert!(m.compose(&Mobius::IDENTITY).approx_eq(&m, 1e-6));
        assert!(Mobius::IDENTITY.compose(&m).approx_eq(&m, 1e-6));
    }

    #[test]
    fn kla_step_matches_recursion() {
        // M(lam) must equal the textbook predict+update recursion.
        let (abar, pbar, phi, lam) = (0.93f32, 0.02f32, 0.7f32, 1.3f32);
        let m = Mobius::kla_step(abar, pbar, phi);
        let prior = lam / (abar * abar + pbar * lam);
        assert!((m.apply(lam) - (prior + phi)).abs() < 1e-6);
    }

    #[test]
    fn composition_is_application_order() {
        // (m2 ∘ m1)(x) == m2(m1(x))
        let m1 = Mobius::kla_step(0.9, 0.05, 0.4);
        let m2 = Mobius::kla_step(0.8, 0.02, 1.1);
        let x = 0.9f32;
        let composed = m2.compose(&m1).apply(x);
        let stepped = m2.apply(m1.apply(x));
        assert!((composed - stepped).abs() < 1e-5, "{composed} {stepped}");
    }

    #[test]
    fn prop_composition_associative() {
        property("mobius_associativity", 200, |g: &mut Gen| {
            let (m1, m2, m3) = (
                rand_kla_map(g.rng),
                rand_kla_map(g.rng),
                rand_kla_map(g.rng),
            );
            let left = m3.compose(&m2).compose(&m1);
            let right = m3.compose(&m2.compose(&m1));
            if !left.approx_eq(&right, 1e-4) {
                return Err(format!("{left:?} != {right:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_kla_maps_preserve_positivity() {
        // Positive precision stays positive under any chain of KLA maps.
        property("positivity", 100, |g: &mut Gen| {
            let mut lam = g.f32_in(1e-3, 5.0);
            for _ in 0..g.usize_in(1, 64) {
                lam = rand_kla_map(g.rng).apply(lam);
                if !(lam > 0.0) || !lam.is_finite() {
                    return Err(format!("lam went to {lam}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_long_products_stay_finite() {
        property("renorm_stability", 30, |g: &mut Gen| {
            let mut acc = Mobius::IDENTITY;
            for _ in 0..4096 {
                acc = rand_kla_map(g.rng).compose(&acc);
            }
            let lam = acc.apply(1.0);
            if !lam.is_finite() || lam <= 0.0 {
                return Err(format!("after 4096 steps lam={lam}"));
            }
            Ok(())
        });
    }

    #[test]
    fn det_positive_for_kla_maps() {
        // det = a2*(1+pbar*phi) - a2*phi*pbar = a2 > 0
        let m = Mobius::kla_step(0.9, 0.1, 2.0);
        assert!(m.det() > 0.0);
    }

    #[test]
    fn mobius64_tracks_f32_maps() {
        let mut rng = Pcg64::seeded(7);
        let mut acc32 = Mobius::IDENTITY;
        let mut acc64 = Mobius64::IDENTITY;
        for _ in 0..256 {
            let m = rand_kla_map(&mut rng);
            acc32 = m.compose(&acc32);
            acc64 = m.widen().compose(&acc64);
        }
        let lam32 = acc32.apply(1.3);
        let lam64 = acc64.apply(1.3) as f32;
        assert!(
            (lam32 - lam64).abs() < 1e-3 * (1.0 + lam64.abs()),
            "{lam32} vs {lam64}"
        );
    }

    #[test]
    fn mobius64_long_products_stay_finite() {
        let mut rng = Pcg64::seeded(8);
        let mut acc = Mobius64::IDENTITY;
        for _ in 0..65536 {
            acc = rand_kla_map(&mut rng).widen().compose(&acc);
        }
        let lam = acc.apply(1.0);
        assert!(lam.is_finite() && lam > 0.0, "{lam}");
    }
}

//! The controlled scheduler behind the `mc-shim` sync primitives.
//!
//! Real OS threads, cooperative execution: exactly one model thread is
//! ever runnable.  Every visible operation of a shim primitive calls
//! [`Exec::op`] — a *scheduling point* where the running thread parks,
//! the scheduler picks the next thread among the currently *enabled*
//! ones (mutex free, condvar notified, channel non-empty, join target
//! finished, ...), and hands the baton over.  Recording each decision
//! (the enabled set and the choice) makes a schedule replayable: the
//! DFS driver re-runs the program under a forced choice prefix to
//! enumerate schedules, the PCT driver derives all choices from a
//! seed.  See DESIGN.md §S19 for the semantics and their limits.
//!
//! Teardown: when an execution aborts (deadlock, panic, step limit),
//! every parked thread is woken and unwinds with the private
//! [`McAbort`] panic payload; shim operations called *during* such an
//! unwind bypass the model entirely (plain `std` behaviour) so guard
//! drops and pool destructors cannot double-panic.

use std::cell::RefCell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, Weak};

use crate::util::Pcg64;

/// Per-execution cap on spurious wakeups granted to `wait_timeout` /
/// timed waiters.  Keeps timed waits *live* (a timed wait can always
/// recover from a missed notification, like the real timeout does)
/// while bounding the schedule space.
const SPURIOUS_BUDGET: usize = 32;

/// Model threads per execution; far above any invariant model's need.
const MAX_THREADS: usize = 16;

/// PCT samples its priority-change points uniformly from this many
/// initial scheduling decisions (the classic algorithm's `k`).
const PCT_EST_DECISIONS: usize = 256;

// ---------------------------------------------------------------------
// public configuration / results
// ---------------------------------------------------------------------

/// Schedule-exploration policy for [`model`].
#[derive(Clone, Debug)]
pub enum Policy {
    /// Bounded-exhaustive DFS over schedules.  `max_preemptions`
    /// bounds *forced* context switches away from a runnable thread
    /// (the CHESS bound); switches at blocking points are free.
    Dfs {
        max_preemptions: usize,
        max_schedules: usize,
    },
    /// Seeded PCT-style randomized schedules: random thread
    /// priorities plus `change_points` priority demotions at random
    /// decisions.  Deterministic per seed.
    Pct {
        seed: u64,
        schedules: usize,
        change_points: usize,
    },
}

/// One exploration request: a policy plus the per-execution decision
/// limit (a runaway-model backstop, not a tuning knob).
#[derive(Clone, Debug)]
pub struct Config {
    pub policy: Policy,
    pub max_steps: usize,
}

impl Config {
    /// The default DFS wall: preemption bound 2 (empirically where
    /// most concurrency bugs live), generous schedule cap.
    pub fn dfs() -> Config {
        Config {
            policy: Policy::Dfs {
                max_preemptions: 2,
                max_schedules: 4000,
            },
            max_steps: 20_000,
        }
    }

    /// The default PCT wall used by CI: 200 seeded schedules.
    pub fn pct(seed: u64) -> Config {
        Config {
            policy: Policy::Pct {
                seed,
                schedules: 200,
                change_points: 3,
            },
            max_steps: 20_000,
        }
    }
}

/// What an exploration covered.
#[derive(Clone, Copy, Debug)]
pub struct Outcome {
    /// Schedules actually executed.
    pub schedules: usize,
    /// DFS only: true when the bounded search space was exhausted
    /// (every schedule within the preemption bound was run).
    pub exhausted: bool,
}

// ---------------------------------------------------------------------
// model state
// ---------------------------------------------------------------------

/// What a thread wants to do at its current scheduling point.
#[derive(Clone, Debug)]
pub(crate) enum Intent {
    /// Freshly spawned, waiting for its first grant.
    Start,
    /// A non-blocking visible op (atomic access, send, notify, ...).
    Step,
    /// Acquire the mutex object.
    Lock(usize),
    /// Wait for the thread to finish.
    Join(usize),
    /// Receive from the channel object.
    Recv(usize),
    /// Condvar wait: parked on `cv`, will re-acquire `lock`; `timed`
    /// waiters are eligible for bounded spurious wakeups.
    Wait {
        cv: usize,
        lock: usize,
        timed: bool,
    },
}

/// How a grant resolved a blocking intent (returned by [`Exec::op`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Note {
    Go,
    /// Timed condvar wait resolved by timeout/spurious wakeup.
    TimedOut,
    /// Recv resolved with a queued message.
    RecvReady,
    /// Recv resolved by disconnection (all senders gone).
    RecvClosed,
}

/// Modelled sync-object state.
pub(crate) enum Obj {
    Mutex { held_by: Option<usize> },
    Condvar { waiters: Vec<usize> },
    Channel { queued: usize, senders: usize },
}

/// Kind selector for [`ObjRef::register`].
#[derive(Clone, Copy, Debug)]
pub(crate) enum ObjKind {
    Mutex,
    Condvar,
    Channel,
}

struct ThreadSt {
    name: String,
    intent: Intent,
    granted: bool,
    note: Note,
    notified: bool,
    finished: bool,
    priority: u64,
}

/// Why an execution stopped early.
#[derive(Clone, Debug)]
enum Abort {
    Deadlock(String),
    Panic(String),
    StepLimit(usize),
    /// Timed waiters starved of spurious-wakeup budget: the model is
    /// inconclusive (the real program would recover via timeout).
    SpuriousExhausted,
    /// A forced replay choice was not enabled — the program under
    /// test is not deterministic enough to model-check.
    ReplayDivergence(usize),
}

/// One scheduling decision, recorded for replay and backtracking.
#[derive(Clone, Debug)]
struct Decision {
    /// Enabled threads in canonical order (previous runner first when
    /// it is still enabled, then ascending thread id).
    alts: Vec<usize>,
    chosen_idx: usize,
    /// Whether `alts[0]` is the previous runner (so any other choice
    /// costs one preemption).
    prev_enabled: bool,
}

enum RunPolicy {
    Dfs,
    Pct {
        rng: Pcg64,
        change: Vec<usize>,
        next_change: usize,
        demote: u64,
    },
}

struct ExecSt {
    threads: Vec<ThreadSt>,
    objects: Vec<Obj>,
    replay: Vec<usize>,
    decisions: Vec<Decision>,
    spurious_left: usize,
    aborted: Option<Abort>,
    policy: RunPolicy,
    max_steps: usize,
}

/// One controlled execution: the model state plus the park/wake pair
/// every model thread blocks on.
pub(crate) struct Exec {
    m: StdMutex<ExecSt>,
    cv: StdCondvar,
}

// ---------------------------------------------------------------------
// thread-local execution context
// ---------------------------------------------------------------------

thread_local! {
    static CTX: RefCell<Option<(Weak<Exec>, usize)>> =
        const { RefCell::new(None) };
}

fn set_ctx(exec: &Arc<Exec>, tid: usize) {
    CTX.with(|c| *c.borrow_mut() = Some((Arc::downgrade(exec), tid)));
}

/// Bind the calling OS thread to a model thread id (used by the
/// `mc::thread` spawn shim).
pub(crate) fn enter(exec: &Arc<Exec>, tid: usize) {
    set_ctx(exec, tid);
}

/// Extract a printable message from a caught panic payload.
pub(crate) fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    panic_msg(p)
}

/// The calling thread's execution context, if it is a live model
/// thread.  Everything outside a model (normal tests, post-model
/// draining) gets `None` and falls through to plain `std` behaviour.
pub(crate) fn current_ctx() -> Option<(Arc<Exec>, usize)> {
    CTX.with(|c| {
        let b = c.borrow();
        let (w, tid) = b.as_ref()?;
        Some((w.upgrade()?, *tid))
    })
}

/// A non-blocking scheduling point for the calling thread, if it is a
/// model thread.  Returns false outside a model.
pub(crate) fn step_point() -> bool {
    match current_ctx() {
        Some((exec, me)) => {
            exec.op(me, Intent::Step);
            true
        }
        None => false,
    }
}

// ---------------------------------------------------------------------
// object handles held by shim primitives
// ---------------------------------------------------------------------

/// A shim object's link back into the execution it was created under
/// (`None` when constructed outside any model — pure std behaviour).
#[derive(Clone, Default)]
pub(crate) struct ObjRef(Option<(Weak<Exec>, usize)>);

impl std::fmt::Debug for ObjRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            Some((_, id)) => write!(f, "ObjRef(#{id})"),
            None => write!(f, "ObjRef(std)"),
        }
    }
}

impl ObjRef {
    /// Register a new object under the calling thread's execution (if
    /// any).  Objects must be created by model threads to be modelled.
    pub(crate) fn register(kind: ObjKind) -> ObjRef {
        match current_ctx() {
            Some((exec, _)) => {
                let id = exec.register_obj(kind);
                ObjRef(Some((Arc::downgrade(&exec), id)))
            }
            None => ObjRef(None),
        }
    }

    /// `(exec, object id, calling thread id)` — only when the calling
    /// thread belongs to the same live execution as the object.
    pub(crate) fn handle(&self) -> Option<(Arc<Exec>, usize, usize)> {
        let (w, obj) = self.0.as_ref()?;
        let owner = w.upgrade()?;
        let (cur, tid) = current_ctx()?;
        if Arc::ptr_eq(&owner, &cur) {
            Some((owner, *obj, tid))
        } else {
            None
        }
    }

    /// The object id, independent of the calling thread.
    pub(crate) fn obj_id(&self) -> Option<usize> {
        self.0.as_ref().map(|(_, id)| *id)
    }

    /// Mutate the object's model state without a scheduling point.
    /// Works from any thread (guard drops during unwind included);
    /// no-op once the execution is gone.
    pub(crate) fn update<R>(
        &self,
        f: impl FnOnce(&mut Obj) -> R,
    ) -> Option<R> {
        let (w, obj) = self.0.as_ref()?;
        let exec = w.upgrade()?;
        let mut st = lock_st(&exec.m);
        Some(f(&mut st.objects[*obj]))
    }
}

// ---------------------------------------------------------------------
// abort plumbing
// ---------------------------------------------------------------------

/// Private panic payload used to unwind model threads at teardown.
struct McAbort;

fn mc_abort() -> ! {
    panic::panic_any(McAbort)
}

pub(crate) fn is_mc_abort(p: &(dyn std::any::Any + Send)) -> bool {
    p.is::<McAbort>()
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn lock_st(m: &StdMutex<ExecSt>) -> std::sync::MutexGuard<'_, ExecSt> {
    // The scheduler never panics while holding this lock, so poison
    // can only come from a foreign bug; recover rather than cascade.
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

// ---------------------------------------------------------------------
// the scheduler
// ---------------------------------------------------------------------

impl Exec {
    fn new(policy: RunPolicy, replay: Vec<usize>, max_steps: usize) -> Exec {
        Exec {
            m: StdMutex::new(ExecSt {
                threads: Vec::new(),
                objects: Vec::new(),
                replay,
                decisions: Vec::new(),
                spurious_left: SPURIOUS_BUDGET,
                aborted: None,
                policy,
                max_steps,
            }),
            cv: StdCondvar::new(),
        }
    }

    /// Register a model thread; it starts parked with [`Intent::Start`].
    pub(crate) fn register_thread(&self, name: &str) -> usize {
        let mut st = lock_st(&self.m);
        assert!(
            st.threads.len() < MAX_THREADS,
            "mc: model exceeds {MAX_THREADS} threads"
        );
        let priority = match &mut st.policy {
            RunPolicy::Dfs => 0,
            // keep random priorities strictly above every demotion
            // value so demoted threads always sink to the bottom
            RunPolicy::Pct { rng, .. } => rng.next_u64() | (1 << 32),
        };
        st.threads.push(ThreadSt {
            name: name.to_string(),
            intent: Intent::Start,
            granted: false,
            note: Note::Go,
            notified: false,
            finished: false,
            priority,
        });
        st.threads.len() - 1
    }

    pub(crate) fn register_obj(&self, kind: ObjKind) -> usize {
        let mut st = lock_st(&self.m);
        st.objects.push(match kind {
            ObjKind::Mutex => Obj::Mutex { held_by: None },
            ObjKind::Condvar => Obj::Condvar { waiters: Vec::new() },
            ObjKind::Channel => Obj::Channel { queued: 0, senders: 1 },
        });
        st.objects.len() - 1
    }

    /// The scheduling point: declare what the calling thread does
    /// next, hand the baton to the scheduler, park until granted.
    pub(crate) fn op(self: &Arc<Self>, me: usize, intent: Intent) -> Note {
        if std::thread::panicking() {
            // Unwinding (user panic or McAbort teardown): bypass the
            // model so drops and destructors cannot double-panic.
            return Note::Go;
        }
        let mut st = lock_st(&self.m);
        if st.aborted.is_some() {
            drop(st);
            mc_abort();
        }
        if let Intent::Wait { cv, lock, .. } = intent {
            // A condvar wait atomically releases the mutex and joins
            // the wait set before anyone else can run.
            if let Obj::Mutex { held_by } = &mut st.objects[lock] {
                *held_by = None;
            }
            if let Obj::Condvar { waiters } = &mut st.objects[cv] {
                waiters.push(me);
            }
            st.threads[me].notified = false;
        }
        st.threads[me].intent = intent;
        st.threads[me].granted = false;
        self.pick(&mut st, Some(me));
        self.park(st, me)
    }

    /// Park a freshly spawned thread until its first grant.
    pub(crate) fn park_start(self: &Arc<Self>, me: usize) {
        let st = lock_st(&self.m);
        self.park(st, me);
    }

    fn park(
        self: &Arc<Self>,
        mut st: std::sync::MutexGuard<'_, ExecSt>,
        me: usize,
    ) -> Note {
        loop {
            if st.threads[me].granted {
                st.threads[me].granted = false;
                return st.threads[me].note;
            }
            if st.aborted.is_some() {
                drop(st);
                mc_abort();
            }
            st = self
                .cv
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Mark the calling thread finished and hand the baton on.
    pub(crate) fn finish(self: &Arc<Self>, me: usize) {
        let mut st = lock_st(&self.m);
        st.threads[me].finished = true;
        if st.aborted.is_none() {
            self.pick(&mut st, None);
        }
        self.cv.notify_all();
    }

    /// Like [`Exec::finish`], for a thread that unwound with a user
    /// panic: records the failure and tears the execution down.
    pub(crate) fn finish_panicked(self: &Arc<Self>, me: usize, msg: String) {
        let mut st = lock_st(&self.m);
        st.threads[me].finished = true;
        if st.aborted.is_none() {
            let name = st.threads[me].name.clone();
            st.aborted =
                Some(Abort::Panic(format!("thread '{name}' panicked: {msg}")));
        }
        self.cv.notify_all();
    }

    /// Move `notified` waiters out of the condvar's wait set (FIFO).
    pub(crate) fn notify(&self, cv: usize, all: bool) {
        let mut st = lock_st(&self.m);
        let woken: Vec<usize> = match &mut st.objects[cv] {
            Obj::Condvar { waiters } => {
                if all {
                    waiters.drain(..).collect()
                } else if waiters.is_empty() {
                    Vec::new()
                } else {
                    vec![waiters.remove(0)]
                }
            }
            _ => Vec::new(),
        };
        for t in woken {
            st.threads[t].notified = true;
        }
    }

    /// Block until every model thread has finished (the harness
    /// monitor; runs on the driving test thread, outside the model).
    fn wait_done(&self) {
        let mut st = lock_st(&self.m);
        while !st.threads.iter().all(|t| t.finished) {
            st = self
                .cv
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    fn take_result(&self) -> (Vec<Decision>, Option<Abort>) {
        let mut st = lock_st(&self.m);
        (std::mem::take(&mut st.decisions), st.aborted.clone())
    }

    /// Pick and grant the next thread.  Called with no thread running
    /// (the previous runner is parked or finished).
    fn pick(self: &Arc<Self>, st: &mut ExecSt, prev: Option<usize>) {
        if st.threads.iter().all(|t| t.finished) {
            self.cv.notify_all();
            return;
        }
        let mut en: Vec<usize> = (0..st.threads.len())
            .filter(|&t| enabled(st, t))
            .collect();
        if en.is_empty() {
            st.aborted = Some(stall_kind(st));
            self.cv.notify_all();
            return;
        }
        if st.decisions.len() >= st.max_steps {
            st.aborted = Some(Abort::StepLimit(st.max_steps));
            self.cv.notify_all();
            return;
        }
        // canonical order: previous runner first when still enabled
        let prev_enabled = prev.is_some_and(|p| en.contains(&p));
        if let Some(p) = prev {
            if prev_enabled {
                en.retain(|&t| t != p);
                en.insert(0, p);
            }
        }
        let k = st.decisions.len();
        let chosen = if k < st.replay.len() {
            let c = st.replay[k];
            if !en.contains(&c) {
                st.aborted = Some(Abort::ReplayDivergence(k));
                self.cv.notify_all();
                return;
            }
            c
        } else {
            match &mut st.policy {
                RunPolicy::Dfs => en[0],
                RunPolicy::Pct {
                    change,
                    next_change,
                    demote,
                    ..
                } => {
                    while *next_change < change.len()
                        && change[*next_change] == k
                    {
                        // demote the current front-runner so a lower
                        // priority thread takes over from here
                        *next_change += 1;
                        *demote = demote.saturating_sub(1);
                        let d = *demote;
                        if let Some(&top) = en.iter().max_by_key(|&&t| {
                            st.threads[t].priority
                        }) {
                            st.threads[top].priority = d;
                        }
                    }
                    *en.iter()
                        .max_by_key(|&&t| {
                            (st.threads[t].priority, std::cmp::Reverse(t))
                        })
                        .expect("mc: enabled set empty")
                }
            }
        };
        let chosen_idx = en
            .iter()
            .position(|&t| t == chosen)
            .expect("mc: chosen thread not enabled");
        st.decisions.push(Decision {
            alts: en,
            chosen_idx,
            prev_enabled,
        });
        grant(st, chosen);
        self.cv.notify_all();
    }
}

fn enabled(st: &ExecSt, t: usize) -> bool {
    let th = &st.threads[t];
    if th.finished || th.granted {
        return false;
    }
    match th.intent {
        Intent::Start | Intent::Step => true,
        Intent::Lock(m) => mutex_free(st, m),
        Intent::Join(x) => st.threads[x].finished,
        Intent::Recv(ch) => match st.objects[ch] {
            Obj::Channel { queued, senders } => queued > 0 || senders == 0,
            _ => false,
        },
        Intent::Wait { lock, timed, .. } => {
            let free = mutex_free(st, lock);
            if th.notified {
                free
            } else {
                timed && free && st.spurious_left > 0
            }
        }
    }
}

fn mutex_free(st: &ExecSt, m: usize) -> bool {
    matches!(st.objects[m], Obj::Mutex { held_by: None })
}

/// Resolve the chosen thread's intent and mark it runnable.
fn grant(st: &mut ExecSt, t: usize) {
    let note = match st.threads[t].intent.clone() {
        Intent::Start | Intent::Step | Intent::Join(_) => Note::Go,
        Intent::Lock(m) => {
            if let Obj::Mutex { held_by } = &mut st.objects[m] {
                *held_by = Some(t);
            }
            Note::Go
        }
        Intent::Recv(ch) => {
            if let Obj::Channel { queued, .. } = &mut st.objects[ch] {
                if *queued > 0 {
                    *queued -= 1;
                    Note::RecvReady
                } else {
                    Note::RecvClosed
                }
            } else {
                Note::Go
            }
        }
        Intent::Wait { cv, lock, .. } => {
            if let Obj::Mutex { held_by } = &mut st.objects[lock] {
                *held_by = Some(t);
            }
            if st.threads[t].notified {
                st.threads[t].notified = false;
                Note::Go
            } else {
                // timeout / spurious wakeup: leave the wait set
                if let Obj::Condvar { waiters } = &mut st.objects[cv] {
                    waiters.retain(|&w| w != t);
                }
                st.spurious_left -= 1;
                Note::TimedOut
            }
        }
    };
    st.threads[t].note = note;
    st.threads[t].granted = true;
}

/// Classify a no-enabled-thread stall: a true deadlock, or a model
/// artefact (timed waiters out of spurious budget).
fn stall_kind(st: &ExecSt) -> Abort {
    let starved_timed = st.threads.iter().any(|th| {
        !th.finished
            && !th.notified
            && matches!(
                th.intent,
                Intent::Wait { timed: true, lock, .. }
                    if mutex_free(st, lock)
            )
    });
    if starved_timed && st.spurious_left == 0 {
        return Abort::SpuriousExhausted;
    }
    let mut lines = Vec::new();
    for (i, th) in st.threads.iter().enumerate() {
        if th.finished {
            continue;
        }
        lines.push(format!(
            "  t{i} '{}': blocked on {:?}{}",
            th.name,
            th.intent,
            if th.notified { " (notified)" } else { "" }
        ));
    }
    Abort::Deadlock(lines.join("\n"))
}

// ---------------------------------------------------------------------
// exploration drivers
// ---------------------------------------------------------------------

struct RunOutcome {
    decisions: Vec<Decision>,
    aborted: Option<Abort>,
}

fn run_once(
    policy: RunPolicy,
    replay: Vec<usize>,
    max_steps: usize,
    f: &Arc<dyn Fn() + Send + Sync>,
) -> RunOutcome {
    let exec = Arc::new(Exec::new(policy, replay, max_steps));
    let t0 = exec.register_thread("main");
    let e2 = Arc::clone(&exec);
    let f2 = Arc::clone(f);
    let h = std::thread::Builder::new()
        .name("mc-main".to_string())
        .spawn(move || {
            set_ctx(&e2, t0);
            let r = panic::catch_unwind(AssertUnwindSafe(|| {
                e2.park_start(t0);
                f2();
            }));
            match r {
                Ok(()) => e2.finish(t0),
                Err(p) if is_mc_abort(p.as_ref()) => e2.finish(t0),
                Err(p) => e2.finish_panicked(t0, panic_msg(p.as_ref())),
            }
        })
        .expect("mc: failed to spawn model main thread");
    {
        let mut st = lock_st(&exec.m);
        exec.pick(&mut st, None);
    }
    exec.wait_done();
    let _ = h.join();
    let (decisions, aborted) = exec.take_result();
    RunOutcome { decisions, aborted }
}

/// Preemption cost of choosing `alts[idx]` at a decision.
fn alt_cost(d: &Decision, idx: usize) -> usize {
    usize::from(d.prev_enabled && idx > 0)
}

/// The next DFS leaf in lexicographic order within the preemption
/// budget, as a forced choice prefix; `None` when the space is done.
fn next_prefix(
    decisions: &[Decision],
    max_preemptions: usize,
) -> Option<Vec<usize>> {
    let mut before = Vec::with_capacity(decisions.len());
    let mut used = 0usize;
    for d in decisions {
        before.push(used);
        used += alt_cost(d, d.chosen_idx);
    }
    for i in (0..decisions.len()).rev() {
        let d = &decisions[i];
        for j in (d.chosen_idx + 1)..d.alts.len() {
            if before[i] + alt_cost(d, j) <= max_preemptions {
                let mut p: Vec<usize> = decisions[..i]
                    .iter()
                    .map(|d| d.alts[d.chosen_idx])
                    .collect();
                p.push(d.alts[j]);
                return Some(p);
            }
        }
    }
    None
}

/// A failing schedule, with enough detail to reproduce it.
pub struct Failure {
    /// 0-based index of the failing schedule under the policy.
    pub schedule: usize,
    /// Human-readable diagnosis (abort kind, trace, seed).
    pub detail: String,
}

fn describe(abort: &Abort) -> String {
    match abort {
        Abort::Deadlock(d) => format!("deadlock (no schedulable thread):\n{d}"),
        Abort::Panic(m) => format!("model thread panic: {m}"),
        Abort::StepLimit(n) => format!("step limit exceeded ({n} decisions)"),
        Abort::SpuriousExhausted => {
            "spurious-wakeup budget exhausted (model inconclusive)"
                .to_string()
        }
        Abort::ReplayDivergence(k) => format!(
            "replay divergence at decision {k}: the model is not \
             deterministic"
        ),
    }
}

fn trace_of(decisions: &[Decision]) -> String {
    let ids: Vec<String> = decisions
        .iter()
        .map(|d| d.alts[d.chosen_idx].to_string())
        .collect();
    ids.join(" ")
}

/// The shared exploration loop.  `Ok` when every schedule passed,
/// `Err` on the first failing schedule.
fn explore(
    name: &str,
    cfg: &Config,
    f: Arc<dyn Fn() + Send + Sync>,
) -> Result<Outcome, Failure> {
    let cap = schedule_cap();
    match cfg.policy {
        Policy::Dfs {
            max_preemptions,
            max_schedules,
        } => {
            let max_schedules = max_schedules.min(cap);
            let mut prefix: Vec<usize> = Vec::new();
            let mut schedules = 0;
            loop {
                let run = run_once(
                    RunPolicy::Dfs,
                    prefix.clone(),
                    cfg.max_steps,
                    &f,
                );
                if let Some(a) = &run.aborted {
                    return Err(Failure {
                        schedule: schedules,
                        detail: format!(
                            "model '{name}' failed under dfs schedule \
                             {schedules}: {}\nschedule trace: [{}]",
                            describe(a),
                            trace_of(&run.decisions),
                        ),
                    });
                }
                schedules += 1;
                if schedules >= max_schedules {
                    return Ok(Outcome {
                        schedules,
                        exhausted: false,
                    });
                }
                match next_prefix(&run.decisions, max_preemptions) {
                    Some(p) => prefix = p,
                    None => {
                        return Ok(Outcome {
                            schedules,
                            exhausted: true,
                        })
                    }
                }
            }
        }
        Policy::Pct {
            seed,
            schedules,
            change_points,
        } => {
            let schedules = schedules.min(cap);
            for i in 0..schedules {
                let s = seed
                    ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let mut rng = Pcg64::seeded(s);
                let mut change: Vec<usize> = (0..change_points)
                    .map(|_| rng.usize_below(PCT_EST_DECISIONS))
                    .collect();
                change.sort_unstable();
                let policy = RunPolicy::Pct {
                    rng,
                    change,
                    next_change: 0,
                    demote: change_points as u64 + 1,
                };
                let run =
                    run_once(policy, Vec::new(), cfg.max_steps, &f);
                if let Some(a) = &run.aborted {
                    return Err(Failure {
                        schedule: i,
                        detail: format!(
                            "model '{name}' failed under pct schedule \
                             {i} (seed {s:#x}): {}\nschedule trace: \
                             [{}]",
                            describe(a),
                            trace_of(&run.decisions),
                        ),
                    });
                }
            }
            Ok(Outcome {
                schedules,
                exhausted: false,
            })
        }
    }
}

/// `KLA_MC_SCHEDULES` caps schedule counts (Miri runs the mc tests
/// with a small cap; the interpreter is ~100x slower than native).
fn schedule_cap() -> usize {
    std::env::var("KLA_MC_SCHEDULES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(usize::MAX)
}

/// Explore `f` under `cfg`; panic with a reproducible diagnosis on
/// the first failing schedule.
pub fn model<F>(name: &str, cfg: Config, f: F) -> Outcome
where
    F: Fn() + Send + Sync + 'static,
{
    match explore(name, &cfg, Arc::new(f)) {
        Ok(out) => out,
        Err(fail) => panic!("{}", fail.detail),
    }
}

/// Explore `f` expecting it to fail: returns the first failure, or
/// `None` if every schedule passed (the regression tests use this to
/// prove the checker *detects* seeded bug classes).
pub fn model_expect_failure<F>(
    name: &str,
    cfg: Config,
    f: F,
) -> Option<Failure>
where
    F: Fn() + Send + Sync + 'static,
{
    explore(name, &cfg, Arc::new(f)).err()
}

//! Scoped data-parallel helpers over std threads (rayon stand-in).
//!
//! `parallel_chunks` is the workhorse of the native chunked KLA scan
//! (DESIGN.md §S8): split an index range into contiguous chunks and run a
//! closure per chunk on its own thread.

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(chunk_index, start, end)` for `n_chunks` contiguous chunks of
/// `0..len`, each on its own scoped thread.  `f` only gets disjoint ranges,
/// so callers can hand out `&mut` slices via `split_at_mut` beforehand.
pub fn parallel_ranges<F>(len: usize, n_chunks: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let n_chunks = n_chunks.clamp(1, len.max(1));
    let chunk = len.div_ceil(n_chunks);
    std::thread::scope(|scope| {
        for c in 0..n_chunks {
            let start = c * chunk;
            let end = ((c + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(c, start, end));
        }
    });
}

/// Map a closure over mutable, disjoint chunks of a slice in parallel.
/// The slice is split into `n_chunks` contiguous pieces.
pub fn parallel_map_chunks<T, F>(data: &mut [T], n_chunks: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let len = data.len();
    if len == 0 {
        return;
    }
    let n_chunks = n_chunks.clamp(1, len);
    let chunk = len.div_ceil(n_chunks);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut idx = 0;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let f = &f;
            let i = idx;
            scope.spawn(move || f(i, head));
            idx += 1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        let len = 103;
        let hits: Vec<AtomicUsize> =
            (0..len).map(|_| AtomicUsize::new(0)).collect();
        parallel_ranges(len, 7, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_chunks_mutates_disjoint() {
        let mut xs = vec![0usize; 50];
        parallel_map_chunks(&mut xs, 4, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx + 1;
            }
        });
        assert!(xs.iter().all(|&x| x > 0));
    }

    #[test]
    fn degenerate_inputs() {
        let mut empty: Vec<u8> = vec![];
        parallel_map_chunks(&mut empty, 4, |_, _| {});
        parallel_ranges(0, 4, |_, _, _| panic!("should not run"));
        parallel_ranges(3, 100, |_, s, e| assert!(e - s >= 1));
    }
}

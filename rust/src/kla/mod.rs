//! Native Rust implementation of the KLA information filter.
//!
//! Mirrors `python/compile/kernels/` (the L1 side): the Moebius precision
//! algebra, the OU prior discretisation, and three filter execution
//! strategies (sequential, scan, chunked multi-threaded).  Used for the
//! Fig. 4 compute-scaling study, property tests, and cross-validation
//! against the Python oracle.  `model` builds a full pure-Rust KLA
//! language model on top of these kernels — the native decode backend
//! the serve stack runs on without XLA artifacts (DESIGN.md §S17).

pub mod mobius;
pub mod model;
pub mod ou;
pub mod scan;

pub use mobius::{Mobius, Mobius64};
pub use model::{NativeLm, NativeLmConfig};
pub use scan::{clamp_lam, filter_blelloch_from, filter_chunked,
               filter_chunked_from, filter_scan, filter_sequential,
               filter_sequential_from, random_inputs, random_params,
               FilterInputs, FilterOutputs, FilterParams};

//! Fig. 3b: OU-prior discretisation ablation on Selective Copy.
//!
//! `kla_noou` replaces the exact OU discretisation with naive Euler;
//! the paper finds exact OU improves accuracy and stability, especially
//! at depth (deeper variants via `make artifacts-full`).

use kla::bench::exp::{bench_seeds, bench_steps, have, train_mean_acc};
use kla::bench::Suite;
use kla::data::task_by_name;
use kla::runtime::Runtime;

fn main() {
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP fig3b: {e}");
            return;
        }
    };
    let steps = bench_steps(150);
    let seeds = bench_seeds(1);
    let task = task_by_name("selective_copy").unwrap();
    let mut suite = Suite::new("fig3b_ou_ablation");
    let pairs = [
        ("mad_kla", "ou/depth1"),
        ("mad_kla_noou", "euler/depth1"),
        ("mad_kla_l2", "ou/depth2"),
        ("mad_kla_noou_l2", "euler/depth2"),
        ("mad_kla_l4", "ou/depth4"),
        ("mad_kla_noou_l4", "euler/depth4"),
    ];
    for (base, label) in pairs {
        if !have(&rt, base) {
            println!("({base} not built — `make artifacts-full` for depth)");
            continue;
        }
        let (acc, _) =
            train_mean_acc(&rt, base, task.as_ref(), steps, seeds).unwrap();
        suite.metric_row(label, vec![("acc".into(), acc)]);
    }
    suite.finish();
}

"""The KLA sequence-mixer block (paper Section 4.4, Algorithm 1, Figure 7).

Block layout follows the Mamba fused-MLP design the paper adopts:

    x ──RMSNorm──> xn ──causal-conv(K=4)──SiLU──> c
    c ──Wk──l2norm──> k   (B,T,N)   observation operator
    c ──Wq──l2norm──> q   (B,T,N)   readout operator          (QK-norm)
    c ──Wv──────────> v   (B,T,D)   token evidence
    c ──Wlam──softplus─> lam_v (B,T,D) value precision (>0)
    (a, p, dt) learnable, TIME-INVARIANT (N,D)  ──OU-discretise──> abar, pbar
    filter(k, q, v, lam_v, abar, pbar, lam0, 0) ──> lam, eta, y
    out = (y * SiLU(xn Wg)) Wo                (gated output, residual outside)

Selectivity comes *only* from the uncertainty ratios of the Moebius precision
recursion — the dynamics parameters are global, unlike Mamba's
token-dependent Delta_t (paper Section 4.1 'Multi-channel specialisation').
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax.nn import softplus

from ..kernels import kla_filter, kla_posterior_moments
from ..kernels.ou import discretise_raw
from .common import causal_conv1d, dense_init, l2norm, rmsnorm, silu

LAMV_FLOOR = 1e-4
LAM0_FLOOR = 1e-3


def init_kla_block(rng: np.random.Generator, d: int, n_state: int,
                   conv_kernel: int = 4) -> dict:
    """Parameter dict for one KLA block (see flatten_params for the ABI)."""
    N = n_state
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "conv_w": jnp.asarray(rng.normal(0, 0.2, (conv_kernel, d)), jnp.float32),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "wk": dense_init(rng, d, N),
        "wq": dense_init(rng, d, N),
        "wv": dense_init(rng, d, d),
        "wlam": dense_init(rng, d, d, scale=0.5),
        "blam": jnp.full((d,), 0.5413, jnp.float32),  # softplus(0.5413)=1.0
        # OU prior: raw params -> (a, p, dt) via kernels.ou.constrain.
        # p init 0.01 (paper G.2); softplus^{-1}(0.01) ~= -4.6.
        "a_raw": jnp.asarray(rng.uniform(-1.0, 1.0, (N, d)), jnp.float32),
        "p_raw": jnp.full((N, d), -4.6, jnp.float32),
        "dt_raw": jnp.asarray(rng.uniform(-1.0, 1.0, (N, d)), jnp.float32),
        "lam0_raw": jnp.full((N, d), 0.5413, jnp.float32),
        "wg": dense_init(rng, d, d),
        "wo": dense_init(rng, d, d, scale=0.5),
    }


def kla_dynamics(p: dict, *, process_noise: bool = True,
                 ou_exact: bool = True):
    """(abar, pbar, lam0) from raw block params — shared by the parallel
    forward, the O(1) decode step, and the native-Rust export."""
    abar, pbar = discretise_raw(p["a_raw"], p["p_raw"], p["dt_raw"],
                                process_noise=process_noise,
                                ou_exact=ou_exact)
    lam0 = softplus(p["lam0_raw"]) + LAM0_FLOOR
    return abar, pbar, lam0


def kla_projections(p: dict, xn: jnp.ndarray):
    """Token-dependent likelihood/readout parameters from the normed input.

    xn: (B, T, D) (already RMS-normed).  Returns (k, q, v, lam_v, gate)."""
    c = silu(causal_conv1d(xn, p["conv_w"], p["conv_b"]))
    k = l2norm(c @ p["wk"])                       # (B, T, N)
    q = l2norm(c @ p["wq"])                       # (B, T, N)
    v = c @ p["wv"]                               # (B, T, D)
    lam_v = softplus(c @ p["wlam"] + p["blam"]) + LAMV_FLOOR
    gate = silu(xn @ p["wg"])
    return k, q, v, lam_v, gate


def kla_block(p: dict, x: jnp.ndarray, *, impl: str = "scan",
              process_noise: bool = True, ou_exact: bool = True,
              want_variance: bool = False):
    """One residual KLA block.  x: (B, T, D) -> (B, T, D)[, y_var]."""
    xn = rmsnorm(x, p["norm"])
    k, q, v, lam_v, gate = kla_projections(p, xn)
    abar, pbar, lam0 = kla_dynamics(p, process_noise=process_noise,
                                    ou_exact=ou_exact)
    eta0 = jnp.zeros_like(lam0)
    lam, eta, y = kla_filter(k, q, v, lam_v, abar, pbar, lam0, eta0,
                             impl=impl)
    out = x + (y * gate) @ p["wo"]
    if want_variance:
        _, y_var = kla_posterior_moments(lam, eta, q)
        return out, y_var
    return out


def kla_block_sample(p: dict, x: jnp.ndarray, eps: jnp.ndarray, *,
                     impl: str = "scan", process_noise: bool = True,
                     ou_exact: bool = True):
    """KLA+ probabilistic decoding path: one posterior sample of the readout,
    y_s = y_mu + sqrt(y_var) * eps  (eps: (B, T, D) standard normal).
    Used by the Monte-Carlo marginal-likelihood loss (paper Eq. 24-25)."""
    xn = rmsnorm(x, p["norm"])
    k, q, v, lam_v, gate = kla_projections(p, xn)
    abar, pbar, lam0 = kla_dynamics(p, process_noise=process_noise,
                                    ou_exact=ou_exact)
    eta0 = jnp.zeros_like(lam0)
    lam, eta, _ = kla_filter(k, q, v, lam_v, abar, pbar, lam0, eta0,
                             impl=impl)
    y_mu, y_var = kla_posterior_moments(lam, eta, q)
    y = y_mu + jnp.sqrt(jnp.maximum(y_var, 0.0)) * eps
    return x + (y * gate) @ p["wo"]


def kla_block_step(p: dict, x_t: jnp.ndarray, conv_state, lam_prev, eta_prev,
                   *, process_noise: bool = True, ou_exact: bool = True):
    """O(1) recurrent decode step (serving path; also the Fig. 4 'naive
    recurrent Kalman' baseline when driven T times).

    x_t: (B, D); conv_state: (B, K-1, D); lam_prev, eta_prev: (B, N, D).
    Returns (out_t, conv_state', lam, eta).
    """
    from .common import conv_state_step
    xn = rmsnorm(x_t, p["norm"])
    cy, conv_state = conv_state_step(conv_state, xn, p["conv_w"], p["conv_b"])
    c = silu(cy)
    k = l2norm(c @ p["wk"])                       # (B, N)
    q = l2norm(c @ p["wq"])
    v = c @ p["wv"]                               # (B, D)
    lam_v = softplus(c @ p["wlam"] + p["blam"]) + LAMV_FLOOR
    abar, pbar, _ = kla_dynamics(p, process_noise=process_noise,
                                 ou_exact=ou_exact)
    phi = (k[:, :, None] ** 2) * lam_v[:, None, :]            # (B, N, D)
    rho = 1.0 / (abar * abar + pbar * lam_prev)
    lam = jnp.clip(rho * lam_prev + phi, 1e-6, 1e8)
    eta = (rho * abar) * eta_prev + k[:, :, None] * (lam_v * v)[:, None, :]
    y = jnp.einsum("bn,bnd->bd", q, eta / lam)
    gate = silu(xn @ p["wg"])
    out = x_t + (y * gate) @ p["wo"]
    return out, conv_state, lam, eta

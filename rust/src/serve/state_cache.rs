//! Belief-state cache manager — the O(1)-state analogue of a KV-cache
//! manager (DESIGN.md §S15).
//!
//! A KLA model's per-sequence decode state is CONSTANT-SIZE: a causal-conv
//! window plus the posterior (precision, information mean).  Slots live in
//! the batch dimension of one `DecodeState`; the manager hands out slots,
//! resets them to the learned prior on release, and supports snapshotting
//! a slot's belief state for conversation resume (the belief-state
//! analogue of prefix caching).

use anyhow::{bail, Result};

use crate::runtime::session::DecodeState;

/// Snapshot of one slot's state (conv window + posterior).
#[derive(Clone, Debug)]
pub struct SlotSnapshot {
    pub conv: Vec<f32>,
    pub lam: Vec<f32>,
    pub eta: Vec<f32>,
}

pub struct BeliefStateCache {
    /// live batched state, shapes (L,B,K-1,D) / (L,B,N,D) / (L,B,N,D)
    state: DecodeState,
    init: DecodeState,
    free: Vec<usize>,
    batch: usize,
    layers: usize,
    conv_row: usize, // (K-1)*D
    post_row: usize, // N*D
}

impl BeliefStateCache {
    pub fn new(init: DecodeState) -> Self {
        let s = init.lam.shape();
        let (layers, batch) = (s[0], s[1]);
        let post_row = s[2] * s[3];
        let cs = init.conv.shape();
        let conv_row = cs[2] * cs[3];
        BeliefStateCache {
            state: init.clone(),
            init,
            free: (0..batch).rev().collect(),
            batch,
            layers,
            conv_row,
            post_row,
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Claim a fresh slot (state reset to the prior).
    pub fn acquire(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.reset_slot(slot);
        Some(slot)
    }

    /// Release a slot back to the pool.
    pub fn release(&mut self, slot: usize) {
        debug_assert!(slot < self.batch);
        debug_assert!(!self.free.contains(&slot));
        self.free.push(slot);
    }

    /// Reset one slot's state to the learned prior (lam0, zeros).
    pub fn reset_slot(&mut self, slot: usize) {
        for l in 0..self.layers {
            let c0 = (l * self.batch + slot) * self.conv_row;
            self.state.conv.data_mut()[c0..c0 + self.conv_row]
                .copy_from_slice(
                    &self.init.conv.data()[c0..c0 + self.conv_row]);
            let p0 = (l * self.batch + slot) * self.post_row;
            self.state.lam.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(
                    &self.init.lam.data()[p0..p0 + self.post_row]);
            self.state.eta.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(
                    &self.init.eta.data()[p0..p0 + self.post_row]);
        }
    }

    /// Snapshot a slot (e.g. end of a conversation turn).
    pub fn snapshot(&self, slot: usize) -> SlotSnapshot {
        let mut snap = SlotSnapshot {
            conv: Vec::with_capacity(self.layers * self.conv_row),
            lam: Vec::with_capacity(self.layers * self.post_row),
            eta: Vec::with_capacity(self.layers * self.post_row),
        };
        for l in 0..self.layers {
            let c0 = (l * self.batch + slot) * self.conv_row;
            snap.conv
                .extend_from_slice(&self.state.conv.data()[c0..c0 + self.conv_row]);
            let p0 = (l * self.batch + slot) * self.post_row;
            snap.lam
                .extend_from_slice(&self.state.lam.data()[p0..p0 + self.post_row]);
            snap.eta
                .extend_from_slice(&self.state.eta.data()[p0..p0 + self.post_row]);
        }
        snap
    }

    /// Restore a previously snapshotted belief state into a slot.
    pub fn restore(&mut self, slot: usize, snap: &SlotSnapshot) -> Result<()> {
        if snap.lam.len() != self.layers * self.post_row {
            bail!("snapshot shape mismatch");
        }
        for l in 0..self.layers {
            let c0 = (l * self.batch + slot) * self.conv_row;
            self.state.conv.data_mut()[c0..c0 + self.conv_row]
                .copy_from_slice(
                    &snap.conv[l * self.conv_row..(l + 1) * self.conv_row]);
            let p0 = (l * self.batch + slot) * self.post_row;
            self.state.lam.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(
                    &snap.lam[l * self.post_row..(l + 1) * self.post_row]);
            self.state.eta.data_mut()[p0..p0 + self.post_row]
                .copy_from_slice(
                    &snap.eta[l * self.post_row..(l + 1) * self.post_row]);
        }
        Ok(())
    }

    pub fn state(&self) -> &DecodeState {
        &self.state
    }

    /// Overwrite the whole batched state (after a decode step).
    pub fn set_state(&mut self, state: DecodeState) {
        debug_assert_eq!(state.lam.shape(), self.state.lam.shape());
        self.state = state;
    }

    /// Mean posterior variance (1/lam) of a slot — the serving-side
    /// uncertainty signal (paper §7: epistemic uncertainty applications).
    pub fn slot_uncertainty(&self, slot: usize) -> f32 {
        let mut acc = 0.0f64;
        let mut n = 0usize;
        for l in 0..self.layers {
            let p0 = (l * self.batch + slot) * self.post_row;
            for &lam in &self.state.lam.data()[p0..p0 + self.post_row] {
                acc += 1.0 / lam.max(1e-9) as f64;
                n += 1;
            }
        }
        (acc / n.max(1) as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tiny_state() -> DecodeState {
        let (l, b, k1, d, n) = (2, 3, 3, 4, 2);
        let mut lam = Tensor::zeros(&[l, b, n, d]);
        lam.data_mut().iter_mut().for_each(|x| *x = 1.5);
        DecodeState {
            conv: Tensor::zeros(&[l, b, k1, d]),
            lam,
            eta: Tensor::zeros(&[l, b, n, d]),
        }
    }

    #[test]
    fn acquire_release_cycle() {
        let mut cache = BeliefStateCache::new(tiny_state());
        assert_eq!(cache.free_slots(), 3);
        let a = cache.acquire().unwrap();
        let b = cache.acquire().unwrap();
        assert_ne!(a, b);
        assert_eq!(cache.free_slots(), 1);
        cache.release(a);
        assert_eq!(cache.free_slots(), 2);
        let c = cache.acquire().unwrap();
        let d = cache.acquire().unwrap();
        assert_eq!(cache.free_slots(), 0);
        assert!(cache.acquire().is_none());
        let _ = (c, d);
    }

    #[test]
    fn reset_restores_prior() {
        let mut cache = BeliefStateCache::new(tiny_state());
        let slot = cache.acquire().unwrap();
        // dirty the slot
        let mut s = cache.state().clone();
        s.lam.data_mut().iter_mut().for_each(|x| *x = 99.0);
        cache.set_state(s);
        cache.reset_slot(slot);
        // slot entries back to 1.5; others still 99
        let lam = cache.state().lam.clone();
        assert_eq!(lam.get(&[0, slot, 0, 0]), 1.5);
        let other = (slot + 1) % 3;
        assert_eq!(lam.get(&[0, other, 0, 0]), 99.0);
    }

    #[test]
    fn snapshot_roundtrip() {
        let mut cache = BeliefStateCache::new(tiny_state());
        let slot = cache.acquire().unwrap();
        let mut s = cache.state().clone();
        s.eta.data_mut().iter_mut().for_each(|x| *x = 7.0);
        cache.set_state(s);
        let snap = cache.snapshot(slot);
        cache.reset_slot(slot);
        assert_eq!(cache.state().eta.get(&[0, slot, 0, 0]), 0.0);
        cache.restore(slot, &snap).unwrap();
        assert_eq!(cache.state().eta.get(&[0, slot, 0, 0]), 7.0);
    }

    #[test]
    fn uncertainty_decreases_with_precision() {
        let mut cache = BeliefStateCache::new(tiny_state());
        let u0 = cache.slot_uncertainty(0);
        let mut s = cache.state().clone();
        s.lam.data_mut().iter_mut().for_each(|x| *x = 100.0);
        cache.set_state(s);
        assert!(cache.slot_uncertainty(0) < u0);
    }
}

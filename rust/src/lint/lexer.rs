//! A minimal, dependency-free Rust lexer for `repro-lint`.
//!
//! This is NOT a full Rust lexer — it is exactly enough to make the
//! repo's own lint passes reliable on token streams instead of raw
//! text, which is what kills grep-based linting: string literals that
//! contain `unwrap(`, comments that mention `panic!`, lifetimes that
//! look like char literals, and raw strings holding JSON protocol
//! examples.  Every token carries the 1-based source line it starts
//! on so findings and waivers can be anchored precisely.
//!
//! Handled: line comments (`//`, `///`, `//!`), nesting block
//! comments, string / raw-string / byte-string / char literals,
//! lifetime-vs-char-literal disambiguation, identifiers, numeric
//! literals (including float vs `..` range ambiguity), and
//! single-char punctuation.  Multi-char operators are emitted as
//! consecutive single-char `Punct` tokens — the passes match on
//! short token sequences, so this keeps the lexer trivially
//! verifiable.

/// One lexed token kind.  `Str` carries the literal's decoded-enough
/// content (escapes left as-is) so passes can inspect protocol
/// strings; comments carry their text so waiver and SAFETY parsing
/// work on the token stream alone.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier or keyword (`unwrap`, `unsafe`, `as`, ...).
    Ident(String),
    /// A single punctuation character (`.`, `[`, `!`, ...).
    Punct(char),
    /// String literal content (without quotes / raw-string hashes).
    Str(String),
    /// Char or byte literal (content irrelevant to the passes).
    Char,
    /// Lifetime such as `'a` or `'static`.
    Lifetime,
    /// Numeric literal (raw text, suffix included).
    Num(String),
    /// `//`-style comment; content excludes the leading `//`.
    LineComment(String),
    /// `/* ... */` comment (nesting); content excludes delimiters.
    BlockComment(String),
}

/// A token plus the 1-based line it starts on.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub line: usize,
    pub tok: Tok,
}

impl Token {
    /// The identifier text, if this token is an `Ident`.
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// True if this token is the given punctuation character.
    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }

    /// True if this token is a line or block comment.
    pub fn is_comment(&self) -> bool {
        matches!(self.tok, Tok::LineComment(_) | Tok::BlockComment(_))
    }

    /// Comment text (line or block), if this token is a comment.
    pub fn comment_text(&self) -> Option<&str> {
        match &self.tok {
            Tok::LineComment(s) | Tok::BlockComment(s) => Some(s.as_str()),
            _ => None,
        }
    }
}

struct Cursor<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
}

impl<'a> Cursor<'a> {
    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn peek_at(&self, off: usize) -> Option<u8> {
        self.src.get(self.pos + off).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
        }
        Some(c)
    }
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_continue(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Lex `src` into a token stream.  Unknown bytes (non-ASCII in code
/// position, stray quotes at EOF, ...) are skipped rather than
/// reported: the lint must never panic or error on the tree it
/// audits, and the passes only need the tokens they match on.
pub fn lex(src: &str) -> Vec<Token> {
    let mut cur = Cursor { src: src.as_bytes(), pos: 0, line: 1 };
    let mut out = Vec::new();
    while let Some(c) = cur.peek() {
        let line = cur.line;
        match c {
            b' ' | b'\t' | b'\r' | b'\n' => {
                cur.bump();
            }
            b'/' if cur.peek_at(1) == Some(b'/') => {
                out.push(Token { line, tok: lex_line_comment(&mut cur) });
            }
            b'/' if cur.peek_at(1) == Some(b'*') => {
                out.push(Token { line, tok: lex_block_comment(&mut cur) });
            }
            b'"' => {
                out.push(Token { line, tok: lex_string(&mut cur) });
            }
            b'\'' => {
                out.push(Token { line, tok: lex_quote(&mut cur) });
            }
            _ if c.is_ascii_digit() => {
                out.push(Token { line, tok: lex_number(&mut cur) });
            }
            _ if is_ident_start(c) => {
                out.push(Token { line, tok: lex_word(&mut cur) });
            }
            _ => {
                cur.bump();
                if c.is_ascii_graphic() {
                    out.push(Token { line, tok: Tok::Punct(c as char) });
                }
            }
        }
    }
    out
}

fn lex_line_comment(cur: &mut Cursor) -> Tok {
    cur.bump(); // '/'
    cur.bump(); // '/'
    let start = cur.pos;
    while let Some(c) = cur.peek() {
        if c == b'\n' {
            break;
        }
        cur.bump();
    }
    let text = String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned();
    Tok::LineComment(text)
}

fn lex_block_comment(cur: &mut Cursor) -> Tok {
    cur.bump(); // '/'
    cur.bump(); // '*'
    let start = cur.pos;
    let mut depth = 1usize;
    let mut end = cur.pos;
    while let Some(c) = cur.peek() {
        if c == b'/' && cur.peek_at(1) == Some(b'*') {
            depth += 1;
            cur.bump();
            cur.bump();
        } else if c == b'*' && cur.peek_at(1) == Some(b'/') {
            depth -= 1;
            end = cur.pos;
            cur.bump();
            cur.bump();
            if depth == 0 {
                break;
            }
        } else {
            cur.bump();
        }
    }
    if depth != 0 {
        end = cur.pos; // unterminated: treat rest of file as comment
    }
    let text = String::from_utf8_lossy(&cur.src[start..end]).into_owned();
    Tok::BlockComment(text)
}

/// Lex a `"..."` literal; `cur` sits on the opening quote.
fn lex_string(cur: &mut Cursor) -> Tok {
    cur.bump(); // '"'
    let start = cur.pos;
    let mut end = cur.pos;
    loop {
        match cur.peek() {
            None => {
                end = cur.pos;
                break;
            }
            Some(b'\\') => {
                cur.bump();
                cur.bump();
            }
            Some(b'"') => {
                end = cur.pos;
                cur.bump();
                break;
            }
            Some(_) => {
                cur.bump();
            }
        }
    }
    let text = String::from_utf8_lossy(&cur.src[start..end]).into_owned();
    Tok::Str(text)
}

/// Lex `r"..."` / `r#"..."#` (any hash depth); `cur` sits on the
/// first `#` or the opening quote, just after the `r`/`br` prefix
/// was consumed as part of `lex_word`.
fn lex_raw_string(cur: &mut Cursor) -> Tok {
    let mut hashes = 0usize;
    while cur.peek() == Some(b'#') {
        hashes += 1;
        cur.bump();
    }
    cur.bump(); // opening '"'
    let start = cur.pos;
    let mut end = cur.pos;
    'scan: while let Some(c) = cur.peek() {
        if c == b'"' {
            // Check for `"` followed by `hashes` many `#`.
            for i in 0..hashes {
                if cur.peek_at(1 + i) != Some(b'#') {
                    end = cur.pos;
                    cur.bump();
                    continue 'scan;
                }
            }
            end = cur.pos;
            cur.bump(); // closing '"'
            for _ in 0..hashes {
                cur.bump();
            }
            break;
        }
        cur.bump();
        end = cur.pos;
    }
    let text = String::from_utf8_lossy(&cur.src[start..end]).into_owned();
    Tok::Str(text)
}

/// Lex after a `'`: either a lifetime (`'a`, `'static`) or a char
/// literal (`'x'`, `'\n'`, `'\''`).
fn lex_quote(cur: &mut Cursor) -> Tok {
    cur.bump(); // '\''
    match cur.peek() {
        Some(b'\\') => {
            // Escaped char literal: consume escape then to closing quote.
            cur.bump();
            cur.bump();
            while let Some(c) = cur.peek() {
                cur.bump();
                if c == b'\'' {
                    break;
                }
            }
            Tok::Char
        }
        Some(c) if is_ident_start(c) || c.is_ascii_digit() => {
            // Could be 'a' (char) or 'a / 'static (lifetime).
            let mut off = 0usize;
            while let Some(n) = cur.peek_at(off) {
                if !is_ident_continue(n) {
                    break;
                }
                off += 1;
            }
            let is_char = cur.peek_at(off) == Some(b'\'');
            for _ in 0..off {
                cur.bump();
            }
            if is_char {
                cur.bump(); // closing '\''
                Tok::Char
            } else {
                Tok::Lifetime
            }
        }
        Some(_) => {
            // Non-identifier char literal like '(' or '"'.
            cur.bump();
            if cur.peek() == Some(b'\'') {
                cur.bump();
            }
            Tok::Char
        }
        None => Tok::Char,
    }
}

fn lex_number(cur: &mut Cursor) -> Tok {
    let start = cur.pos;
    while let Some(c) = cur.peek() {
        if is_ident_continue(c) {
            cur.bump();
        } else if c == b'.' {
            // Float continues only if the next byte is a digit; this
            // keeps `0..n` as Num(0) Punct(.) Punct(.) Ident(n).
            match cur.peek_at(1) {
                Some(d) if d.is_ascii_digit() => {
                    cur.bump();
                }
                _ => break,
            }
        } else {
            break;
        }
    }
    let text = String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned();
    Tok::Num(text)
}

fn lex_word(cur: &mut Cursor) -> Tok {
    let start = cur.pos;
    while let Some(c) = cur.peek() {
        if !is_ident_continue(c) {
            break;
        }
        cur.bump();
    }
    let word = String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned();
    // Raw / byte string prefixes: r"..", r#"..."#, b"..", br#"..."#.
    if matches!(word.as_str(), "r" | "b" | "br" | "rb") {
        match cur.peek() {
            Some(b'"') => return lex_raw_or_plain(cur, &word),
            Some(b'#') if word != "b" => {
                // `r#ident` raw identifiers don't occur in this repo;
                // `r#"`-style raw strings do.
                if looks_like_raw_string(cur) {
                    return lex_raw_string(cur);
                }
            }
            _ => {}
        }
    }
    Tok::Ident(word)
}

/// After an `r`/`b`/`br` prefix sitting on a `"`: byte strings (`b"`)
/// have escapes like plain strings; raw strings (`r"`, `br"`) do not.
fn lex_raw_or_plain(cur: &mut Cursor, prefix: &str) -> Tok {
    if prefix == "b" {
        lex_string(cur)
    } else {
        lex_raw_string(cur)
    }
}

/// True when the `#`-run after an `r` prefix ends in a `"` — i.e.
/// this really is `r#"..."#` and not the raw identifier `r#foo`.
fn looks_like_raw_string(cur: &Cursor) -> bool {
    let mut off = 0usize;
    while cur.peek_at(off) == Some(b'#') {
        off += 1;
    }
    cur.peek_at(off) == Some(b'"')
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| t.ident().map(str::to_string))
            .collect()
    }

    #[test]
    fn strings_and_comments_do_not_leak_idents() {
        let src = r##"
            let x = "call unwrap() here"; // unwrap() in comment
            /* panic! in /* nested */ block */
            let y = r#"json "unwrap" body"#;
        "##;
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "x", "let", "y"]);
    }

    #[test]
    fn lifetime_vs_char_literal() {
        let toks = lex("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes =
            toks.iter().filter(|t| t.tok == Tok::Lifetime).count();
        let chars = toks.iter().filter(|t| t.tok == Tok::Char).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn range_is_not_a_float() {
        let toks = lex("for i in 0..10 {}");
        let nums: Vec<_> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Num(n) => Some(n.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec!["0", "10"]);
    }

    #[test]
    fn float_literals_survive() {
        let toks = lex("let x = 1.5e-3f64;");
        assert!(toks
            .iter()
            .any(|t| matches!(&t.tok, Tok::Num(n) if n.starts_with("1.5"))));
    }

    #[test]
    fn line_numbers_are_one_based_and_advance() {
        let toks = lex("a\nb\n\nc");
        let lines: Vec<_> = toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }

    #[test]
    fn string_content_is_captured() {
        let toks = lex(r#"err_reply(id, "bad-json", "parse error")"#);
        let strs: Vec<_> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(strs, vec!["bad-json", "parse error"]);
    }

    #[test]
    fn comment_text_is_captured() {
        let toks = lex("x; // lint: allow(panic, fixture)\n");
        let c = toks
            .iter()
            .find_map(|t| t.comment_text())
            .unwrap_or_default();
        assert!(c.contains("lint: allow(panic, fixture)"));
    }
}

fn main() {}

"""Artifact manifest: every AOT-compiled XLA module the Rust coordinator
may load, as declarative specs (DESIGN.md §6).

A spec = (family, model kind + config, batch/seq shape, roles).  Artifact
names are `{family}_{tag}_{role}` and each emits
`artifacts/{name}.hlo.txt` + `artifacts/{name}.meta.json`.

`default` manifest covers tests, examples and the default bench grids;
`full` adds the deep/sweep configs (Fig. 1a depth sweep, MQAR dim sweep,
Table 4 extra models, long-T scaling points).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .models.lm import ModelConfig
from .train_step import OptConfig


@dataclass(frozen=True)
class ArtifactSpec:
    family: str            # mad | mqar | a5 | lm | fig4 | serve
    tag: str               # unique within family (model kind + variant)
    model: ModelConfig
    opt: OptConfig
    batch: int
    seq: int
    roles: tuple           # subset of init/train/eval/score/logits/variance/decode

    @property
    def base_name(self) -> str:
        return f"{self.family}_{self.tag}"

    def artifact_name(self, role: str) -> str:
        return f"{self.base_name}_{role}"


# --------------------------------------------------------------- configs ---
# Shapes are CPU-budget scaled versions of the paper's (Appendix F/G);
# DESIGN.md §3 documents each substitution.

MAD = dict(vocab=64, d_model=64, n_layers=1, n_state=8)
MAD_B, MAD_T = 32, 128
MAD_OPT = OptConfig(lr=2e-3, total_steps=400)

MQAR = dict(vocab=64, n_layers=2, n_state=8)
MQAR_B, MQAR_T = 16, 256
MQAR_OPT = OptConfig(lr=2e-3, total_steps=600)

A5 = dict(vocab=64, d_model=64, n_state=8)
A5_B, A5_T = 32, 24
A5_OPT = OptConfig(lr=1e-3, total_steps=600)

LM = dict(vocab=512, d_model=128, n_layers=2, n_state=8)
LM_B, LM_T = 16, 128
LM_OPT = OptConfig(lr=1e-3, total_steps=800)

TRAIN_ROLES = ("init", "train", "eval")


def _mk(family, tag, model, opt, batch, seq, roles):
    return ArtifactSpec(family, tag, model, opt, batch, seq, tuple(roles))


def default_specs():
    specs = []

    # ---- Fig. 5a MAD suite: one artifact set per mixer (single block) ----
    for kind in ("kla", "kla_plus", "mamba", "gla", "gdn"):
        mc = 4 if kind == "kla_plus" else 0
        m = ModelConfig(kind="kla" if kind == "kla_plus" else kind,
                        mc_samples=mc, **MAD)
        roles = list(TRAIN_ROLES)
        if kind == "kla":
            roles += ["variance", "logits"]   # Fig. 5b + attention maps
        specs.append(_mk("mad", kind, m, MAD_OPT, MAD_B, MAD_T, roles))

    # ---- Fig. 6b / Table 6: process-noise ablation ----
    specs.append(_mk("mad", "kla_nonoise",
                     ModelConfig(kind="kla", process_noise=False, **MAD),
                     MAD_OPT, MAD_B, MAD_T, TRAIN_ROLES))

    # ---- Fig. 3b: OU-discretisation ablation (depth 1 default) ----
    specs.append(_mk("mad", "kla_noou",
                     ModelConfig(kind="kla", ou_exact=False, **MAD),
                     MAD_OPT, MAD_B, MAD_T, TRAIN_ROLES))

    # ---- Fig. 6a MQAR (d=64 point in default; sweep in full) ----
    for kind in ("kla", "mamba", "gla", "gdn"):
        m = ModelConfig(kind=kind, d_model=64, **MQAR)
        specs.append(_mk("mqar", f"{kind}_d64", m, MQAR_OPT,
                         MQAR_B, MQAR_T, TRAIN_ROLES))

    # ---- Fig. 1a A5 state tracking: depth sweep 1-2 in default ----
    for kind in ("kla", "mamba", "gpt", "gla"):
        for L in (1, 2):
            m = ModelConfig(kind=kind, n_layers=L, **A5)
            specs.append(_mk("a5", f"{kind}_l{L}", m, A5_OPT,
                             A5_B, A5_T, TRAIN_ROLES))

    # ---- Table 4 / Fig. 1b LM pretraining (scaled) ----
    for kind in ("kla", "gpt", "hybrid_kla"):
        m = ModelConfig(kind=kind, **LM)
        specs.append(_mk("lm", kind, m, LM_OPT, LM_B, LM_T,
                         list(TRAIN_ROLES) + ["score"]))

    # ---- Serving / Fig. 4 recurrent path: KLA decode step ----
    serve_model = ModelConfig(kind="kla", **LM)
    specs.append(_mk("serve", "kla_b8", serve_model, LM_OPT, 8, 1,
                     ("decode",)))
    specs.append(_mk("serve", "kla_b1", serve_model, LM_OPT, 1, 1,
                     ("decode",)))

    # ---- Fig. 4 scan path: forward-only KLA block at growing T ----
    fig4_model_scan = ModelConfig(kind="kla", impl="scan", **MAD)
    fig4_model_pallas = ModelConfig(kind="kla", impl="pallas", **MAD)
    for T in (128, 512, 2048):
        specs.append(_mk("fig4", f"scan_t{T}", fig4_model_scan, MAD_OPT,
                         1, T, ("logits",)))
    specs.append(_mk("fig4", "pallas_t512", fig4_model_pallas, MAD_OPT,
                     1, 512, ("logits",)))
    # recurrent baseline at MAD shape (driven per-token from Rust)
    specs.append(_mk("fig4", "kla_decode_b1",
                     ModelConfig(kind="kla", **MAD), MAD_OPT, 1, 1,
                     ("init", "decode")))
    return specs


def full_specs():
    """Extra grid for the sweep benches (built by `make artifacts-full`)."""
    specs = []
    # MQAR dimension sweep
    for kind in ("kla", "mamba", "gla", "gdn"):
        for d in (32, 128):
            m = ModelConfig(kind=kind, d_model=d, **MQAR)
            specs.append(_mk("mqar", f"{kind}_d{d}", m, MQAR_OPT,
                             MQAR_B, MQAR_T, TRAIN_ROLES))
    # A5 deeper baselines (linear mixers need depth to track state)
    for kind in ("mamba", "gpt", "gla"):
        for L in (3, 4):
            m = ModelConfig(kind=kind, n_layers=L, **A5)
            specs.append(_mk("a5", f"{kind}_l{L}", m, A5_OPT,
                             A5_B, A5_T, TRAIN_ROLES))
    # Table 4 remaining mixers
    for kind in ("mamba", "gdn", "hybrid_mamba", "hybrid_gdn"):
        m = ModelConfig(kind=kind, **LM)
        specs.append(_mk("lm", kind, m, LM_OPT, LM_B, LM_T,
                         list(TRAIN_ROLES) + ["score"]))
    # KLA+ at LM scale
    specs.append(_mk("lm", "kla_plus", ModelConfig(kind="kla", mc_samples=4, **LM),
                     LM_OPT, LM_B, LM_T, list(TRAIN_ROLES) + ["score"]))
    # Fig. 3b deeper OU ablation
    for ou, tag in ((True, "kla_l2"), (False, "kla_noou_l2"),
                    (True, "kla_l4"), (False, "kla_noou_l4")):
        L = int(tag[-1])
        m = ModelConfig(kind="kla", ou_exact=ou,
                        **{**MAD, "n_layers": L})
        specs.append(_mk("mad", tag, m, MAD_OPT, MAD_B, MAD_T, TRAIN_ROLES))
    # Long-T scaling point
    specs.append(_mk("fig4", "scan_t8192",
                     ModelConfig(kind="kla", impl="scan", **MAD),
                     MAD_OPT, 1, 8192, ("logits",)))
    return specs


def manifest(name: str):
    if name == "default":
        return default_specs()
    if name == "full":
        return default_specs() + full_specs()
    raise ValueError(f"unknown manifest {name!r}")

fn main() {}

//! Serving integration: the real TCP server — request/response protocol,
//! continuous batching under concurrent load, determinism of greedy
//! decoding, and error handling.
//!
//! The `native_*` tests run the WHOLE stack (server, router threads,
//! engine, scheduler, belief cache) on the pure-Rust `NativeBackend`
//! with no artifacts, so they execute everywhere — CI greps their output
//! and fails on any SKIP.  `serve_end_to_end` is the same flow on the
//! XLA artifact backend and still skips gracefully without artifacts.

mod common;

use std::sync::atomic::AtomicBool;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use common::{native_cfg, small_lm, tokens_of};
use kla::config::ServeConfig;
use kla::runtime::{DecodeBackend, NativeBackend, Runtime};
use kla::serve::{run_engine, serve, serve_native, Client, EngineRequest,
                 EngineResponse, RequestOpts, SamplerConfig};
use kla::util::Json;

/// Send a raw protocol line and parse the reply (for malformed requests
/// the typed `Client` cannot express).
fn send_raw(addr: &str, line: &str) -> Json {
    use std::io::{BufRead, Write};
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
    w.flush().unwrap();
    let mut r = std::io::BufReader::new(stream);
    let mut reply = String::new();
    r.read_line(&mut reply).unwrap();
    kla::util::json::parse(reply.trim()).unwrap()
}

fn err_code(r: &Json) -> String {
    r.req("err")
        .unwrap()
        .req("code")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string()
}

fn setup() -> Option<(std::path::PathBuf, Vec<kla::runtime::Value>)> {
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            return None;
        }
    };
    let init = rt.load("lm_kla_init").unwrap();
    let params = init.run(&[]).unwrap();
    Some((rt.dir().to_path_buf(), params))
}

#[test]
fn serve_end_to_end() {
    let Some((dir, params)) = setup() else { return };
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(), // ephemeral port
        artifact: "serve_kla_b8".into(),
        max_batch: 8,
        batch_window_us: 200,
        max_new_tokens: 4,
        state_pool: 8,
        ..Default::default()
    };
    let handle = serve(dir, "serve_kla_b8".into(), params, &cfg).unwrap();
    let addr = handle.addr.clone();

    // ping
    let mut c = Client::connect(&addr).unwrap();
    assert!(c.ping().unwrap().req("ok").unwrap().as_bool().unwrap());

    // single request
    let r = c.request(&[5, 6, 7], 4).unwrap();
    let toks = r.req("tokens").unwrap().as_arr().unwrap();
    assert_eq!(toks.len(), 4);
    assert!(r.req("total_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert!(r.req("uncertainty").unwrap().as_f64().unwrap() > 0.0);

    // greedy decoding is deterministic: same prompt -> same tokens
    let r2 = c.request(&[5, 6, 7], 4).unwrap();
    assert_eq!(r.req("tokens").unwrap(), r2.req("tokens").unwrap());

    // concurrent load: more requests than slots (12 > max_batch 8), varied
    // prompt lengths — the overflow requests must wait for a free slot,
    // which has to show up as a nonzero queue_ms (measured submit->admit;
    // the old engine stamped admit time at submit, so this was always 0).
    let mut joins = Vec::new();
    for i in 0..12u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let prompt: Vec<i32> =
                (0..(1 + i % 5)).map(|j| (i + j) as i32 % 64).collect();
            let r = c.request(&prompt, 3).unwrap();
            assert_eq!(r.req("tokens").unwrap().as_arr().unwrap().len(), 3);
            r.req("queue_ms").unwrap().as_f64().unwrap()
        }));
    }
    let queue_times: Vec<f64> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();
    let max_queue = queue_times.iter().fold(0.0f64, |a, &b| a.max(b));
    assert!(queue_times.iter().all(|&q| q >= 0.0));
    assert!(max_queue > 0.0,
            "no request waited behind the full batch (queue_ms all zero: \
             {queue_times:?})");

    // malformed request gets a structured error, connection stays usable
    // (protocol v2: generation requests must carry a client-chosen id)
    let bad = send_raw(&addr, "{\"max_new_tokens\": 2}");
    assert_eq!(err_code(&bad), "missing-id", "bad reply: {bad:?}");
    let bad = send_raw(&addr, "{\"id\": 0, \"max_new_tokens\": 2}");
    assert_eq!(err_code(&bad), "missing-prompt", "bad reply: {bad:?}");

    let stats = handle.stop().unwrap();
    assert!(stats.requests >= 14, "requests seen: {}", stats.requests);
    assert!(stats.tokens_out >= 14 * 3);
    assert!(stats.tokens_per_sec() > 0.0);
    // continuous batching actually batched something
    let max_occ = stats
        .batch_occupancy
        .iter()
        .fold(0.0f64, |a, &b| a.max(b));
    assert!(max_occ > 1.0 / 8.0 + 1e-9,
            "never batched more than one request (max occupancy {max_occ})");
}

// ===================================================== native backend ====
// Everything below runs with zero artifacts: the serve stack end-to-end
// on the pure-Rust backend (the first serve-side tests that cannot SKIP).

#[test]
fn native_serve_end_to_end() {
    let backend = NativeBackend::seeded(&small_lm(), 7, 4);
    let handle = serve_native(backend, &native_cfg()).unwrap();
    let addr = handle.addr.clone();

    // ping
    let mut c = Client::connect(&addr).unwrap();
    assert!(c.ping().unwrap().req("ok").unwrap().as_bool().unwrap());

    // empty prompt: the scheduler substitutes PAD and still generates
    let r = c.request(&[], 3).unwrap();
    assert_eq!(r.req("tokens").unwrap().as_arr().unwrap().len(), 3);
    assert!(r.req("queue_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert!(r.req("uncertainty").unwrap().as_f64().unwrap() > 0.0);

    // long prompt (well past the conv window and typical decode depth)
    let long: Vec<i32> = (0..50).map(|i| i % 32).collect();
    let r = c.request(&long, 4).unwrap();
    assert_eq!(r.req("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert!(r.req("total_ms").unwrap().as_f64().unwrap() >= 0.0);

    // greedy decoding is deterministic: same prompt -> same tokens
    let a = c.request(&[5, 6, 7], 4).unwrap();
    let b = c.request(&[5, 6, 7], 4).unwrap();
    assert_eq!(a.req("tokens").unwrap(), b.req("tokens").unwrap());

    // concurrent load: more requests than slots (10 > 4) — overflow
    // requests must wait for a free slot, visible as nonzero queue_ms.
    // A barrier releases all submissions at once so the overflow is
    // deterministic, not a scheduling accident.
    let barrier = Arc::new(std::sync::Barrier::new(10));
    let mut joins = Vec::new();
    for i in 0..10u64 {
        let addr = addr.clone();
        let barrier = barrier.clone();
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let prompt: Vec<i32> =
                (0..(1 + i % 5)).map(|j| ((i + j) % 32) as i32).collect();
            barrier.wait();
            let r = c.request(&prompt, 3).unwrap();
            assert_eq!(r.req("tokens").unwrap().as_arr().unwrap().len(), 3);
            r.req("queue_ms").unwrap().as_f64().unwrap()
        }));
    }
    let queue_times: Vec<f64> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert!(queue_times.iter().all(|&q| q >= 0.0));
    let max_queue = queue_times.iter().fold(0.0f64, |a, &b| a.max(b));
    assert!(max_queue > 0.0,
            "no request waited behind the full batch: {queue_times:?}");

    // malformed request gets a structured error, server survives
    // (protocol v2: generation requests must carry a client-chosen id)
    let bad = send_raw(&addr, "{\"max_new_tokens\": 2}");
    assert_eq!(err_code(&bad), "missing-id", "bad reply: {bad:?}");
    let bad = send_raw(&addr, "{\"id\": 0, \"max_new_tokens\": 2}");
    assert_eq!(err_code(&bad), "missing-prompt", "bad reply: {bad:?}");

    // clean shutdown: stats account for everything served
    let stats = handle.stop().unwrap();
    assert!(stats.requests >= 14, "requests seen: {}", stats.requests);
    assert!(stats.tokens_out >= 4 + 3 + 4 + 4 + 10 * 3);
    assert!(stats.steps > 0);
    assert!(stats.tokens_per_sec() > 0.0);
    // continuous batching actually batched something
    let max_occ = stats
        .batch_occupancy
        .iter()
        .fold(0.0f64, |a, &b| a.max(b));
    assert!(max_occ > 1.0 / 4.0 + 1e-9,
            "never batched more than one request (max occupancy {max_occ})");
}

#[test]
fn native_prefill_chunk_parity_with_token_by_token() {
    // The acceptance invariant of scan-based chunked prefill: for any
    // chunk size, generations are IDENTICAL to token-by-token prefill
    // (chunk=1, the legacy Feed::Prefill path), and the slot state
    // agrees within the 1e-5 scan-conformance tolerance (observed here
    // through the uncertainty signal, a pure function of the belief).
    // Prompt lengths cover the edges: empty, single token, one conv
    // window (K-1 = 3 for small_lm), and a long 512-token prompt that
    // spans many chunks.
    let prompts: Vec<Vec<i32>> = vec![
        vec![],
        vec![7],
        (0..3).map(|i| i * 5 % 32).collect(),
        (0..512).map(|i| i * 13 % 32).collect(),
    ];
    let run = |chunk: usize| -> Vec<(Vec<String>, f64)> {
        let backend = NativeBackend::seeded(&small_lm(), 42, 2);
        let mut cfg = native_cfg();
        cfg.prefill_chunk = chunk;
        let handle = serve_native(backend, &cfg).unwrap();
        let mut c = Client::connect(&handle.addr).unwrap();
        let out = prompts
            .iter()
            .map(|p| {
                let r = c.request(p, 6).unwrap();
                let toks: Vec<String> = r
                    .req("tokens")
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|t| t.to_string())
                    .collect();
                assert_eq!(toks.len(), 6);
                (toks, r.req("uncertainty").unwrap().as_f64().unwrap())
            })
            .collect();
        handle.stop().unwrap();
        out
    };
    let reference = run(1);
    println!("prefill parity chunk=1 baseline: ok");
    for chunk in [8usize, 64] {
        let got = run(chunk);
        for (i, ((ref_toks, ref_unc), (toks, unc))) in
            reference.iter().zip(&got).enumerate()
        {
            // exact token equality is the acceptance bar; it follows
            // from the 1e-5 state parity only when no greedy top-2
            // margin is that thin, which holds for this pinned seed —
            // if a future model change trips this, inspect the margins
            // before reaching for a looser assertion
            assert_eq!(ref_toks, toks,
                       "prompt {i}: chunk={chunk} generated different \
                        tokens than token-by-token prefill");
            assert!(kla::testing::rel_close64(*ref_unc, *unc, 1e-5),
                    "prompt {i}: chunk={chunk} uncertainty {unc} vs \
                     sequential {ref_unc}");
        }
        println!("prefill parity chunk={chunk} vs chunk=1: ok");
    }
}

#[test]
fn native_stats_cmd_reports_live_counters() {
    let backend = NativeBackend::seeded(&small_lm(), 9, 2);
    let mut cfg = native_cfg();
    cfg.prefill_chunk = 8;
    let handle = serve_native(backend, &cfg).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    // documented protocol line answers (it used to bail "unknown cmd")
    let s0 = c.stats().unwrap();
    assert_eq!(s0.req("requests").unwrap().as_usize().unwrap(), 0);
    assert_eq!(s0.req("tokens_out").unwrap().as_usize().unwrap(), 0);
    let prompt: Vec<i32> = (0..20).map(|i| i % 32).collect();
    let r = c.request(&prompt, 3).unwrap();
    assert_eq!(r.req("tokens").unwrap().as_arr().unwrap().len(), 3);
    // counters are LIVE — the server is still running when we read them
    let s1 = c.stats().unwrap();
    assert_eq!(s1.req("requests").unwrap().as_usize().unwrap(), 1);
    assert_eq!(s1.req("tokens_out").unwrap().as_usize().unwrap(), 3);
    assert!(s1.req("steps").unwrap().as_usize().unwrap() >= 3);
    // a 20-token prompt leaves 19 tokens for the scan prefill
    assert_eq!(s1.req("prefill_tokens").unwrap().as_usize().unwrap(), 19);
    let stats = handle.stop().unwrap();
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.prefill_tokens, 19);
}

#[test]
fn native_client_shutdown_quiesces_listener_without_external_poke() {
    let backend = NativeBackend::seeded(&small_lm(), 5, 2);
    let handle = serve_native(backend, &native_cfg()).unwrap();
    let addr = handle.addr.clone();
    let mut c = Client::connect(&addr).unwrap();
    let _ = c.request(&[1, 2, 3], 2).unwrap();
    assert!(c.shutdown().unwrap().req("ok").unwrap().as_bool().unwrap());
    drop(c);
    // the shutdown handler pokes its own accept(), so the listener must
    // exit and close the socket WITHOUT any external help.  Pre-fix the
    // accept() blocked forever holding the port open, so this loop never
    // saw a refused connection.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        match std::net::TcpStream::connect(&addr) {
            Err(_) => break, // listener gone: server quiesced
            Ok(_) => {
                assert!(std::time::Instant::now() < deadline,
                        "listener still accepting after client shutdown");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    // stop() reduces to a join and must not hang
    let stats = handle.stop().unwrap();
    assert!(stats.requests >= 1);
    assert_eq!(stats.tokens_out, 2);
}

#[test]
fn native_tokens_deterministic_for_fixed_seed_across_servers() {
    let run = |seed: u64| -> Vec<String> {
        let backend = NativeBackend::seeded(&small_lm(), seed, 2);
        let handle = serve_native(backend, &native_cfg()).unwrap();
        let mut c = Client::connect(&handle.addr).unwrap();
        let r = c.request(&[3, 1, 4, 1, 5], 6).unwrap();
        let toks: Vec<String> = r
            .req("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.to_string())
            .collect();
        handle.stop().unwrap();
        toks
    };
    let a = run(1234);
    let b = run(1234);
    assert_eq!(a, b, "same seed must reproduce the same greedy tokens");
    assert_eq!(a.len(), 6);
}

#[test]
fn native_engine_fifo_completion_on_single_slot() {
    // engine-level: one slot forces strictly serial execution, so
    // completion order must equal submission order.  Distinct max_new
    // values label the requests through the shared response channel.
    let backend = NativeBackend::seeded(&small_lm(), 3, 1);
    let (tx, rx) = channel::<EngineRequest>();
    let (rtx, rrx) = channel::<EngineResponse>();
    for i in 0..3usize {
        // Sender<EngineResponse> is the collect-only compatibility sink:
        // Started/Token events are dropped, Done arrives as the one-shot
        // EngineResponse the pre-streaming engine used to send
        tx.send(EngineRequest::new(
            vec![i as i32 + 1, i as i32 + 2],
            i + 1,
            SamplerConfig::greedy(),
            Box::new(rtx.clone()),
        ))
        .unwrap();
    }
    drop(tx);
    drop(rtx);
    let shutdown = Arc::new(AtomicBool::new(false));
    let stats = run_engine(&backend, rx, Duration::from_micros(100),
                           shutdown)
        .unwrap();
    let responses: Vec<_> = (0..3).map(|_| rrx.recv().unwrap()).collect();
    assert!(rrx.recv().is_err(), "exactly three responses expected");
    let lens: Vec<usize> =
        responses.iter().map(|r| r.tokens.len()).collect();
    assert_eq!(lens, vec![1, 2, 3], "completion order is not FIFO");
    // queue time: all non-negative, later submissions waited longer
    // (each had to wait for every earlier request to fully finish)
    assert!(responses.iter().all(|r| r.queue_ms >= 0.0));
    assert!(responses[2].queue_ms >= responses[1].queue_ms);
    assert!(responses[2].queue_ms > 0.0,
            "third request cannot have zero queue time on one slot");
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.tokens_out, 6);
}

// ================================================= sampling subsystem ====
// Per-request sampling & termination (serve::sampling), pinned end to
// end through the real TCP server.  CI's `sampling-parity` step runs
// every `native_sampling_*` test with --nocapture and greps the result
// lines below, failing on any SKIP.

#[test]
fn native_sampling_degenerate_configs_match_greedy() {
    // the greedy-reduction property, token for token: temperature -> 0,
    // top_k = 1, and top_p -> 0 all reproduce the default greedy output
    // exactly, for every prompt shape (empty / single / long)
    let backend = NativeBackend::seeded(&small_lm(), 11, 2);
    let handle = serve_native(backend, &native_cfg()).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let prompts: Vec<Vec<i32>> = vec![
        vec![],
        vec![3],
        (0..40).map(|i| (i * 7) % 32).collect(),
    ];
    for (pi, p) in prompts.iter().enumerate() {
        let greedy = tokens_of(&c.request(p, 6).unwrap());
        assert_eq!(greedy.len(), 6);
        let cases: Vec<(&str, RequestOpts)> = vec![
            ("temperature->0", RequestOpts {
                temperature: Some(1e-7),
                seed: Some(9),
                ..Default::default()
            }),
            ("top_k=1", RequestOpts {
                temperature: Some(1.7),
                top_k: Some(1),
                seed: Some(9),
                ..Default::default()
            }),
            ("top_p->0", RequestOpts {
                temperature: Some(1.7),
                top_p: Some(1e-9),
                seed: Some(9),
                ..Default::default()
            }),
        ];
        for (name, opts) in &cases {
            let got = tokens_of(&c.request_opts(p, 6, opts).unwrap());
            assert_eq!(&greedy, &got,
                       "prompt {pi}: {name} diverged from greedy");
        }
        println!("sampling parity prompt {pi}: ok");
    }
    handle.stop().unwrap();
}

#[test]
fn native_sampling_seeded_deterministic_across_restarts_and_batch() {
    // four concurrent temperature/top-p requests with explicit seeds:
    // identical tokens whether each runs alone on a 1-slot server or
    // batched with the other three on a 4-slot server, and identical
    // again after a full server restart — the counter-based RNG contract.
    let prompts: Vec<Vec<i32>> = (0..4u64)
        .map(|i| (0..6 + i).map(|j| ((i * 11 + j) % 32) as i32).collect())
        .collect();
    let run = |slots: usize| -> Vec<Vec<i64>> {
        let backend = NativeBackend::seeded(&small_lm(), 21, slots);
        let handle = serve_native(backend, &native_cfg()).unwrap();
        let addr = handle.addr.clone();
        let barrier = Arc::new(std::sync::Barrier::new(prompts.len()));
        let joins: Vec<_> = prompts
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, prompt)| {
                let addr = addr.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let opts = RequestOpts {
                        temperature: Some(0.9),
                        top_p: Some(0.9),
                        seed: Some(1000 + i as u64),
                        ..Default::default()
                    };
                    barrier.wait();
                    tokens_of(&c.request_opts(&prompt, 6, &opts).unwrap())
                })
            })
            .collect();
        let out: Vec<Vec<i64>> =
            joins.into_iter().map(|j| j.join().unwrap()).collect();
        handle.stop().unwrap();
        out
    };
    let solo = run(1);
    let batched = run(4);
    let restarted = run(4);
    assert_eq!(solo, batched,
               "seeded sampling changed with batch width 1 vs 4");
    assert_eq!(batched, restarted,
               "seeded sampling changed across a server restart");
    assert!(solo.iter().all(|t| t.len() == 6));
    println!("sampling determinism across batch sizes + restarts: ok");
}

#[test]
fn native_sampling_max_new_zero_is_prefill_only() {
    // regression for the silent `max_new.max(1)` clamp: max_new_tokens 0
    // now means prefill only — empty tokens, uncertainty still reported —
    // on both the chunked and the legacy prefill path
    for chunk in [1usize, 8] {
        let backend = NativeBackend::seeded(&small_lm(), 5, 2);
        let mut cfg = native_cfg();
        cfg.prefill_chunk = chunk;
        let handle = serve_native(backend, &cfg).unwrap();
        let mut c = Client::connect(&handle.addr).unwrap();
        let prompt: Vec<i32> = (0..20).map(|i| i % 32).collect();
        let r = c.request(&prompt, 0).unwrap();
        assert!(tokens_of(&r).is_empty(),
                "chunk={chunk}: max_new 0 must generate nothing");
        assert!(r.req("uncertainty").unwrap().as_f64().unwrap() > 0.0,
                "chunk={chunk}: uncertainty must still be reported");
        // the server keeps serving normally afterwards
        let r2 = c.request(&[1, 2, 3], 2).unwrap();
        assert_eq!(tokens_of(&r2).len(), 2);
        let stats = handle.stop().unwrap();
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.tokens_out, 2);
        println!("sampling max_new=0 chunk={chunk}: ok");
    }
}

#[test]
fn native_sampling_stop_tokens_terminate_early() {
    let backend = NativeBackend::seeded(&small_lm(), 13, 2);
    let handle = serve_native(backend, &native_cfg()).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let prompt = vec![2, 4, 6];
    let full = tokens_of(&c.request(&prompt, 8).unwrap());
    assert_eq!(full.len(), 8);
    // stop on a token the greedy continuation is known to produce
    let stop = full[3] as i32;
    let first = full.iter().position(|&t| t == stop as i64).unwrap();
    let opts = RequestOpts {
        stop_tokens: Some(vec![stop]),
        ..Default::default()
    };
    let got = tokens_of(&c.request_opts(&prompt, 8, &opts).unwrap());
    // terminated at the first occurrence; the stop token IS included
    assert_eq!(got, full[..=first].to_vec());
    // the `eos` shorthand behaves identically
    let eos_opts = RequestOpts { eos: Some(stop), ..Default::default() };
    let got_eos =
        tokens_of(&c.request_opts(&prompt, 8, &eos_opts).unwrap());
    assert_eq!(got_eos, full[..=first].to_vec());
    // stop ids in the PROMPT never terminate: prompt starts with the
    // stop token, yet generation still runs to the stop or max_new
    let mut stopped_prompt = vec![stop];
    stopped_prompt.extend_from_slice(&prompt);
    let r = c.request_opts(&stopped_prompt, 4, &opts).unwrap();
    assert!(!tokens_of(&r).is_empty());
    handle.stop().unwrap();
    println!("sampling stop tokens: ok");
}

#[test]
fn native_sampling_request_validation_structured_errors() {
    let backend = NativeBackend::seeded(&small_lm(), 3, 2);
    let handle = serve_native(backend, &native_cfg()).unwrap();
    let addr = handle.addr.clone();
    // out-of-i32-range prompt id: previously truncated silently by
    // `as_i64()? as i32`
    let r = send_raw(
        &addr, r#"{"id": 1, "prompt": [3000000000], "max_new_tokens": 2}"#);
    assert_eq!(err_code(&r), "bad-prompt-token", "{r:?}");
    // the error event echoes the request id (protocol v2)
    assert_eq!(r.req("id").unwrap().as_i64().unwrap(), 1, "{r:?}");
    // fractional token ids are not ids
    let r = send_raw(&addr, r#"{"id": 1, "prompt": [1.5]}"#);
    assert_eq!(err_code(&r), "bad-prompt-token", "{r:?}");
    // oversized max_new_tokens: previously clamped silently, now rejected
    let r = send_raw(
        &addr, r#"{"id": 1, "prompt": [1], "max_new_tokens": 999999}"#);
    assert_eq!(err_code(&r), "max-new-too-large", "{r:?}");
    // sampler field validation
    let r = send_raw(&addr, r#"{"id": 1, "prompt": [1], "temperature": -1}"#);
    assert_eq!(err_code(&r), "bad-temperature", "{r:?}");
    let r = send_raw(&addr, r#"{"id": 1, "prompt": [1], "top_p": 0}"#);
    assert_eq!(err_code(&r), "bad-top-p", "{r:?}");
    let r = send_raw(&addr, r#"{"id": 1, "prompt": [1], "top_k": 2.5}"#);
    assert_eq!(err_code(&r), "bad-top-k", "{r:?}");
    let r = send_raw(&addr, r#"{"id": 1, "prompt": [1], "seed": -4}"#);
    assert_eq!(err_code(&r), "bad-seed", "{r:?}");
    // seeds beyond 2^53 would silently collapse in f64 — rejected
    let r = send_raw(&addr, r#"{"id": 1, "prompt": [1], "seed": 1e17}"#);
    assert_eq!(err_code(&r), "bad-seed", "{r:?}");
    let r = send_raw(
        &addr, r#"{"id": 1, "prompt": [1], "stop_tokens": [1e12]}"#);
    assert_eq!(err_code(&r), "bad-stop-tokens", "{r:?}");
    // id validation itself (the v2 rules; rejected before anything else)
    let r = send_raw(&addr, r#"{"prompt": [1]}"#);
    assert_eq!(err_code(&r), "missing-id", "{r:?}");
    let r = send_raw(&addr, r#"{"id": 1.5, "prompt": [1]}"#);
    assert_eq!(err_code(&r), "bad-id", "{r:?}");
    let r = send_raw(&addr, r#"{"id": -3, "prompt": [1]}"#);
    assert_eq!(err_code(&r), "bad-id", "{r:?}");
    let r = send_raw(&addr, "not json at all");
    assert_eq!(err_code(&r), "bad-json", "{r:?}");
    let r = send_raw(&addr, r#"{"cmd": "frobnicate"}"#);
    assert_eq!(err_code(&r), "unknown-cmd", "{r:?}");
    let r = send_raw(&addr, r#"{"cmd": "cancel"}"#);
    assert_eq!(err_code(&r), "bad-id", "{r:?}");
    // after all that abuse the server still serves
    let mut c = Client::connect(&addr).unwrap();
    let ok = c.request(&[1, 2], 2).unwrap();
    assert_eq!(tokens_of(&ok).len(), 2);
    let stats = handle.stop().unwrap();
    assert_eq!(stats.requests, 1, "rejected requests never reach the engine");
    println!("sampling request validation: ok");
}

// ============================================ belief-state prefix cache ====
// Content-addressed prompt reuse (serve::prefix_cache) through the real
// TCP server.  The correctness crux: a cache hit must reproduce the cold
// prefill's generation — full hits bit-exactly (the snapshot IS the cold
// end-of-prefill state), partial hits within the scan-conformance
// tolerance.  CI's `prefix-cache-parity` step runs every
// `native_prefix_cache_*` test with --nocapture and greps the result
// lines below, failing on any SKIP.

/// `native_cfg` with the prefix cache on: chunked prefill (the only path
/// with snapshot insertion points) plus a byte budget.
fn cache_cfg(chunk: usize, budget: usize) -> ServeConfig {
    ServeConfig {
        prefill_chunk: chunk,
        prefix_cache_bytes: budget,
        ..native_cfg()
    }
}

#[test]
fn native_prefix_cache_identity_greedy() {
    // cold request, then the exact same prompt warm: the warm request
    // restores the cold end-of-prefill snapshot (cached_tokens > 0) and
    // generates IDENTICAL tokens with IDENTICAL uncertainty — and both
    // agree across chunk sizes and across a full server restart, so a
    // restarted server's cold output matches what the cache reproduced.
    let prompt: Vec<i32> = (0..24).map(|i| (i * 5) % 32).collect();
    let run = |chunk: usize| -> (Vec<i64>, f64, usize, usize) {
        let backend = NativeBackend::seeded(&small_lm(), 31, 2);
        let handle =
            serve_native(backend, &cache_cfg(chunk, 1 << 20)).unwrap();
        let mut c = Client::connect(&handle.addr).unwrap();
        let cold = c.request(&prompt, 6).unwrap();
        assert_eq!(
            cold.req("cached_tokens").unwrap().as_usize().unwrap(), 0,
            "chunk={chunk}: first request cannot hit an empty cache");
        let warm = c.request(&prompt, 6).unwrap();
        let cached =
            warm.req("cached_tokens").unwrap().as_usize().unwrap();
        assert_eq!(tokens_of(&cold), tokens_of(&warm),
                   "chunk={chunk}: warm tokens differ from cold");
        // full hit: the restored snapshot IS the cold end-of-prefill
        // state, so even the uncertainty trajectory is bit-identical
        assert_eq!(cold.req("uncertainty").unwrap().as_f64().unwrap(),
                   warm.req("uncertainty").unwrap().as_f64().unwrap(),
                   "chunk={chunk}: full hit must be bit-exact");
        let stats = handle.stop().unwrap();
        assert_eq!(stats.prefix_misses, 1);
        assert_eq!(stats.prefix_hits + stats.prefix_partial_hits, 1);
        (tokens_of(&cold),
         cold.req("uncertainty").unwrap().as_f64().unwrap(),
         cached, stats.prefix_cached_tokens)
    };
    let (toks8, unc8, cached8, stat8) = run(8);
    // a 24-token prompt with max_new > 0 prefills 23 tokens; the warm
    // request's full hit restores exactly that end-of-prefill snapshot
    assert_eq!(cached8, 23, "full hit must cover the usable prefix");
    assert_eq!(stat8, 23, "engine stats must mirror cached_tokens");
    // restart + different chunk size: exact token equality is the
    // acceptance bar; it follows from the 1e-5 state parity only when no
    // greedy top-2 margin is that thin, which holds for this pinned seed
    // (same caveat as native_prefill_chunk_parity_with_token_by_token)
    let (toks4, unc4, cached4, _) = run(4);
    assert_eq!(toks8, toks4,
               "restarted server with chunk=4 generated different tokens");
    assert!(kla::testing::rel_close64(unc8, unc4, 1e-5));
    assert_eq!(cached4, 23);
    println!("prefix cache identity greedy: ok");
}

#[test]
fn native_prefix_cache_identity_sampled() {
    // seeded sampling: the counter-based RNG draws depend only on the
    // request key and token index, so a full hit reproduces a sampled
    // generation exactly, not just a greedy one
    let backend = NativeBackend::seeded(&small_lm(), 37, 2);
    let handle = serve_native(backend, &cache_cfg(8, 1 << 20)).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let prompt: Vec<i32> = (0..24).map(|i| (i * 7) % 32).collect();
    let opts = RequestOpts {
        temperature: Some(0.9),
        top_p: Some(0.9),
        seed: Some(77),
        ..Default::default()
    };
    let cold = c.request_opts(&prompt, 6, &opts).unwrap();
    let warm = c.request_opts(&prompt, 6, &opts).unwrap();
    assert_eq!(
        warm.req("cached_tokens").unwrap().as_usize().unwrap(), 23,
        "warm sampled request must restore the full usable prefix");
    assert_eq!(tokens_of(&cold), tokens_of(&warm),
               "seeded-sampled warm tokens differ from cold");
    assert_eq!(cold.req("uncertainty").unwrap().as_f64().unwrap(),
               warm.req("uncertainty").unwrap().as_f64().unwrap());
    handle.stop().unwrap();
    println!("prefix cache identity sampled: ok");
}

#[test]
fn native_prefix_cache_partial_hit_resumes_prefill() {
    // two prompts sharing a 16-token prefix but diverging after it: the
    // second request partial-hits a block-aligned snapshot of the first
    // and resumes chunked prefill from there.  Its output must match a
    // cache-DISABLED server's cold output for the same prompt (same
    // backend seed => same weights => deterministic greedy reference).
    let prefix: Vec<i32> = (0..16).map(|i| (i * 3) % 32).collect();
    let mut a = prefix.clone();
    a.extend_from_slice(&[1, 2, 3, 4]);
    let mut b = prefix.clone();
    b.extend_from_slice(&[9, 8, 7, 6, 5]);

    // reference: prompt b, cold, cache off
    let backend = NativeBackend::seeded(&small_lm(), 41, 2);
    let handle = serve_native(backend, &cache_cfg(8, 0)).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let cold = c.request(&b, 5).unwrap();
    handle.stop().unwrap();

    // cache on: prompt a populates shared-prefix snapshots, prompt b
    // partial-hits one (it can never full-hit: its exact end-of-prefill
    // snapshot was never produced)
    let backend = NativeBackend::seeded(&small_lm(), 41, 2);
    let handle = serve_native(backend, &cache_cfg(8, 1 << 20)).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let _ = c.request(&a, 5).unwrap();
    let warm = c.request(&b, 5).unwrap();
    let cached = warm.req("cached_tokens").unwrap().as_usize().unwrap();
    assert!(cached > 0 && cached < b.len() - 1,
            "expected a partial hit, got cached_tokens = {cached}");
    // exact token equality per the chunk-parity precedent (the resumed
    // prefill re-chunks the suffix, so the state agrees to 1e-5, and no
    // greedy margin is that thin for this pinned seed)
    assert_eq!(tokens_of(&cold), tokens_of(&warm),
               "partial-hit resume generated different tokens than a \
                cold cache-disabled prefill");
    assert!(kla::testing::rel_close64(
        cold.req("uncertainty").unwrap().as_f64().unwrap(),
        warm.req("uncertainty").unwrap().as_f64().unwrap(),
        1e-5));
    let stats = handle.stop().unwrap();
    assert_eq!(stats.prefix_partial_hits, 1);
    assert_eq!(stats.prefix_cached_tokens, cached);
    println!("prefix cache partial hit resume: ok");
}

#[test]
fn native_prefix_cache_opt_out_per_request() {
    let backend = NativeBackend::seeded(&small_lm(), 43, 2);
    let handle = serve_native(backend, &cache_cfg(8, 1 << 20)).unwrap();
    let addr = handle.addr.clone();
    let mut c = Client::connect(&addr).unwrap();
    let prompt: Vec<i32> = (0..20).map(|i| (i * 11) % 32).collect();
    let opt_out = RequestOpts { cache: Some(false), ..Default::default() };
    // two identical opted-out requests: neither looks up NOR inserts
    for pass in 0..2 {
        let r = c.request_opts(&prompt, 4, &opt_out).unwrap();
        assert_eq!(
            r.req("cached_tokens").unwrap().as_usize().unwrap(), 0,
            "pass {pass}: opted-out request must never restore");
    }
    let s = c.stats().unwrap();
    for key in ["prefix_hits", "prefix_partial_hits", "prefix_misses",
                "prefix_entries"]
    {
        assert_eq!(s.req(key).unwrap().as_usize().unwrap(), 0,
                   "{key} counted for an opted-out request");
    }
    // default requests on the same server still use the cache
    let cold = c.request(&prompt, 4).unwrap();
    assert_eq!(cold.req("cached_tokens").unwrap().as_usize().unwrap(), 0);
    let warm = c.request(&prompt, 4).unwrap();
    assert!(warm.req("cached_tokens").unwrap().as_usize().unwrap() > 0,
            "default request did not warm-hit after the cold one");
    assert_eq!(tokens_of(&cold), tokens_of(&warm));
    // a non-boolean cache field is a structured protocol error
    let bad = send_raw(&addr, r#"{"id": 9, "prompt": [1], "cache": "yes"}"#);
    assert_eq!(err_code(&bad), "bad-cache", "{bad:?}");
    handle.stop().unwrap();
    println!("prefix cache opt-out: ok");
}

#[test]
fn native_prefix_cache_stats_counters_end_to_end() {
    // the live {"cmd":"stats"} counters and the shutdown EngineStats
    // tell the same story, at every stage: empty, after a miss, after a
    // full hit
    let backend = NativeBackend::seeded(&small_lm(), 47, 2);
    let handle = serve_native(backend, &cache_cfg(8, 1 << 20)).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let s0 = c.stats().unwrap();
    for key in ["prefix_hits", "prefix_partial_hits", "prefix_misses",
                "prefix_evictions", "prefix_cached_tokens", "prefix_bytes",
                "prefix_entries"]
    {
        assert_eq!(s0.req(key).unwrap().as_usize().unwrap(), 0,
                   "{key} nonzero before any request");
    }
    let prompt: Vec<i32> = (0..24).map(|i| (i * 13) % 32).collect();
    let _ = c.request(&prompt, 4).unwrap();
    let s1 = c.stats().unwrap();
    assert_eq!(s1.req("prefix_misses").unwrap().as_usize().unwrap(), 1);
    assert_eq!(s1.req("prefix_hits").unwrap().as_usize().unwrap(), 0);
    // fused rounds keep the cursor on the chunk grid: chunk 8 over a
    // 23-token usable prefix snapshots at BOTH block boundaries (8, 16)
    // and at the end of prefill (23) — the legacy path drifted off the
    // grid after the first chunk and only ever produced two entries
    assert_eq!(s1.req("prefix_entries").unwrap().as_usize().unwrap(), 3);
    let bytes = s1.req("prefix_bytes").unwrap().as_usize().unwrap();
    assert!(bytes > 0);
    let _ = c.request(&prompt, 4).unwrap();
    let s2 = c.stats().unwrap();
    assert_eq!(s2.req("prefix_hits").unwrap().as_usize().unwrap(), 1);
    assert_eq!(
        s2.req("prefix_cached_tokens").unwrap().as_usize().unwrap(), 23);
    // the warm walk re-visits the same offsets: recency refresh, no growth
    assert_eq!(s2.req("prefix_entries").unwrap().as_usize().unwrap(), 3);
    assert_eq!(s2.req("prefix_bytes").unwrap().as_usize().unwrap(), bytes);
    let stats = handle.stop().unwrap();
    assert_eq!(stats.prefix_hits, 1);
    assert_eq!(stats.prefix_misses, 1);
    assert_eq!(stats.prefix_cached_tokens, 23);
    assert_eq!(stats.prefix_bytes, bytes);
    assert_eq!(stats.prefix_entries, 3);
    println!("prefix cache stats counters: ok");
}

// ============================== fused (slots x time) prefill round ====
// The engine gathers one chunk per mid-prefill slot and hands the whole
// ragged batch to a single `DecodeBackend::prefill_batch` call.  The
// acceptance invariant is a three-way identity: fused round == per-slot
// fallback == token-by-token prefill, across chunk sizes and batch
// widths, greedy and seeded-sampled.  CI's `multidim-prefill-parity`
// step runs every `native_multidim_*` test with --nocapture and greps
// the result lines below, failing on any SKIP.

#[test]
fn native_multidim_prefill_parity_across_chunk_and_batch() {
    // token-by-token reference (chunk=1, one slot) vs every fused
    // configuration, through the real server.  Batched runs submit all
    // prompts concurrently behind a barrier so admissions genuinely
    // share fused rounds; determinism of the outputs regardless of
    // batch composition is exactly the invariant under test.
    let prompts: Vec<Vec<i32>> = vec![
        vec![],
        vec![7],
        (0..3).map(|i| i * 5 % 32).collect(),
        (0..100).map(|i| (i * 13) % 32).collect(),
    ];
    let run = |chunk: usize, batch: usize| -> Vec<(Vec<i64>, Vec<i64>)> {
        let backend = NativeBackend::seeded(&small_lm(), 101, batch);
        let mut cfg = native_cfg();
        cfg.prefill_chunk = chunk;
        let handle = serve_native(backend, &cfg).unwrap();
        let addr = handle.addr.clone();
        let barrier = Arc::new(std::sync::Barrier::new(prompts.len()));
        let joins: Vec<_> = prompts
            .iter()
            .cloned()
            .map(|prompt| {
                let addr = addr.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let sampled = RequestOpts {
                        temperature: Some(0.9),
                        top_p: Some(0.9),
                        seed: Some(4242),
                        ..Default::default()
                    };
                    barrier.wait();
                    let g = tokens_of(&c.request(&prompt, 6).unwrap());
                    let s = tokens_of(
                        &c.request_opts(&prompt, 6, &sampled).unwrap());
                    (g, s)
                })
            })
            .collect();
        let out: Vec<_> =
            joins.into_iter().map(|j| j.join().unwrap()).collect();
        handle.stop().unwrap();
        out
    };
    let reference = run(1, 1);
    assert!(reference.iter().all(|(g, s)| g.len() == 6 && s.len() == 6));
    println!("multidim prefill parity chunk=1 batch=1 baseline: ok");
    for (chunk, batch) in [(1usize, 4usize), (8, 1), (8, 4), (64, 1),
                           (64, 4)]
    {
        let got = run(chunk, batch);
        assert_eq!(reference, got,
                   "chunk={chunk} batch={batch}: fused prefill generated \
                    different tokens than token-by-token on one slot");
        println!("multidim prefill parity chunk={chunk} batch={batch}: ok");
    }
}

/// `NativeBackend` with `prefill_batch` pinned to the trait's default
/// per-slot loop (each lane still runs the native single-lane scan) —
/// the reference the fused multi-lane round must match bit-exactly.
struct PerSlotPrefill(NativeBackend);

impl DecodeBackend for PerSlotPrefill {
    fn batch(&self) -> usize {
        self.0.batch()
    }
    fn vocab(&self) -> usize {
        self.0.vocab()
    }
    fn kind(&self) -> &'static str {
        self.0.kind()
    }
    fn init_state(&self) -> anyhow::Result<kla::runtime::DecodeState> {
        self.0.init_state()
    }
    fn step(&self, tokens: &kla::tensor::IntTensor,
            state: &kla::runtime::DecodeState)
            -> anyhow::Result<(kla::tensor::Tensor,
                               kla::runtime::DecodeState)> {
        self.0.step(tokens, state)
    }
    fn prefill_is_parallel(&self) -> bool {
        true
    }
    fn prefill(&self, tokens: &kla::tensor::IntTensor, slot: usize,
               state: &kla::runtime::DecodeState)
               -> anyhow::Result<(kla::tensor::Tensor,
                                  kla::runtime::DecodeState)> {
        self.0.prefill(tokens, slot, state)
    }
    // prefill_batch: default — the per-slot fallback under test
}

#[test]
fn native_multidim_prefill_fused_matches_per_slot_fallback() {
    // engine-level leg of the three-way identity: the same request mix
    // through `run_engine_opts` on the fused NativeBackend and on the
    // per-slot fallback wrapper must produce identical tokens AND
    // identical uncertainties (lane-chained scans are sequential per
    // lane, so the agreement is bit-exact, not tolerance-based)
    let prompts: Vec<Vec<i32>> = vec![
        (0..30).map(|i| (i * 3) % 32).collect(),
        vec![4, 2],
        (0..75).map(|i| (i * 11) % 32).collect(),
        (0..12).map(|i| (i * 7) % 32).collect(),
    ];
    let run = |per_slot: bool| -> Vec<(Vec<i32>, f32)> {
        let native = NativeBackend::seeded(&small_lm(), 61, 4);
        let cfg = ServeConfig {
            prefill_chunk: 8,
            batch_window_us: 100,
            ..native_cfg()
        };
        let opts = kla::serve::EngineOptions::from_serve(&cfg);
        let (tx, rx) = channel::<EngineRequest>();
        let mut rxs = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let (rtx, rrx) = channel::<EngineResponse>();
            // request 0 is seeded-sampled, the rest greedy
            let sampler = if i == 0 {
                SamplerConfig {
                    temperature: 0.9,
                    top_p: 0.9,
                    seed: Some(7),
                    ..SamplerConfig::greedy()
                }
            } else {
                SamplerConfig::greedy()
            };
            tx.send(EngineRequest::new(p.clone(), 5, sampler,
                                       Box::new(rtx)))
                .unwrap();
            rxs.push(rrx);
        }
        drop(tx);
        let shutdown = Arc::new(AtomicBool::new(false));
        let live = Arc::new(kla::serve::LiveStats::default());
        if per_slot {
            let be = PerSlotPrefill(native);
            kla::serve::run_engine_opts(&be, rx, &opts, shutdown, &live)
                .unwrap();
        } else {
            kla::serve::run_engine_opts(&native, rx, &opts, shutdown,
                                        &live)
                .unwrap();
        }
        rxs.iter()
            .map(|r| {
                let resp = r.recv().unwrap();
                (resp.tokens.clone(), resp.uncertainty)
            })
            .collect()
    };
    let fused = run(false);
    let fallback = run(true);
    assert_eq!(fused, fallback,
               "fused prefill_batch diverged from the per-slot fallback");
    assert!(fused.iter().all(|(t, _)| t.len() == 5));
    println!("multidim prefill parity fused vs per-slot fallback: ok");
}

#[test]
fn native_prefix_cache_eviction_under_tiny_budget() {
    // a budget that fits roughly one prompt's snapshots: distinct
    // prompts churn the cache, evictions fire, the byte budget holds,
    // and the most recent prompt is still warm (LRU evicts oldest first)
    let backend = NativeBackend::seeded(&small_lm(), 53, 2);
    let budget = 2200usize;
    let handle = serve_native(backend, &cache_cfg(8, budget)).unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let prompts: Vec<Vec<i32>> = (0..4usize)
        .map(|p| (0..16usize).map(|j| ((p * 7 + j * 3 + 1) % 32) as i32)
            .collect())
        .collect();
    for p in &prompts {
        let r = c.request(p, 2).unwrap();
        assert_eq!(r.req("cached_tokens").unwrap().as_usize().unwrap(), 0,
                   "distinct prompts must all miss");
    }
    let s = c.stats().unwrap();
    assert!(s.req("prefix_evictions").unwrap().as_usize().unwrap() > 0,
            "four distinct prompts under a ~2 KB budget must evict");
    assert!(s.req("prefix_bytes").unwrap().as_usize().unwrap() <= budget,
            "byte budget violated");
    // the newest prompt survived the churn
    let warm = c.request(&prompts[3], 2).unwrap();
    assert!(warm.req("cached_tokens").unwrap().as_usize().unwrap() > 0,
            "most recently inserted prompt was evicted");
    let stats = handle.stop().unwrap();
    assert!(stats.prefix_evictions > 0);
    assert!(stats.prefix_bytes <= budget);
    println!("prefix cache eviction under budget: ok");
}

// Known-bad fixture for the `counter-sync` pass: `dropped_frames` is
// an EngineStats counter with no LiveStats mirror, no stats-reply key,
// and no doc mention; `ghost` is a LiveStats field mirroring nothing.
// Never compiled — only `include_str!`-ed by counter_sync.rs tests.

pub struct EngineStats {
    pub requests: usize,
    pub steps: usize,
    pub dropped_frames: usize,
    pub step_ms: Vec<f64>,
}

pub struct LiveStats {
    pub requests: AtomicUsize,
    pub steps: AtomicUsize,
    pub ghost: AtomicUsize,
}

//! Native Rust implementation of the KLA information filter.
//!
//! Mirrors `python/compile/kernels/` (the L1 side): the Moebius precision
//! algebra, the OU prior discretisation, and three filter execution
//! strategies (sequential, scan, chunked multi-threaded).  Used for the
//! Fig. 4 compute-scaling study, property tests, and cross-validation
//! against the Python oracle.

pub mod mobius;
pub mod ou;
pub mod scan;

pub use mobius::Mobius;
pub use scan::{filter_chunked, filter_scan, filter_sequential,
               random_inputs, random_params, FilterInputs, FilterOutputs,
               FilterParams};

//! Waiver fixture for the `send-sync-audit` pass: the structural
//! findings of the bad fixture suppressed by reasoned waivers (the
//! SAFETY comments satisfy the generic `unsafe` pass but not the
//! structural one).  Never compiled — `include_str!`-ed by tests.

// lint: allow(send-sync-audit, fixture: device handle, hand-audited)
pub struct WaivedPtr(*mut f32);

struct Opaque {
    data: *const u8,
}

// SAFETY: reviewed by hand in fixture form.
// lint: allow(send-sync-audit, fixture: prose reviewed out of band)
unsafe impl Send for Opaque {}

// SAFETY: reviewed by hand in fixture form.
// lint: allow(send-sync-audit, fixture: prose reviewed out of band)
unsafe impl Sync for Opaque {}

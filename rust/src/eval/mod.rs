//! Evaluation harness: zero-shot commonsense-lite suite (Table 4 / Fig. 1b
//! substitution), posterior-variance diagnostics (Fig. 5b), and the
//! unrolled Kalman attention maps (Figs. 10-13).

pub mod attnmap;
pub mod variance;
pub mod zeroshot;

pub use zeroshot::{ZeroShotItem, ZeroShotSuite, ZeroShotReport};

// Waived fixture for the `panic` pass: the same panicking shapes as
// panic_bad.rs, each carrying a waiver comment on its own line or the
// line above.  Never compiled — only `include_str!`-ed by
// rust/src/lint/panic_free.rs tests.

fn hot_path(v: &[i32]) -> i32 {
    // lint: allow(panic, fixture: caller guarantees non-empty batch)
    let first = v.first().unwrap();
    let x = v[0]; // lint: allow(panic, fixture: bounds checked above)
    first + x
}

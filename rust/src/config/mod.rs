//! Run configuration: typed experiment configs + a TOML-lite file format.
//!
//! The launcher accepts `--config runs/foo.toml` overridden by CLI options.
//! The file format is a flat-section subset of TOML (sections, `key = value`
//! with string/number/bool values, `#` comments) — enough for experiment
//! configs without a serde dependency.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// Flat `section.key -> string value` configuration store.
#[derive(Clone, Debug, Default)]
pub struct ConfigMap {
    values: BTreeMap<String, String>,
}

impl ConfigMap {
    pub fn parse(src: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = match raw.find('#') {
                Some(i) => &raw[..i],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: bad section header {raw:?}", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value",
                                       lineno + 1))?;
            let key = key.trim();
            let mut val = val.trim().to_string();
            if val.starts_with('"') && val.ends_with('"') && val.len() >= 2 {
                val = val[1..val.len() - 1].to_string();
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, val);
        }
        Ok(ConfigMap { values })
    }

    pub fn load(path: &str) -> Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::parse(&src)
    }

    pub fn set(&mut self, key: &str, val: &str) {
        self.values.insert(key.to_string(), val.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow!("{key}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow!("{key}: {e}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(s) => bail!("{key}: not a bool: {s:?}"),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

/// Trainer run configuration (consumed by `crate::train::Trainer`).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Artifact base name, e.g. "mad_kla" (roles are appended).
    pub artifact: String,
    pub steps: usize,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub log_every: usize,
    pub checkpoint_dir: Option<String>,
    pub target_accuracy: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact: String::new(),
            steps: 200,
            seed: 0,
            eval_every: 50,
            eval_batches: 4,
            log_every: 25,
            checkpoint_dir: None,
            target_accuracy: None,
        }
    }
}

impl TrainConfig {
    pub fn from_map(map: &ConfigMap) -> Result<Self> {
        Ok(TrainConfig {
            artifact: map.get_or("train.artifact", ""),
            steps: map.usize_or("train.steps", 200)?,
            seed: map.usize_or("train.seed", 0)? as u64,
            eval_every: map.usize_or("train.eval_every", 50)?,
            eval_batches: map.usize_or("train.eval_batches", 4)?,
            log_every: map.usize_or("train.log_every", 25)?,
            checkpoint_dir: map.get("train.checkpoint_dir")
                .map(|s| s.to_string()),
            target_accuracy: match map.get("train.target_accuracy") {
                Some(s) => Some(s.parse()?),
                None => None,
            },
        })
    }
}

/// Server configuration (consumed by `crate::serve`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Decode backend: "xla" (artifact session) | "native" (pure Rust).
    pub backend: String,
    pub artifact: String,
    pub max_batch: usize,
    /// Batching window: how long the batcher waits to fill a batch.
    pub batch_window_us: u64,
    pub max_new_tokens: usize,
    pub state_pool: usize,
    /// Engine seed: keys the counter-based sampling RNG for every
    /// request (see `serve::sampling::request_key`), and doubles as the
    /// weight seed for the native backend's deterministic init (ignored
    /// for weights when a checkpoint supplies them, and by the XLA
    /// backend — but still used for sampling on both).
    pub seed: u64,
    /// Upper bound on a request's `max_new_tokens`; requests asking for
    /// more are REJECTED with a structured `{"err": ...}` reply (never
    /// silently clamped).
    pub max_new_limit: usize,
    /// Default sampling temperature (0 = greedy argmax, the historical
    /// behaviour).  Per-request `temperature` overrides it.
    pub temperature: f64,
    /// Default top-k cutoff (0 = off, 1 = greedy).  Per-request `top_k`
    /// overrides it.
    pub top_k: usize,
    /// Default nucleus mass (>= 1 = off).  Per-request `top_p` overrides.
    pub top_p: f64,
    /// Default uncertainty->temperature coupling `c` in
    /// `tau_eff = tau * (1 + c * u)` over the slot's mean posterior
    /// variance (0 = off).  Per-request `uncertainty_temp` overrides.
    pub uncertainty_temp: f64,
    /// Default stop token ids (sampling one terminates the request; the
    /// stop token is included in the output).  A per-request
    /// `stop_tokens` REPLACES this list; `eos` appends one id to
    /// whatever list is in effect.
    pub stop_tokens: Vec<i32>,
    /// Pad token id, used for idle batch lanes and empty prompts.  Must
    /// be a valid vocab id; the engine clamps it into [0, vocab) like
    /// every other token.  (Previously hardcoded to 0, which is a live
    /// vocab id — now an explicit, configurable choice.)
    pub pad: i32,
    /// Max prompt tokens consumed per backend `prefill()` call at admit
    /// time.  1 = legacy token-per-engine-iteration prefill (prompt
    /// tokens interleave with decode steps in the shared batched step);
    /// >1 = scan-based chunked prefill (the prompt cursor jumps by up to
    /// this many tokens per call).
    pub prefill_chunk: usize,
    /// Max requests in flight per connection (protocol v2 multiplexes
    /// any number of streaming requests over one socket; this caps how
    /// much of the engine queue a single connection can claim).
    /// Requests beyond it are rejected with `too-many-inflight`.
    pub max_inflight: usize,
    /// Belief-state prefix cache byte budget (0 = disabled, the
    /// default).  The CLI exposes it as `--prefix-cache-mb`; the value
    /// here is in BYTES.  Only effective on the chunked-prefill path
    /// (`prefill_chunk > 1` on a backend with a parallel prefill).
    pub prefix_cache_bytes: usize,
    /// Prefix-cache snapshot granularity in prompt tokens (0 = use
    /// `prefill_chunk`, which keeps cached offsets chunk-aligned — the
    /// generation-identity condition, DESIGN.md §S15).  A non-zero value
    /// that is not a multiple of `prefill_chunk` is rounded UP to the
    /// next chunk multiple at engine boot (with a logged warning):
    /// fused prefill rounds only land cursors on chunk multiples, so an
    /// unaligned block would never produce a snapshot.
    pub prefix_cache_block: usize,
    /// Worker threads for the native backend's fused (slots x time)
    /// prefill rounds (0 = auto: resolve per round from batch width,
    /// total prompt tokens, and the core count — `api::Strategy::Auto`).
    /// A fixed value pins `Strategy::Chained { threads }`.  Ignored by
    /// the XLA backend, which prefills per slot inside its artifact.
    pub prefill_threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            backend: "xla".into(),
            artifact: "serve_kla_b8".into(),
            max_batch: 8,
            batch_window_us: 500,
            max_new_tokens: 32,
            state_pool: 64,
            seed: 0,
            max_new_limit: 1024,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            uncertainty_temp: 0.0,
            stop_tokens: Vec::new(),
            pad: 0,
            prefill_chunk: 64,
            max_inflight: 64,
            prefix_cache_bytes: 0,
            prefix_cache_block: 0,
            prefill_threads: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"
# experiment config
[train]
artifact = "mad_kla"
steps = 400
seed = 3
target_accuracy = 0.9

[serve]
addr = "0.0.0.0:9000"  # comment after value
"#;

    #[test]
    fn parse_sections_and_comments() {
        let m = ConfigMap::parse(SRC).unwrap();
        assert_eq!(m.get("train.artifact"), Some("mad_kla"));
        assert_eq!(m.usize_or("train.steps", 0).unwrap(), 400);
        assert_eq!(m.get("serve.addr"), Some("0.0.0.0:9000"));
        assert_eq!(m.get("nope"), None);
    }

    #[test]
    fn train_config_from_map() {
        let m = ConfigMap::parse(SRC).unwrap();
        let tc = TrainConfig::from_map(&m).unwrap();
        assert_eq!(tc.artifact, "mad_kla");
        assert_eq!(tc.steps, 400);
        assert_eq!(tc.seed, 3);
        assert_eq!(tc.target_accuracy, Some(0.9));
        assert_eq!(tc.eval_every, 50); // default
    }

    #[test]
    fn bad_lines_fail() {
        assert!(ConfigMap::parse("[open").is_err());
        assert!(ConfigMap::parse("novalue").is_err());
    }

    #[test]
    fn overrides() {
        let mut m = ConfigMap::parse(SRC).unwrap();
        m.set("train.steps", "10");
        assert_eq!(m.usize_or("train.steps", 0).unwrap(), 10);
    }

    #[test]
    fn bool_parsing() {
        let m = ConfigMap::parse("a = true\nb = 0\nc = nope").unwrap();
        assert!(m.bool_or("a", false).unwrap());
        assert!(!m.bool_or("b", true).unwrap());
        assert!(m.bool_or("c", false).is_err());
        assert!(m.bool_or("missing", true).unwrap());
    }
}

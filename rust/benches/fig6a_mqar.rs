//! Fig. 6a: long-context MQAR accuracy vs model dimension.
//!
//! Paper: T=2048, V=256, d in {64,128,256}; ours: T=256, V=64, d in
//! {32,64,128} (d=64 in the default manifest; 32/128 need
//! `make artifacts-full`).  Claim shape to reproduce: KLA > Mamba >> GLA
//! at the top dimension; GDN strongest at the smallest.

use kla::bench::exp::{bench_seeds, bench_steps, have, train_mean_acc};
use kla::bench::Suite;
use kla::data::task_by_name;
use kla::runtime::Runtime;

fn main() {
    let rt = match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP fig6a: {e}");
            return;
        }
    };
    let steps = bench_steps(250);
    let seeds = bench_seeds(1);
    let task = task_by_name("mqar").unwrap();
    let mut suite = Suite::new("fig6a_mqar");
    for d in [32usize, 64, 128] {
        for model in ["kla", "mamba", "gla", "gdn"] {
            let base = format!("mqar_{model}_d{d}");
            if !have(&rt, &base) {
                println!("({base} not built — `make artifacts-full`)");
                continue;
            }
            let (acc, step_ms) =
                train_mean_acc(&rt, &base, task.as_ref(), steps, seeds)
                    .unwrap();
            suite.metric_row(&format!("d{d}/{model}"),
                             vec![("acc".into(), acc),
                                  ("step_ms".into(), step_ms)]);
        }
    }
    suite.finish();
}

//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io (DESIGN.md
//! §S16), so this vendored crate implements exactly the subset the repo
//! uses: `Result`, `Error`, the `anyhow!` / `bail!` macros, and the
//! `Context` extension trait with `context` / `with_context`.

use std::fmt;

/// A type-erased error: a rendered message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap with a higher-level context message (kept in the rendering).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root-cause error, if this error wrapped one.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match &self.source {
            Some(e) => Some(&**e),
            None => None,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// keeps this blanket conversion coherent (the same trick anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.source().is_some());
    }

    #[test]
    fn context_prepends() {
        let err: Result<()> = io_fail()
            .map(|_| ())
            .with_context(|| "reading config".to_string());
        let msg = format!("{}", err.unwrap_err());
        assert!(msg.starts_with("reading config: "), "{msg}");
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        let e = anyhow!("plain {}", 42);
        assert_eq!(e.to_string(), "plain 42");
    }
}

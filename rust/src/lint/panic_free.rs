//! Pass `panic`: no panicking constructs in the serve hot paths.
//!
//! The engine loop's fault-isolation rule (DESIGN.md §S15: one lane's
//! failure must never kill the engine) dies the moment a stray
//! `unwrap()` or out-of-bounds index lands in `serve::engine`,
//! `serve::server`, or `serve::batcher`.  This pass flags, in the
//! non-test code of those three files:
//!
//! - `.unwrap()` / `.expect(...)` method calls (the `unwrap_or*`
//!   family is fine — it cannot panic);
//! - the panicking macros `panic!`, `todo!`, `unimplemented!`,
//!   `unreachable!`;
//! - unguarded index/slice expressions `x[...]`, recognised as a `[`
//!   that directly follows an identifier, `)`, or `]` (so array
//!   literals, types, attributes `#[...]`, and `vec![...]` never
//!   match).
//!
//! Sites whose bounds are established by construction keep a
//! `// lint: allow(panic, <invariant>)` waiver naming that invariant;
//! everything else gets rewritten onto a non-panicking path.

use super::{Finding, LintInput, SourceFile};

/// The serve hot-path files this pass audits.
const SCOPE: [&str; 3] = [
    "serve/engine.rs",
    "serve/server.rs",
    "serve/batcher.rs",
];

const PANIC_MACROS: [&str; 4] =
    ["panic", "todo", "unimplemented", "unreachable"];

pub fn run(input: &LintInput) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &input.files {
        if !SCOPE.iter().any(|s| file.path_ends_with(s)) {
            continue;
        }
        check_file(file, &mut out);
    }
    out
}

fn check_file(file: &SourceFile, out: &mut Vec<Finding>) {
    let code = &file.code;
    for (i, t) in code.iter().enumerate() {
        if file.is_test_line(t.line) {
            continue;
        }
        match t.ident() {
            Some(name @ ("unwrap" | "expect"))
                if i > 0
                    && code[i - 1].is_punct('.')
                    && code.get(i + 1).is_some_and(|n| n.is_punct('(')) =>
            {
                out.push(finding(
                    file,
                    t.line,
                    format!("`.{name}()` in a serve hot path can panic; \
                             handle the None/Err arm or waive with the \
                             invariant that rules it out"),
                ));
            }
            Some(name) if PANIC_MACROS.contains(&name)
                && code.get(i + 1).is_some_and(|n| n.is_punct('!')) =>
            {
                out.push(finding(
                    file,
                    t.line,
                    format!("`{name}!` in a serve hot path kills the \
                             engine thread; return an error event \
                             instead"),
                ));
            }
            _ => {}
        }
        // Unguarded indexing: `[` directly after an ident, `)`, or `]`.
        if t.is_punct('[') && i > 0 {
            let prev = &code[i - 1];
            let indexes = prev.ident().is_some()
                || prev.is_punct(')')
                || prev.is_punct(']');
            // `name![...]` is a macro invocation (vec![..]), handled by
            // the `!` check on the token between name and bracket — the
            // token before `[` is `!`, not an ident, so it never gets
            // here; this extra guard documents the intent.
            let macro_bang = i > 1 && code[i - 1].is_punct('!');
            if indexes && !macro_bang {
                out.push(finding(
                    file,
                    t.line,
                    "unguarded index/slice expression can panic in a \
                     serve hot path; use `.get(..)` or waive with the \
                     bounds invariant"
                        .to_string(),
                ));
            }
        }
    }
}

fn finding(file: &SourceFile, line: usize, message: String) -> Finding {
    Finding { pass: "panic", file: file.path.clone(), line, message }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{run as run_all, LintInput, SourceFile};

    fn input(path: &str, src: &str) -> LintInput {
        LintInput {
            files: vec![SourceFile::from_source(path, src)],
            design_md: String::new(),
        }
    }

    #[test]
    fn fixture_fires_on_every_bad_construct() {
        let src = include_str!("fixtures/panic_bad.rs");
        let inp = input("rust/src/serve/engine.rs", src);
        let fs = run(&inp);
        let msgs: Vec<&str> =
            fs.iter().map(|f| f.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("`.unwrap()`")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("`.expect()`")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("`panic!`")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("`todo!`")), "{msgs:?}");
        assert!(
            msgs.iter().any(|m| m.contains("unguarded index")),
            "{msgs:?}"
        );
    }

    #[test]
    fn fixture_waivers_suppress_and_are_counted() {
        let src = include_str!("fixtures/panic_waived.rs");
        let inp = input("rust/src/serve/engine.rs", src);
        let report = run_all(&inp);
        assert!(
            report.findings.is_empty(),
            "waived fixture should be clean:\n{}",
            report.render()
        );
        let s = report
            .summaries
            .iter()
            .find(|s| s.pass == "panic")
            .unwrap_or_else(|| panic!("no panic summary"));
        assert!(s.waivers_used >= 2, "waivers used: {}", s.waivers_used);
    }

    #[test]
    fn out_of_scope_files_and_test_code_are_ignored() {
        let src = include_str!("fixtures/panic_bad.rs");
        // same content, but a file outside the serve hot paths
        assert!(run(&input("rust/src/kla/scan.rs", src)).is_empty());
        // and inside a #[cfg(test)] module in a scoped file
        let test_only = format!("#[cfg(test)]\nmod tests {{\n{src}\n}}\n");
        assert!(run(&input("rust/src/serve/engine.rs", &test_only))
            .is_empty());
    }

    #[test]
    fn unwrap_or_family_and_macro_brackets_do_not_fire() {
        let src = "\
fn ok(v: &[i32]) -> i32 {\n\
    let x = v.first().copied().unwrap_or(0);\n\
    let w = v.first().copied().unwrap_or_else(|| 1);\n\
    let ys = vec![x, w];\n\
    let zs: [i32; 2] = [0; 2];\n\
    ys.first().copied().unwrap_or_default() + zs.len() as i32\n\
}\n";
        let inp = input("rust/src/serve/engine.rs", src);
        assert!(run(&inp).is_empty(), "{:?}", run(&inp));
    }
}

// instrumented pass-level timing of the chunked scan
use kla::kla::{random_inputs, random_params, FilterParams, FilterInputs};
use kla::kla::mobius::Mobius;
use kla::util::{Pcg64, Timer};

fn main() {
    let (t_len, n, d) = (8192usize, 8usize, 64usize);
    let s = n * d;
    let mut rng = Pcg64::seeded(1);
    let p: FilterParams = random_params(&mut rng, n, d);
    let inp: FilterInputs = random_inputs(&mut rng, t_len, n, d);
    let threads = 8;
    let chunk_len = t_len.div_ceil(threads);

    // pass 1 style loop, single chunk on main thread (FTZ off)
    let tm = Timer::start();
    let mut mob = vec![Mobius::IDENTITY; s];
    for t in 0..chunk_len {
        let k_t = &inp.k[t * n..(t + 1) * n];
        let lv_t = &inp.lam_v[t * d..(t + 1) * d];
        for ni in 0..n {
            let k2 = k_t[ni] * k_t[ni];
            for di in 0..d {
                let idx = ni * d + di;
                let m = Mobius::kla_step(p.abar[idx], p.pbar[idx], k2 * lv_t[di]);
                mob[idx] = m.compose(&mob[idx]);
            }
        }
    }
    println!("compose pass ({chunk_len} steps): {:.1} ms", tm.elapsed_ms());
    // how big do entries get?
    let mx = mob.iter().fold(0f32, |a, m| a.max(m.a.abs()).max(m.d.abs()));
    let mn = mob.iter().fold(f32::MAX, |a, m| a.min(m.a.abs().max(m.b.abs()).max(m.c.abs()).max(m.d.abs())));
    println!("entry magnitude range after {chunk_len} composes: {mn:e} .. {mx:e}");

    // replay-style pass
    let tm = Timer::start();
    let mut lam = vec![0.0f32; chunk_len * s];
    let mut cur = p.lam0.clone();
    for t in 0..chunk_len {
        let k_t = &inp.k[t * n..(t + 1) * n];
        let lv_t = &inp.lam_v[t * d..(t + 1) * d];
        for ni in 0..n {
            let k2 = k_t[ni] * k_t[ni];
            for di in 0..d {
                let idx = ni * d + di;
                let abar = p.abar[idx];
                let rho = 1.0 / (abar * abar + p.pbar[idx] * cur[idx]);
                let l = (rho * cur[idx] + k2 * lv_t[di]).clamp(1e-6, 1e8);
                lam[t * s + idx] = l;
                cur[idx] = l;
            }
        }
    }
    println!("replay pass ({chunk_len} steps): {:.1} ms", tm.elapsed_ms());
    std::hint::black_box(&lam);
}

//! Persistent work-stealing thread pool (DESIGN.md §S8).
//!
//! The scoped helpers in `util::pool` spawn fresh OS threads per call,
//! which is fine for one long scan but wasteful for the serving engine's
//! per-iteration fused prefill round (many small lane scans, every few
//! milliseconds).  This pool keeps `n` workers alive for the process
//! lifetime: jobs are pushed round-robin onto per-worker deques, a worker
//! pops its own deque from the front and steals from the back of its
//! peers when idle, and a blocked `scope()` caller assists by executing
//! queued jobs itself (work-assisting, so a 1-thread pool can never
//! deadlock a nested scope).
//!
//! Borrowed data is supported through [`ThreadPool::scope`], which does
//! not return until every job spawned inside it has finished — the same
//! structured-concurrency argument `std::thread::scope` makes, applied
//! to persistent workers.
//!
//! The pool's sync primitives come from [`crate::mc::sync`] (std
//! re-exports in normal builds), so the Gate/Scope protocols are
//! model-checked under `--features mc-shim` — no deadlock, no lost
//! wakeup, scope completion, panic propagation (DESIGN.md §S19).

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::mc::sync::{AtomicBool, AtomicUsize, Condvar, Mutex};
use crate::mc::thread::{spawn_named, JoinHandle};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Gate {
    /// Bumped on every submission; workers sleep until it moves.
    generation: u64,
    shutdown: bool,
}

struct Shared {
    /// One deque per worker.  Owners pop the front; thieves pop the back.
    queues: Vec<Mutex<VecDeque<Job>>>,
    gate: Mutex<Gate>,
    wake: Condvar,
    /// Round-robin submission cursor.
    next: AtomicUsize,
}

impl Shared {
    /// Pop a job: own deque first (front), then steal from peers (back).
    fn find_job(&self, me: usize) -> Option<Job> {
        if let Some(job) = self.queues[me].lock().unwrap().pop_front() {
            return Some(job);
        }
        let n = self.queues.len();
        for off in 1..n {
            let victim = (me + off) % n;
            if let Some(job) =
                self.queues[victim].lock().unwrap().pop_back()
            {
                return Some(job);
            }
        }
        None
    }

    /// Pop a job from any deque (used by assisting scope callers, which
    /// own no deque of their own).
    fn steal_any(&self) -> Option<Job> {
        for victim in 0..self.queues.len() {
            if let Some(job) =
                self.queues[victim].lock().unwrap().pop_back()
            {
                return Some(job);
            }
        }
        None
    }

    fn submit(&self, job: Job) {
        // ord: Relaxed — the cursor only spreads load round-robin;
        // job handoff is ordered by the deque mutex below.
        // lint: allow(atomic-ordering, load-balance cursor only)
        let slot = self.next.fetch_add(1, Ordering::Relaxed)
            % self.queues.len();
        self.queues[slot].lock().unwrap().push_back(job);
        {
            let mut g = self.gate.lock().unwrap();
            g.generation = g.generation.wrapping_add(1);
        }
        self.wake.notify_one();
    }
}

fn worker_loop(shared: Arc<Shared>, me: usize) {
    let mut seen = 0u64;
    loop {
        while let Some(job) = shared.find_job(me) {
            job();
        }
        let mut g = shared.gate.lock().unwrap();
        if g.shutdown {
            return;
        }
        if g.generation == seen {
            // Nothing has been submitted since our last sweep; sleep
            // until the gate moves.  (Jobs are pushed BEFORE the
            // generation bump, so generation == seen proves the sweep
            // above saw every job.)
            g = shared.wake.wait(g).unwrap();
        }
        if g.shutdown {
            return;
        }
        seen = g.generation;
    }
}

/// A fixed-size pool of persistent worker threads with per-worker
/// work-stealing deques.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool of `threads.max(1)` persistent workers.
    pub fn new(threads: usize) -> Self {
        let n = threads.max(1);
        let shared = Arc::new(Shared {
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            gate: Mutex::new(Gate { generation: 0, shutdown: false }),
            wake: Condvar::new(),
            next: AtomicUsize::new(0),
        });
        let handles = (0..n)
            .map(|me| {
                let shared = Arc::clone(&shared);
                spawn_named(&format!("kla-pool-{me}"), move || {
                    worker_loop(shared, me)
                })
                .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, handles }
    }

    /// The process-wide shared pool, sized to the machine
    /// (`util::pool::default_threads()`), created on first use.
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| ThreadPool::new(super::pool::default_threads()))
    }

    pub fn num_threads(&self) -> usize {
        self.handles.len()
    }

    /// Run `f` with a [`Scope`] whose spawned jobs may borrow from the
    /// caller's stack.  Does not return until every spawned job has
    /// finished; while waiting, the caller executes queued jobs itself.
    /// Panics (after all jobs settle) if any job panicked.
    pub fn scope<'s, R>(&self, f: impl FnOnce(&Scope<'_, 's>) -> R) -> R {
        let state = Arc::new(ScopeState {
            pending: Mutex::new(0usize),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let scope = Scope {
            pool: self,
            state: Arc::clone(&state),
            _borrow: PhantomData,
        };
        let out = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // Wait for every spawned job, assisting with queued work so a
        // scope entered FROM a pool worker (or a 1-thread pool) cannot
        // deadlock on its own backlog.
        loop {
            if *state.pending.lock().unwrap() == 0 {
                break;
            }
            if let Some(job) = self.shared.steal_any() {
                job();
                continue;
            }
            let pending = state.pending.lock().unwrap();
            if *pending == 0 {
                break;
            }
            // All remaining jobs are mid-execution on workers; sleep
            // until one completes (timeout guards the pop/wait race).
            let _ = state
                .done
                .wait_timeout(pending, Duration::from_millis(1))
                .unwrap();
        }
        match out {
            Ok(r) => {
                // ord: Acquire pairs with the Release store in the
                // job wrapper.  The flag is written before the job's
                // final pending-- under the mutex, and read here only
                // after this thread observed pending == 0 under the
                // same mutex — so the mutex alone already orders the
                // handoff; Acquire/Release keeps the flag correct
                // even if the wait loop is ever rewritten without it.
                assert!(
                    !state.panicked.load(Ordering::Acquire),
                    "thread_pool: a scoped job panicked"
                );
                r
            }
            Err(e) => resume_unwind(e),
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut g = self.shared.gate.lock().unwrap();
            g.shutdown = true;
        }
        self.shared.wake.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

struct ScopeState {
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

/// Handle for spawning borrowed jobs inside [`ThreadPool::scope`].
pub struct Scope<'pool, 'scope> {
    pool: &'pool ThreadPool,
    state: Arc<ScopeState>,
    /// Invariant over 'scope, like `std::thread::Scope`.
    _borrow: PhantomData<&'scope mut &'scope ()>,
}

impl<'pool, 'scope> Scope<'pool, 'scope> {
    /// Queue `f` on the pool.  `f` may borrow anything that outlives the
    /// enclosing `scope()` call.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        *self.state.pending.lock().unwrap() += 1;
        let state = Arc::clone(&self.state);
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            if catch_unwind(AssertUnwindSafe(f)).is_err() {
                // ord: Release makes the flag visible to the scope
                // caller's Acquire load; the pending mutex below
                // orders it too (see the load site in scope()).
                state.panicked.store(true, Ordering::Release);
            }
            let mut pending = state.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                state.done.notify_all();
            }
        });
        // SAFETY: scope() blocks until `pending` drains to zero, so this
        // job — and every borrow it captures — completes before 'scope
        // ends.  Same argument as crossbeam/std scoped threads, with the
        // wait moved from thread join to the pending counter.
        let job: Job = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'scope>,
                Box<dyn FnOnce() + Send + 'static>,
            >(job)
        };
        self.pool.shared.submit(job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scope_runs_every_job_and_waits() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> =
            (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.scope(|s| {
            for h in &hits {
                s.spawn(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn borrowed_mutation_via_split_at_mut() {
        let pool = ThreadPool::new(3);
        let mut xs = vec![0usize; 30];
        pool.scope(|s| {
            let mut rest = &mut xs[..];
            let mut tag = 1usize;
            while !rest.is_empty() {
                let take = 7.min(rest.len());
                let (head, tail) = rest.split_at_mut(take);
                rest = tail;
                let t = tag;
                s.spawn(move || {
                    for x in head.iter_mut() {
                        *x = t;
                    }
                });
                tag += 1;
            }
        });
        assert!(xs.iter().all(|&x| x > 0));
    }

    #[test]
    fn single_thread_pool_cannot_deadlock_nested_scopes() {
        let pool = ThreadPool::new(1);
        let outer = AtomicUsize::new(0);
        let pool_ref = &pool;
        pool.scope(|s| {
            for _ in 0..4 {
                let outer = &outer;
                s.spawn(move || {
                    // a nested scope from inside a worker job: the
                    // worker assists on its own backlog
                    pool_ref.scope(|inner| {
                        inner.spawn(|| {
                            outer.fetch_add(1, Ordering::Relaxed);
                        });
                    });
                });
            }
        });
        assert_eq!(outer.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn scope_propagates_job_panics() {
        let pool = ThreadPool::new(2);
        let hit = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|| panic!("boom"));
            });
        }));
        assert!(hit.is_err());
        // the pool survives a panicked job
        let ok = AtomicUsize::new(0);
        pool.scope(|s| {
            s.spawn(|| {
                ok.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = ThreadPool::global() as *const _;
        let b = ThreadPool::global() as *const _;
        assert_eq!(a, b);
        assert!(ThreadPool::global().num_threads() >= 1);
    }

    /// Seeded stress sweep: random (threads, jobs, payload) permutations
    /// must never lose a task.  Every job contributes a distinct weight
    /// to a checksum; any dropped, duplicated, or unjoined job changes
    /// the total.  Spin payloads are drawn per job so fast jobs race
    /// slow ones across the steal paths.
    #[test]
    fn stress_no_lost_tasks_under_seeded_permutations() {
        let mut rng = crate::util::Pcg64::seeded(0xC0FFEE);
        for round in 0..24 {
            let threads = 1 + rng.usize_below(6);
            let jobs = 1 + rng.usize_below(97);
            let spins: Vec<usize> =
                (0..jobs).map(|_| rng.usize_below(200)).collect();
            let pool = ThreadPool::new(threads);
            let sum = AtomicUsize::new(0);
            pool.scope(|s| {
                for (i, &spin) in spins.iter().enumerate() {
                    let sum = &sum;
                    s.spawn(move || {
                        // data-dependent busy work so job durations vary
                        let mut acc = spin;
                        for k in 0..spin {
                            acc = acc.wrapping_mul(31).wrapping_add(k);
                        }
                        std::hint::black_box(acc);
                        sum.fetch_add(i + 1, Ordering::Relaxed);
                    });
                }
            });
            let expect = jobs * (jobs + 1) / 2;
            assert_eq!(
                sum.load(Ordering::Relaxed),
                expect,
                "round {round}: threads={threads} jobs={jobs}"
            );
        }
    }

    /// Work-assist progress: a 1-thread pool whose only worker is parked
    /// inside a job that BLOCKS until every other job has run.  The
    /// scope caller must execute the remaining backlog itself or this
    /// test deadlocks — i.e. it proves the assist path makes progress,
    /// not just that it exists.
    #[test]
    fn stress_assist_unblocks_a_parked_worker() {
        let mut rng = crate::util::Pcg64::seeded(7);
        for _ in 0..8 {
            let rest = 1 + rng.usize_below(31);
            let pool = ThreadPool::new(1);
            let done = AtomicUsize::new(0);
            pool.scope(|s| {
                let done = &done;
                s.spawn(move || {
                    // worker parks here until the backlog drains
                    while done.load(Ordering::Acquire) < rest {
                        std::thread::yield_now();
                    }
                });
                for _ in 0..rest {
                    s.spawn(move || {
                        done.fetch_add(1, Ordering::Release);
                    });
                }
            });
            assert_eq!(done.load(Ordering::Acquire), rest);
        }
    }

    /// Panic propagation under permutation: a seeded subset of jobs
    /// panics; scope() must still run every non-panicking job, then
    /// panic itself, and the pool must stay usable afterwards.
    #[test]
    fn stress_panics_propagate_without_losing_survivors() {
        let mut rng = crate::util::Pcg64::seeded(42);
        for round in 0..12 {
            let threads = 1 + rng.usize_below(4);
            let jobs = 2 + rng.usize_below(40);
            // capped so the (expected) panic spew stays readable
            let bombs = 1 + rng.usize_below((jobs - 1).min(4));
            let bad = rng.choose_distinct(jobs, bombs);
            let pool = ThreadPool::new(threads);
            let ran = AtomicUsize::new(0);
            let out = catch_unwind(AssertUnwindSafe(|| {
                pool.scope(|s| {
                    for i in 0..jobs {
                        let ran = &ran;
                        let boom = bad.contains(&i);
                        s.spawn(move || {
                            if boom {
                                panic!("seeded bomb {i}");
                            }
                            ran.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }));
            assert!(out.is_err(), "round {round}: panic was swallowed");
            assert_eq!(
                ran.load(Ordering::Relaxed),
                jobs - bombs,
                "round {round}: a non-panicking job was lost"
            );
            // same pool still serves a clean scope
            let ok = AtomicUsize::new(0);
            pool.scope(|s| {
                s.spawn(|| {
                    ok.fetch_add(1, Ordering::Relaxed);
                });
            });
            assert_eq!(ok.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn sequential_results_on_reused_pool() {
        // many scopes back to back reuse the same workers
        let pool = ThreadPool::new(2);
        for round in 0..16 {
            let sum = AtomicUsize::new(0);
            pool.scope(|s| {
                for i in 0..8 {
                    let sum = &sum;
                    s.spawn(move || {
                        sum.fetch_add(i, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(sum.load(Ordering::Relaxed), 28, "round {round}");
        }
    }
}

//! Declarative CLI argument parser (offline stand-in for clap).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, required arguments, and auto-generated help.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// One argument declaration.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
    pub required: bool,
}

/// A subcommand: name, help, arg specs.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, args: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str,
               help: &'static str) -> Self {
        self.args.push(ArgSpec { name, help, default: Some(default),
                                 is_flag: false, required: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec { name, help, default: None,
                                 is_flag: false, required: true });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec { name, help, default: None,
                                 is_flag: true, required: false });
        self
    }

    fn usage(&self) -> String {
        let mut s = format!("  {} — {}\n", self.name, self.about);
        for a in &self.args {
            let kind = if a.is_flag {
                "".to_string()
            } else if let Some(d) = a.default {
                format!(" <value, default {d}>")
            } else {
                " <value, required>".to_string()
            };
            s.push_str(&format!("      --{}{}  {}\n", a.name, kind, a.help));
        }
        s
    }
}

/// Parsed argument values for one invocation.
#[derive(Debug)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Result<&str> {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing --{name}"))
    }

    pub fn get_string(&self, name: &str) -> Result<String> {
        Ok(self.get(name)?.to_string())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: not a usize: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: not a u64: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: not a float: {e}"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Comma-separated list accessor.
    pub fn get_list(&self, name: &str) -> Result<Vec<String>> {
        Ok(self
            .get(name)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect())
    }
}

/// Top-level application.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        App { name, about, commands: Vec::new() }
    }

    pub fn command(mut self, cmd: Command) -> Self {
        self.commands.push(cmd);
        self
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nCOMMANDS:\n", self.name, self.about);
        for c in &self.commands {
            s.push_str(&c.usage());
        }
        s
    }

    /// Parse argv (without the binary name).
    pub fn parse(&self, argv: &[String]) -> Result<Matches> {
        let cmd_name = argv
            .first()
            .ok_or_else(|| anyhow!("no command given\n\n{}", self.help()))?;
        if cmd_name == "help" || cmd_name == "--help" || cmd_name == "-h" {
            bail!("{}", self.help());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| {
                anyhow!("unknown command {cmd_name:?}\n\n{}", self.help())
            })?;

        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        for a in &cmd.args {
            if let Some(d) = a.default {
                values.insert(a.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            let stripped = tok
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --option, got {tok:?}"))?;
            let (key, inline_val) = match stripped.split_once('=') {
                Some((k, v)) => (k, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = cmd
                .args
                .iter()
                .find(|a| a.name == key)
                .ok_or_else(|| {
                    anyhow!("unknown option --{key} for {cmd_name}\n\n{}",
                            cmd.usage())
                })?;
            if spec.is_flag {
                if inline_val.is_some() {
                    bail!("--{key} is a flag and takes no value");
                }
                flags.insert(key.to_string(), true);
            } else {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| anyhow!("--{key} needs a value"))?
                    }
                };
                values.insert(key.to_string(), val);
            }
            i += 1;
        }

        for a in &cmd.args {
            if a.required && !values.contains_key(a.name) {
                bail!("missing required --{} for {}\n\n{}", a.name,
                      cmd_name, cmd.usage());
            }
        }

        Ok(Matches { command: cmd_name.clone(), values, flags })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("repro", "test").command(
            Command::new("train", "train a model")
                .req("task", "task name")
                .opt("steps", "100", "training steps")
                .opt("lr", "0.001", "learning rate")
                .flag("quiet", "suppress output"),
        )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let m = app()
            .parse(&argv(&["train", "--task", "mad", "--steps=250",
                           "--quiet"]))
            .unwrap();
        assert_eq!(m.command, "train");
        assert_eq!(m.get("task").unwrap(), "mad");
        assert_eq!(m.get_usize("steps").unwrap(), 250);
        assert!((m.get_f64("lr").unwrap() - 0.001).abs() < 1e-12);
        assert!(m.get_flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let m = app().parse(&argv(&["train", "--task", "x"])).unwrap();
        assert_eq!(m.get_usize("steps").unwrap(), 100);
        assert!(!m.get_flag("quiet"));
    }

    #[test]
    fn missing_required_fails() {
        assert!(app().parse(&argv(&["train"])).is_err());
    }

    #[test]
    fn unknown_option_fails() {
        assert!(app()
            .parse(&argv(&["train", "--task", "x", "--nope", "1"]))
            .is_err());
    }

    #[test]
    fn unknown_command_fails() {
        assert!(app().parse(&argv(&["zap"])).is_err());
    }

    #[test]
    fn list_accessor() {
        let m = app()
            .parse(&argv(&["train", "--task", "a,b,c"]))
            .unwrap();
        assert_eq!(m.get_list("task").unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn flag_with_value_fails() {
        assert!(app()
            .parse(&argv(&["train", "--task", "x", "--quiet=1"]))
            .is_err());
    }
}

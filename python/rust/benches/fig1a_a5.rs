fn main() {}

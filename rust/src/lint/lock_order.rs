//! Pass `lock-order`: the may-hold-while-acquiring graph stays
//! acyclic and agrees with the DESIGN.md §S19 lock hierarchy.
//!
//! The model checker (`rust/src/mc/`) explores interleavings of the
//! protocols we wrote tests for; this pass is the static complement
//! that covers every `.lock()` site in the concurrency scope
//! ([`SCOPE`]: the serve modules and `util::thread_pool`) on every
//! build.  It tracks, token by token, which lock guards are live —
//! let-bound guards until their block closes (or an explicit
//! `drop(guard)`), `.lock().unwrap().method()` temporaries until the
//! end of the statement — and records an edge A → B whenever lock B
//! is acquired while a guard on A is still live.  Locks are named by
//! the last field identifier of the receiver chain
//! (`self.shared.gate.lock()` → `gate`), so call sites aggregate
//! across files.  Findings:
//!
//! - **cycle** — an edge A → B where B (transitively) reaches A:
//!   a deadlock interleaving exists;
//! - **hierarchy** — DESIGN.md §S19 carries a machine-parsed rank
//!   table (`| rank | `lock` | ... |` rows).  Every observed lock
//!   must be ranked, every ranked lock must still exist, and every
//!   edge must go strictly rank-upward;
//! - **condvar discipline** — `.wait()` / `.wait_timeout()` outside a
//!   loop loses wakeups (no predicate recheck — exactly the seeded
//!   bug `mc::invariants::regression_lost_wakeup_detected` proves the
//!   model checker catches dynamically), and waiting while holding a
//!   second lock blocks every acquirer of that lock for the whole
//!   sleep.
//!
//! Known approximation: guards bound by `if let`/`match` on the lock
//! result are treated as live for the whole dependent block, and a
//! lock temporary inside a plain `if` condition is released at the
//! opening brace — both match rustc's drop order for the patterns
//! used in this repo.

use std::collections::BTreeMap;

use super::{Finding, LintInput, SourceFile};
use crate::lint::lexer::{Tok, Token};

/// The concurrency scope this pass audits.
const SCOPE: [&str; 4] = [
    "serve/engine.rs",
    "serve/server.rs",
    "serve/batcher.rs",
    "util/thread_pool.rs",
];

const PASS: &str = "lock-order";

/// A live lock guard.
struct Held {
    lock: String,
    var: Option<String>,
    /// Dropped when brace depth falls below this (ignored for temps).
    release_depth: usize,
    /// Statement temporary: dropped at the next `;` / `{` / `}`.
    temp: bool,
}

/// One observed may-hold-while-acquiring edge.
struct Edge {
    from: String,
    to: String,
    file: String,
    line: usize,
}

/// A row of the §S19 hierarchy table.
struct Row {
    rank: usize,
    name: String,
    line: usize,
}

pub fn run(input: &LintInput) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut edges: Vec<Edge> = Vec::new();
    let mut first_site: BTreeMap<String, (String, usize)> = BTreeMap::new();
    for file in &input.files {
        if !SCOPE.iter().any(|s| file.path_ends_with(s)) {
            continue;
        }
        scan_file(file, &mut out, &mut edges, &mut first_site);
    }

    // Aggregate parallel edges: keep the first site per (from, to).
    let mut seen: Vec<(String, String)> = Vec::new();
    edges.retain(|e| {
        let key = (e.from.clone(), e.to.clone());
        if seen.contains(&key) {
            false
        } else {
            seen.push(key);
            true
        }
    });

    for e in &edges {
        if reaches(&edges, &e.to, &e.from) {
            out.push(Finding {
                pass: PASS,
                file: e.file.clone(),
                line: e.line,
                message: format!(
                    "lock-order cycle: `{}` is held while acquiring \
                     `{}`, and `{}` (transitively) reaches `{}` — a \
                     deadlock interleaving exists; acquire in one \
                     global order",
                    e.from, e.to, e.to, e.from
                ),
            });
        }
    }

    table_check(input, &edges, &first_site, &mut out);
    out
}

/// True if `from` reaches `to` over the edge set (zero steps count,
/// so a self-edge is reported as a cycle).
fn reaches(edges: &[Edge], from: &str, to: &str) -> bool {
    let mut stack = vec![from];
    let mut visited: Vec<&str> = Vec::new();
    while let Some(n) = stack.pop() {
        if n == to {
            return true;
        }
        if visited.contains(&n) {
            continue;
        }
        visited.push(n);
        for e in edges {
            if e.from == n {
                stack.push(&e.to);
            }
        }
    }
    false
}

fn scan_file(
    file: &SourceFile,
    out: &mut Vec<Finding>,
    edges: &mut Vec<Edge>,
    first_site: &mut BTreeMap<String, (String, usize)>,
) {
    let code = &file.code;
    let mut depth = 0usize;
    let mut held: Vec<Held> = Vec::new();
    // Brace depths of loop bodies currently open.
    let mut loops: Vec<usize> = Vec::new();
    let mut pending_loop = false;
    for i in 0..code.len() {
        let t = &code[i];
        if t.is_punct('{') {
            depth += 1;
            if pending_loop {
                loops.push(depth);
                pending_loop = false;
            }
            held.retain(|h| !h.temp);
        } else if t.is_punct('}') {
            held.retain(|h| !h.temp);
            if loops.last() == Some(&depth) {
                loops.pop();
            }
            depth = depth.saturating_sub(1);
            held.retain(|h| h.release_depth <= depth);
        } else if t.is_punct(';') {
            held.retain(|h| !h.temp);
        }
        match t.ident() {
            Some("loop") | Some("while") => pending_loop = true,
            Some("for") if for_is_loop(code, i) => pending_loop = true,
            Some("drop")
                if code.get(i + 1).is_some_and(|n| n.is_punct('('))
                    && code.get(i + 3).is_some_and(|n| n.is_punct(')')) =>
            {
                if let Some(name) = code.get(i + 2).and_then(|n| n.ident())
                {
                    held.retain(|h| h.var.as_deref() != Some(name));
                }
            }
            Some("lock")
                if i > 0
                    && code[i - 1].is_punct('.')
                    && code.get(i + 1).is_some_and(|n| n.is_punct('('))
                    && code.get(i + 2).is_some_and(|n| n.is_punct(')')) =>
            {
                if !file.is_test_line(t.line) {
                    acquire(
                        file, code, i, depth, &mut held, edges, first_site,
                    );
                }
            }
            Some(w @ ("wait" | "wait_timeout"))
                if i > 0
                    && code[i - 1].is_punct('.')
                    && code.get(i + 1).is_some_and(|n| n.is_punct('(')) =>
            {
                if !file.is_test_line(t.line) {
                    if loops.is_empty() {
                        out.push(Finding {
                            pass: PASS,
                            file: file.path.clone(),
                            line: t.line,
                            message: format!(
                                "condvar `{w}` outside a loop: a missed \
                                 or spurious wakeup is unrecoverable \
                                 without re-checking the predicate; \
                                 wrap the wait in a `while`/`loop` \
                                 recheck"
                            ),
                        });
                    }
                    let consumed = code.get(i + 2).and_then(|n| n.ident());
                    for h in &held {
                        if h.var.as_deref() != consumed {
                            out.push(Finding {
                                pass: PASS,
                                file: file.path.clone(),
                                line: t.line,
                                message: format!(
                                    "condvar `{w}` while holding `{}`: \
                                     the sleeping thread blocks every \
                                     acquirer of that lock for the \
                                     whole sleep; drop it first",
                                    h.lock
                                ),
                            });
                        }
                    }
                }
            }
            _ => {}
        }
    }
}

/// Handle the `.lock()` whose `lock` ident sits at `i`.
#[allow(clippy::too_many_arguments)]
fn acquire(
    file: &SourceFile,
    code: &[Token],
    i: usize,
    depth: usize,
    held: &mut Vec<Held>,
    edges: &mut Vec<Edge>,
    first_site: &mut BTreeMap<String, (String, usize)>,
) {
    let Some(lock) = chain_last_ident(code, i - 1) else {
        return;
    };
    let line = code[i].line;
    for h in held.iter() {
        edges.push(Edge {
            from: h.lock.clone(),
            to: lock.clone(),
            file: file.path.clone(),
            line,
        });
    }
    first_site
        .entry(lock.clone())
        .or_insert_with(|| (file.path.clone(), line));

    // Skip the `.unwrap()` / `.expect(..)` / `.unwrap_or_else(..)`
    // chain after the call to classify what the guard binds to.
    let mut j = i + 3;
    while code.get(j).is_some_and(|n| n.is_punct('.'))
        && matches!(
            code.get(j + 1).and_then(|n| n.ident()),
            Some("unwrap" | "expect" | "unwrap_or_else")
        )
        && code.get(j + 2).is_some_and(|n| n.is_punct('('))
    {
        j = skip_balanced(code, j + 2);
    }
    let start = chain_start(code, i - 1);
    let binding = binding_var(code, start);
    let held_entry = match code.get(j) {
        // `let g = x.lock().unwrap();` — guard lives in this block.
        Some(n) if n.is_punct(';') && binding.is_some() => Held {
            lock,
            var: binding,
            release_depth: depth,
            temp: false,
        },
        // `if let Ok(g) = x.lock() {` / `match x.lock() {` — guard
        // lives in the dependent block.
        Some(n) if n.is_punct('{') => {
            let is_match = start > 0
                && code[start - 1].ident() == Some("match");
            if binding.is_some() || is_match {
                Held {
                    lock,
                    var: binding,
                    release_depth: depth + 1,
                    temp: false,
                }
            } else {
                // plain `if cond {` temporary: dropped at the brace
                Held { lock, var: None, release_depth: 0, temp: true }
            }
        }
        // `let Ok(g) = x.lock() else { .. };` — guard lives here.
        Some(n) if n.ident() == Some("else") => Held {
            lock,
            var: binding,
            release_depth: depth,
            temp: false,
        },
        // anything else (`.method()`, `+=`, `==`, ...) — temporary
        _ => Held { lock, var: None, release_depth: 0, temp: true },
    };
    held.push(held_entry);
}

/// Index one past the `)` matching the `(` at `open`.
fn skip_balanced(code: &[Token], open: usize) -> usize {
    let mut d = 0usize;
    let mut k = open;
    while k < code.len() {
        if code[k].is_punct('(') {
            d += 1;
        } else if code[k].is_punct(')') {
            d -= 1;
            if d == 0 {
                return k + 1;
            }
        }
        k += 1;
    }
    code.len()
}

/// Last field identifier of the receiver chain ending at the `.` at
/// `dot_idx` (`self.shared.queues[victim].lock` → `queues`).  Shared
/// with the `atomic-ordering` pass, which names atomic sites the
/// same way.
pub(crate) fn chain_last_ident(
    code: &[Token],
    dot_idx: usize,
) -> Option<String> {
    let mut k = dot_idx.checked_sub(1)?;
    if code[k].is_punct(']') {
        let mut d = 0usize;
        loop {
            if code[k].is_punct(']') {
                d += 1;
            } else if code[k].is_punct('[') {
                d -= 1;
                if d == 0 {
                    break;
                }
            }
            k = k.checked_sub(1)?;
        }
        k = k.checked_sub(1)?;
    }
    code[k].ident().map(str::to_string)
}

/// First token index of the receiver chain ending at the `.` at
/// `dot_idx`.
fn chain_start(code: &[Token], dot_idx: usize) -> usize {
    let mut k = dot_idx;
    loop {
        let Some(prev) = k.checked_sub(1) else { return k };
        if code[prev].is_punct(']') {
            let mut d = 0usize;
            let mut m = prev;
            loop {
                if code[m].is_punct(']') {
                    d += 1;
                } else if code[m].is_punct('[') {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                let Some(next) = m.checked_sub(1) else { return k };
                m = next;
            }
            let Some(before) = m.checked_sub(1) else { return m };
            if code[before].ident().is_none() {
                return m;
            }
            k = before;
        } else if code[prev].ident().is_some() {
            k = prev;
        } else {
            return k;
        }
        let Some(pp) = k.checked_sub(1) else { return k };
        if code[pp].is_punct('.') {
            k = pp;
        } else {
            return k;
        }
    }
}

/// The variable a `let <pat> = <chain>.lock()...` binds, if the chain
/// at `start` is the right-hand side of a plain `=` binding.
fn binding_var(code: &[Token], start: usize) -> Option<String> {
    let eq = start.checked_sub(1)?;
    if !code[eq].is_punct('=') {
        return None;
    }
    let before = eq.checked_sub(1)?;
    // reject compound assignment (`+=`, `==`, ...)
    if matches!(code[before].tok, Tok::Punct(c)
        if "+-*/%&|^<>=!".contains(c))
    {
        return None;
    }
    if let Some(name) = code[before].ident() {
        if name == "mut" || name == "let" {
            return None;
        }
        return Some(name.to_string());
    }
    if code[before].is_punct(')') {
        // tuple-struct pattern (`Ok(mut map)`): last ident inside
        let mut d = 0usize;
        let mut k = before;
        let mut last: Option<String> = None;
        loop {
            if code[k].is_punct(')') {
                d += 1;
            } else if code[k].is_punct('(') {
                d -= 1;
                if d == 0 {
                    break;
                }
            } else if let Some(n) = code[k].ident() {
                if last.is_none() && n != "mut" {
                    last = Some(n.to_string());
                }
            }
            k = k.checked_sub(1)?;
        }
        return last;
    }
    None
}

/// True if the `for` at `i` heads a for-loop (an `in` appears before
/// the body brace), not a trait impl (`impl Send for T`).
fn for_is_loop(code: &[Token], i: usize) -> bool {
    let mut k = i + 1;
    while let Some(t) = code.get(k) {
        if t.is_punct('{') || t.is_punct(';') {
            return false;
        }
        if t.ident() == Some("in") {
            return true;
        }
        k += 1;
    }
    false
}

fn table_check(
    input: &LintInput,
    edges: &[Edge],
    first_site: &BTreeMap<String, (String, usize)>,
    out: &mut Vec<Finding>,
) {
    if input.design_md.is_empty() {
        return;
    }
    let Some(rows) = parse_table(&input.design_md) else {
        if !first_site.is_empty() {
            out.push(Finding {
                pass: PASS,
                file: "DESIGN.md".to_string(),
                line: 1,
                message: "locks exist in the concurrency scope but \
                          DESIGN.md has no §S19 lock-hierarchy table \
                          (`| <rank> | `<lock>` | ... |` rows under \
                          the `## §S19` heading)"
                    .to_string(),
            });
        }
        return;
    };
    let rank: BTreeMap<&str, usize> =
        rows.iter().map(|r| (r.name.as_str(), r.rank)).collect();
    for (lock, (file, line)) in first_site {
        if !rank.contains_key(lock.as_str()) {
            out.push(Finding {
                pass: PASS,
                file: file.clone(),
                line: *line,
                message: format!(
                    "lock `{lock}` is missing from the DESIGN.md §S19 \
                     lock-hierarchy table; add a ranked row for it"
                ),
            });
        }
    }
    for row in &rows {
        if !first_site.contains_key(&row.name) {
            out.push(Finding {
                pass: PASS,
                file: "DESIGN.md".to_string(),
                line: row.line,
                message: format!(
                    "§S19 hierarchy row `{}` matches no `.lock()` site \
                     in the concurrency scope — stale row, remove or \
                     rename it",
                    row.name
                ),
            });
        }
    }
    for e in edges {
        if let (Some(&rf), Some(&rt)) =
            (rank.get(e.from.as_str()), rank.get(e.to.as_str()))
        {
            if rf >= rt {
                out.push(Finding {
                    pass: PASS,
                    file: e.file.clone(),
                    line: e.line,
                    message: format!(
                        "acquiring `{}` (rank {rt}) while holding `{}` \
                         (rank {rf}) violates the §S19 hierarchy: hold \
                         only strictly lower-rank locks while acquiring",
                        e.to, e.from
                    ),
                });
            }
        }
    }
}

/// Parse the `| rank | `lock` | ... |` rows of the §S19 section.
fn parse_table(design_md: &str) -> Option<Vec<Row>> {
    let mut rows = Vec::new();
    let mut in_section = false;
    for (idx, line) in design_md.lines().enumerate() {
        if line.starts_with("## ") {
            in_section = line.starts_with("## §S19");
            continue;
        }
        if !in_section {
            continue;
        }
        let trimmed = line.trim();
        if !trimmed.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = trimmed.split('|').collect();
        let (Some(rank_cell), Some(name_cell)) =
            (cells.get(1), cells.get(2))
        else {
            continue;
        };
        let Ok(rank) = rank_cell.trim().parse::<usize>() else {
            continue;
        };
        let name_cell = name_cell.trim();
        let Some(rest) = name_cell.strip_prefix('`') else { continue };
        let Some(name) = rest.split('`').next() else { continue };
        rows.push(Row {
            rank,
            name: name.to_string(),
            line: idx + 1,
        });
    }
    if rows.is_empty() {
        None
    } else {
        Some(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{run as run_all, LintInput, SourceFile};

    fn input(path: &str, src: &str) -> LintInput {
        LintInput {
            files: vec![SourceFile::from_source(path, src)],
            design_md: String::new(),
        }
    }

    #[test]
    fn fixture_fires_on_cycle_and_condvar_misuse() {
        let src = include_str!("fixtures/lock_order_bad.rs");
        let fs = run(&input("rust/src/util/thread_pool.rs", src));
        let msgs: Vec<&str> =
            fs.iter().map(|f| f.message.as_str()).collect();
        assert_eq!(
            msgs.iter().filter(|m| m.contains("lock-order cycle")).count(),
            2,
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("outside a loop")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("while holding `b`")),
            "{msgs:?}"
        );
    }

    #[test]
    fn fixture_waivers_suppress_every_finding() {
        let src = include_str!("fixtures/lock_order_waived.rs");
        let report = run_all(&input("rust/src/util/thread_pool.rs", src));
        assert!(
            report.findings.is_empty(),
            "waived fixture not clean:\n{}",
            report.render()
        );
        let s = report
            .summaries
            .iter()
            .find(|s| s.pass == "lock-order")
            .unwrap_or_else(|| panic!("no lock-order summary"));
        assert!(s.waivers_used >= 4, "waivers used: {}", s.waivers_used);
    }

    #[test]
    fn hierarchy_table_rank_violation_is_reported() {
        let src = "\
fn f(s: &S) {\n\
    let gb = s.b.lock().unwrap();\n\
    let ga = s.a.lock().unwrap();\n\
    drop(ga);\n\
    drop(gb);\n\
}\n";
        let design = "\
## §S19 Concurrency\n\
\n\
| rank | lock | defined in |\n\
|------|------|------------|\n\
| 1 | `a` | x.rs |\n\
| 2 | `b` | x.rs |\n";
        let inp = LintInput {
            files: vec![SourceFile::from_source(
                "rust/src/util/thread_pool.rs",
                src,
            )],
            design_md: design.to_string(),
        };
        let fs = run(&inp);
        assert!(
            fs.iter().any(|f| f.message.contains("violates the §S19")),
            "{fs:?}"
        );
        // no cycle: the reverse edge does not exist
        assert!(
            !fs.iter().any(|f| f.message.contains("cycle")),
            "{fs:?}"
        );
    }

    #[test]
    fn unranked_lock_and_stale_row_are_reported() {
        let src = "\
fn f(s: &S) {\n\
    let g = s.c.lock().unwrap();\n\
    drop(g);\n\
}\n";
        let design = "\
## §S19 Concurrency\n\
\n\
| 1 | `d` | x.rs |\n";
        let inp = LintInput {
            files: vec![SourceFile::from_source(
                "rust/src/serve/server.rs",
                src,
            )],
            design_md: design.to_string(),
        };
        let fs = run(&inp);
        assert!(
            fs.iter().any(|f| f.message.contains("missing from the")),
            "{fs:?}"
        );
        assert!(
            fs.iter()
                .any(|f| f.file == "DESIGN.md"
                    && f.message.contains("stale row")),
            "{fs:?}"
        );
    }

    #[test]
    fn sequential_temporaries_and_loop_waits_are_clean() {
        // the real pool's shapes: statement temporaries, an if-let
        // block guard, and a wait inside a loop with its own guard
        let src = "\
fn f(s: &S) {\n\
    if let Some(j) = s.queues[0].lock().unwrap().pop_front() {\n\
        run(j);\n\
    }\n\
    s.queues[1].lock().unwrap().push_back(1);\n\
    loop {\n\
        let mut g = s.gate.lock().unwrap();\n\
        if g.shutdown {\n\
            return;\n\
        }\n\
        g = s.wake.wait(g).unwrap();\n\
        drop(g);\n\
    }\n\
}\n";
        let fs = run(&input("rust/src/util/thread_pool.rs", src));
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn out_of_scope_files_are_ignored() {
        let src = "\
fn f(s: &S) {\n\
    let ga = s.a.lock().unwrap();\n\
    let gb = s.b.lock().unwrap();\n\
    let _ = s.cv.wait(gb);\n\
    drop(ga);\n\
}\n";
        assert!(run(&input("rust/src/kla/scan.rs", src)).is_empty());
    }
}

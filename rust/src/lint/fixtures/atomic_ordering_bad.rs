//! Known-bad fixture for the `atomic-ordering` pass: one snippet per
//! finding class.  Never compiled — `include_str!`-ed by the pass's
//! unit tests only.  The local `LiveStats` makes the allowlist
//! self-contained.

use std::sync::atomic::{AtomicUsize, Ordering};

pub struct LiveStats {
    pub steps: AtomicUsize,
}

pub struct Flags {
    pub ready: AtomicUsize,
}

// `Relaxed` outside the LiveStats stats-counter allowlist.
pub fn bad_relaxed(f: &Flags) -> usize {
    f.ready.load(Ordering::Relaxed)
}

// A real ordering with no `// ord:` rationale anywhere near it.
pub fn missing_rationale(f: &Flags) {
    f.ready.store(1, Ordering::Release);
}

// The rationale names Relaxed but the site uses Acquire.
pub fn mismatched(f: &Flags) -> usize {
    // ord: Relaxed would do here, nothing is published
    f.ready.load(Ordering::Acquire)
}

pub fn stale() -> usize {
    // ord: Acquire pairs with a store that no longer exists
    0
}

// Padding keeps the clean site below outside the stale anchor's
// coverage window.
//
//
//
//
//
// A LiveStats counter may stay Relaxed with no rationale: drift in a
// monotonic stats counter is cosmetic.
pub fn clean(s: &LiveStats) -> usize {
    s.steps.fetch_add(1, Ordering::Relaxed)
}

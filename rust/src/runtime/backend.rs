//! `DecodeBackend` — the execution seam for O(1) autoregressive decoding.
//!
//! The serve stack (engine, continuous batcher, `BeliefStateCache`, TCP
//! server) needs exactly three things from a model: a fixed batch width,
//! a fresh belief state, and a one-token step `(tokens, state) ->
//! (logits, state')`.  This trait is that contract; the engine, the
//! state cache, and the server are generic over it.
//!
//! Two implementations:
//!
//! - [`crate::runtime::DecodeSession`] — the XLA/PJRT path over a
//!   `{base}_decode` HLO artifact (requires `make artifacts`);
//! - [`NativeBackend`] — a pure-Rust KLA LM (`kla::model::NativeLm`)
//!   whose per-layer filter update goes through the same
//!   `kla::api::Filter::step()` carry the training-side scan uses.  No
//!   artifacts needed: weights come from a deterministic seeded init or
//!   a `train::checkpoint` file, so the whole continuous-batching stack
//!   runs (and is tested) offline.
//!
//! Both backends share the `DecodeState` layout (L,B,K-1,D) /
//! (L,B,N,D), so slot pooling, snapshot/restore, and the uncertainty
//! signal work unchanged on either path.

use std::path::Path;

use anyhow::Result;

use crate::kla::model::{NativeLm, NativeLmConfig};
use crate::tensor::{IntTensor, Tensor};

/// One model's recurrent decode state: (conv, lam, eta), shapes
/// (L,B,K-1,D) / (L,B,N,D) / (L,B,N,D).  Slots live in the batch
/// dimension (see `crate::serve::state_cache`).
#[derive(Clone, Debug)]
pub struct DecodeState {
    pub conv: Tensor,
    pub lam: Tensor,
    pub eta: Tensor,
}

/// A decode execution backend: init state + step a batch of tokens.
pub trait DecodeBackend {
    /// Fixed batch width (the serving engine's slot count).
    fn batch(&self) -> usize;

    /// Vocabulary size.  The serving engine clamps incoming token ids
    /// into [0, vocab) before `step()` (the native model additionally
    /// clamps internally; the XLA gather does not).
    fn vocab(&self) -> usize;

    /// Short backend tag for logs: "native" | "xla".
    fn kind(&self) -> &'static str;

    /// Fresh state for `batch()` sequences at the learned prior.
    fn init_state(&self) -> Result<DecodeState>;

    /// One autoregressive step for the whole batch:
    /// tokens (B,) -> (logits (B, V), new state).
    fn step(&self, tokens: &IntTensor, state: &DecodeState)
            -> Result<(Tensor, DecodeState)>;
}

/// The pure-Rust backend: a `NativeLm` pinned to a fixed batch width.
pub struct NativeBackend {
    lm: NativeLm,
    batch: usize,
}

impl NativeBackend {
    pub fn new(lm: NativeLm, batch: usize) -> Self {
        assert!(batch >= 1, "backend batch must be >= 1");
        NativeBackend { lm, batch }
    }

    /// Deterministic seeded weights (same seed => same tokens out).
    pub fn seeded(cfg: &NativeLmConfig, seed: u64, batch: usize) -> Self {
        Self::new(NativeLm::seeded(cfg, seed), batch)
    }

    /// Load weights from a flatten-ABI param list (init artifact output
    /// or checkpoint contents).
    pub fn from_values(values: &[crate::runtime::Value], batch: usize,
                       process_noise: bool, ou_exact: bool)
                       -> Result<Self> {
        Ok(Self::new(NativeLm::from_values(values, process_noise,
                                           ou_exact)?,
                     batch))
    }

    /// Load weights from a `train::checkpoint` file.
    pub fn from_checkpoint(path: &Path, batch: usize, process_noise: bool,
                           ou_exact: bool) -> Result<Self> {
        let values = crate::train::checkpoint::load(path)?;
        Self::from_values(&values, batch, process_noise, ou_exact)
    }

    pub fn lm(&self) -> &NativeLm {
        &self.lm
    }
}

impl DecodeBackend for NativeBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn vocab(&self) -> usize {
        self.lm.cfg.vocab
    }

    fn kind(&self) -> &'static str {
        "native"
    }

    fn init_state(&self) -> Result<DecodeState> {
        Ok(self.lm.init_state(self.batch))
    }

    fn step(&self, tokens: &IntTensor, state: &DecodeState)
            -> Result<(Tensor, DecodeState)> {
        self.lm.step(tokens, state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        let cfg = NativeLmConfig {
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_state: 2,
            conv_kernel: 3,
            ..Default::default()
        };
        NativeBackend::seeded(&cfg, 42, 3)
    }

    #[test]
    fn native_backend_shapes_and_kind() {
        let be = backend();
        assert_eq!(be.batch(), 3);
        assert_eq!(be.vocab(), 16);
        assert_eq!(be.kind(), "native");
        let st = be.init_state().unwrap();
        assert_eq!(st.conv.shape(), &[2, 3, 2, 8]);
        assert_eq!(st.lam.shape(), &[2, 3, 2, 8]);
        assert_eq!(st.eta.shape(), &[2, 3, 2, 8]);
    }

    #[test]
    fn native_backend_step_is_deterministic() {
        let be = backend();
        let toks = IntTensor::new(&[3], vec![1, 2, 3]).unwrap();
        let st = be.init_state().unwrap();
        let (a, sa) = be.step(&toks, &st).unwrap();
        let (b, sb) = be.step(&toks, &st).unwrap();
        assert_eq!(a.data(), b.data());
        assert_eq!(sa.lam.data(), sb.lam.data());
        assert_eq!(a.shape(), &[3, 16]);
    }

    #[test]
    fn native_backend_usable_as_trait_object() {
        let be = backend();
        let dynref: &dyn DecodeBackend = &be;
        assert_eq!(dynref.batch(), 3);
        assert!(dynref.init_state().is_ok());
    }

    #[test]
    fn from_values_roundtrip_matches_seeded() {
        let be = backend();
        let vals = be.lm().to_values();
        let be2 = NativeBackend::from_values(&vals, 3, true, true).unwrap();
        let toks = IntTensor::new(&[3], vec![5, 6, 7]).unwrap();
        let st = be.init_state().unwrap();
        let (a, _) = be.step(&toks, &st).unwrap();
        let (b, _) = be2.step(&toks, &st).unwrap();
        assert_eq!(a.data(), b.data());
    }
}
